//! Burst resilience: inject 8×-scale traffic spikes and compare how the
//! three LT strategies absorb them (the paper's §7.2.7 / Fig 16a story:
//! LT-I and LT-U cap out at the forecast ceiling, LT-UA's last-20-minute
//! forecast-gap override keeps scaling).
//!
//! ```bash
//! cargo run --release --example burst_resilience
//! ```

use sageserve::config::{ModelKind, Tier};
use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::TraceConfig;

fn main() {
    println!("burst resilience: 1 simulated day, random 5–15 min bursts amplified to ~8x\n");
    println!(
        "{:<8} {:>14} {:>14} {:>12} {:>12}",
        "strategy", "IW-F p95 TTFT", "IW-F viol %", "inst-hours", "mean util"
    );
    for strategy in [Strategy::LtI, Strategy::LtU, Strategy::LtUa] {
        let cfg = SimConfig {
            trace: TraceConfig {
                days: 1.0,
                scale: 0.1,
                bursts: true,
                burst_amplitude: 2.7,       // 2–4x base → ~5.4–10.8x spikes
                burst_minutes: (25.0, 50.0), // long enough to cross LT-UA's
                                             // end-of-hour correction window
                ..Default::default()
            },
            strategy,
            ..Default::default()
        };
        let sim = run_simulation(cfg);
        let iwf = sim.metrics.latency_by_tier(Tier::IwF);
        let ih = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, sim.end_time());
        println!(
            "{:<8} {:>13.2}s {:>13.1}% {:>12.1} {:>12.2}",
            strategy.name(),
            iwf.ttft_p95,
            iwf.sla_violation_rate * 100.0,
            ih,
            sim.metrics.mean_util(ModelKind::Llama2_70B)
        );
    }
    println!("\nexpected shape (paper Fig 16a): LT-UA holds the lowest tail latency under");
    println!("bursts because it alone scales past the ILP/forecast ceiling when observed");
    println!("TPS exceeds 5x the prediction.");
}
