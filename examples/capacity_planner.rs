//! Capacity planner: run the forecast → ILP pipeline standalone, the way
//! SageServe's controller does every hour (§5/§6.3) — useful for what-if
//! planning without a full simulation.
//!
//! ```bash
//! cargo run --release --example capacity_planner            # native forecaster
//! cargo run --release --example capacity_planner -- --pjrt  # AOT/PJRT forecaster
//! ```

use std::collections::BTreeMap;

use sageserve::config::{GpuKind, ModelKind, Region, ScalingParams, Tier, HOUR};
use sageserve::coordinator::controller::{run_epoch, SolverStates, Telemetry};
use sageserve::forecast::{Forecaster, NativeArForecaster, PjrtForecaster};
use sageserve::perf::PerfTable;
use sageserve::trace::generator::{TraceConfig, TraceGenerator};

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");
    let models = ModelKind::EVAL4.to_vec();

    // Build a week of per-(model, region) demand history from the trace
    // model, as the production telemetry pipeline would.
    let gen = TraceGenerator::new(TraceConfig { days: 7.0, scale: 0.2, ..Default::default() });
    let mut telemetry = Telemetry::new(&models, 900.0);
    let mut warm = BTreeMap::new();
    for &m in &models {
        for r in Region::ALL {
            let series: Vec<f64> = (0..672)
                .map(|b| {
                    let t = (b as f64 + 0.5) * 900.0;
                    let mut tps = 0.0;
                    for tier in [Tier::IwF, Tier::IwN] {
                        tps += gen.rate(m, r, tier, t)
                            * TraceGenerator::mean_tokens_exact(m, tier)
                            * 0.85;
                    }
                    tps
                })
                .collect();
            warm.insert((m, r), series);
        }
    }
    telemetry.warmup(&warm);

    let mut forecaster: Box<dyn Forecaster> = if pjrt {
        println!("forecaster: PJRT-compiled seasonal-AR (artifacts/)");
        Box::new(PjrtForecaster::load("artifacts")?)
    } else {
        println!("forecaster: native seasonal-AR");
        Box::new(NativeArForecaster::new(96, 8, 4))
    };

    // Plan over a heterogeneous H100+A100 fleet: the ILP's per-SKU
    // columns (θ_{i,k}, α_k) pick where growth lands.
    let gpus = [GpuKind::H100x8, GpuKind::A100x8];
    let perf = PerfTable::for_fleet(&gpus, &models);
    let params = ScalingParams::default();
    // Dense allocated counts: one row per telemetry key (models ×
    // regions, telemetry order), indexed by GpuKind::index — 6 H100 each.
    let counts = vec![[6usize, 0, 0]; telemetry.keys().len()];

    println!("\nhourly scaling plan (δ per SKU; ε = {}, β = {}%):\n",
             params.epsilon, params.niw_buffer_frac * 100.0);
    println!("{:<14} {:<10} {:>8} {:>8} {:>8} {:>14}",
             "model", "region", "current", "δ H100", "δ A100", "forecast TPS");
    let mut solvers = SolverStates::new();
    let t0 = std::time::Instant::now();
    let plan = run_epoch(
        &telemetry, forecaster.as_mut(), &perf, &gpus, &params, &counts, &mut solvers, 0.0,
    );
    let solve = t0.elapsed().as_secs_f64();
    for entry in &plan {
        println!(
            "{:<14} {:<10} {:>8} {:>+8} {:>+8} {:>14.0}",
            entry.model.to_string(),
            entry.region.to_string(),
            counts[0].iter().sum::<usize>(), // uniform seed — see above
            entry.deltas[0],
            entry.deltas[1],
            entry.forecast_tps
        );
    }
    let total_delta: i64 = plan.iter().map(|p| p.delta_total()).sum();
    println!(
        "\nnet change: {total_delta:+} instances; forecast+ILP wall time {:.3} s \
         (paper quotes ~0.7 s ARIMA + ~1.5 s ILP per hour)",
        solve
    );
    println!("(the controller repeats this every hour = {}s of simulated time)", HOUR);
    Ok(())
}
