//! Quickstart: simulate one morning of LLM traffic under SageServe's
//! LT-UA strategy and print the SLA / cost summary.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sageserve::config::Tier;
use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::TraceConfig;

fn main() {
    // A quarter-day of the Jul-2025 workload at 1% of production volume:
    // 4 models, 3 regions, all three SLA tiers.
    let cfg = SimConfig {
        trace: TraceConfig { days: 0.25, scale: 0.05, ..Default::default() },
        strategy: Strategy::LtUa,
        ..Default::default()
    };
    println!("SageServe quickstart: 6 simulated hours, strategy = lt-ua\n");
    let sim = run_simulation(cfg);

    println!("requests completed: {}", sim.metrics.completed);
    for tier in Tier::ALL {
        let s = sim.metrics.latency_by_tier(tier);
        if s.count == 0 {
            continue;
        }
        println!(
            "  {tier:<5} n={:<7} TTFT p50 {:.2}s p95 {:.2}s | E2E p95 {:.2}s | SLA viol {:.1}%",
            s.count,
            s.ttft_p50,
            s.ttft_p95,
            s.e2e_p95,
            s.sla_violation_rate * 100.0
        );
    }
    let end = sim.end_time();
    let mut total = 0.0;
    for &m in &sim.cfg.trace.models {
        let ih = sim.metrics.model_instance_hours(m, end);
        total += ih;
        println!("  {m:<12} {ih:>7.1} instance-hours (mean util {:.2})", sim.metrics.mean_util(m));
    }
    println!(
        "\ntotal {total:.1} instance-hours; {:.1} donated to spot; {:.2} GPU-h lost to scaling",
        sim.metrics.spot_hours(end),
        sim.metrics.scaling_waste.total_gpu_hours()
    );
    println!("\nNext steps:");
    println!("  target/release/sageserve exp all          # regenerate the paper's figures");
    println!("  cargo run --release --example serve_model # real PJRT serving end-to-end");
}
