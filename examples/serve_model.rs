//! End-to-end serving driver — THE proof that all three layers compose.
//!
//! Loads the AOT-compiled byte-level transformer (Layer-2 JAX model with
//! Layer-1 Pallas attention kernels, lowered to HLO text by
//! `make artifacts`), then serves batched requests from the Rust
//! coordinator via PJRT, reporting TTFT / E2E latency and throughput —
//! with Python nowhere on the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_model
//! ```

use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::runtime::tinylm::TinyLm;
use sageserve::serve::{synthetic_requests, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    println!("loading AOT artifacts from {artifacts}/ ...");
    let model = TinyLm::load(&artifacts)?;
    println!(
        "tinylm: {} layers, d_model {}, {} heads, vocab {} — B={} lanes, S={} prefill, M={} cache",
        model.cfg.n_layers,
        model.cfg.d_model,
        model.cfg.n_heads,
        model.cfg.vocab,
        model.cfg.batch,
        model.cfg.prefill_len,
        model.cfg.max_len
    );

    let mut server = Server::new(model, SchedPolicy::Edf);
    let requests = synthetic_requests(48, 11, 48);
    let n = requests.len();
    println!("serving {n} requests (mixed IW-F / IW-N, greedy decoding, 48 new tokens) ...\n");
    let t0 = std::time::Instant::now();
    let outcomes = server.serve(requests)?;
    let wall = t0.elapsed().as_secs_f64();

    let summary = Server::latency_summary(&outcomes);
    let gen_tokens: usize = outcomes.iter().map(|o| o.generated.len()).sum();
    println!("--- results ---");
    println!("requests:            {}", summary.count);
    println!("wall time:           {wall:.2} s");
    println!("throughput:          {:.1} req/s, {:.0} generated tok/s", n as f64 / wall, gen_tokens as f64 / wall);
    println!("TTFT  mean / p95:    {:.3} / {:.3} s", summary.mean_ttft, summary.ttft_p95);
    println!("E2E   mean / p95:    {:.3} / {:.3} s", summary.mean_e2e, summary.e2e_p95);
    println!("decode throughput:   {:.0} lane-tokens/s per PJRT step", server.decode_throughput());
    println!(
        "perf-model fidelity: prefill R² {:.3}, decode R² {:.3} (Fig 9 analogue)",
        server.phase_r2("prefill").unwrap_or(f64::NAN),
        server.phase_r2("decode").unwrap_or(f64::NAN)
    );

    // Show a couple of generations so it's visibly a real model.
    println!("\nsample generations (byte-level, untrained weights ⇒ gibberish but deterministic):");
    for o in outcomes.iter().take(3) {
        let text: String = o
            .generated
            .iter()
            .map(|&b| if b.is_ascii_graphic() || b == b' ' { b as char } else { '.' })
            .collect();
        println!("  req {:>2} [{}]: \"{}\"", o.id, o.tier, text);
    }
    Ok(())
}
