# SageServe — build / test / bench entry points.
#
# `make check` is the CI gate: tier-1 build + tests plus a bench smoke
# run (SAGESERVE_BENCH_QUICK=1 caps iterations) that refreshes
# BENCH_sim.json at the repo root, so the simulator-throughput
# trajectory stays machine-readable across PRs.  See PERF.md for how to
# read and regenerate the numbers.

CARGO_DIR := rust

.PHONY: check verify build test bench bench-quick smoke-faults smoke-ilp smoke-disagg smoke-guardrails timing docs clean

check: build test bench-quick

# The verify flow: tier-1 build + tests plus the bench smoke that
# refreshes BENCH_sim.json (see PERF.md "Verify flow"), the fault-plane,
# ILP-solver, disaggregation and control-plane-guardrail smokes
# (quick-mode `exp faults` / `exp ilp` / `exp disagg` /
# `exp guardrails`), plus the rustdoc gate (every public-surface doc
# link and `missing_docs` audit must hold).
verify: check smoke-faults smoke-ilp smoke-disagg smoke-guardrails docs

# Fault-plane smoke: the quick-mode fault ablation — 1-day trace, capped
# scale — drives the kill/retry/failover/re-provision path end-to-end
# across both scenarios × 3 strategies, asserts the graceful-degradation
# invariant (no interactive shed) and writes fault_recovery.csv under
# results-smoke/.
smoke-faults:
	cd $(CARGO_DIR) && SAGESERVE_EXP_QUICK=1 cargo run --release -- exp faults --out ../results-smoke

# ILP-solver smoke: the quick-mode §5 runtime table — the two smallest
# sizes through the bounded B&B (cold + warm re-solve) and the dense
# oracle, writing ilp_solver_runtime.csv under results-smoke/.
smoke-ilp:
	cd $(CARGO_DIR) && SAGESERVE_EXP_QUICK=1 cargo run --release -- exp ilp --out ../results-smoke

# Disaggregation smoke: the quick-mode unified-vs-disaggregated ablation
# — 1-day trace, capped scale — drives the prefill/decode pools, the
# KV-transfer handoff and the per-phase capacity solves end-to-end,
# asserts handoff conservation and writes disagg_ablation.csv under
# results-smoke/.
smoke-disagg:
	cd $(CARGO_DIR) && SAGESERVE_EXP_QUICK=1 cargo run --release -- exp disagg --out ../results-smoke

# Control-plane guardrail smoke: the quick-mode guardrail ablation —
# 1-day trace, capped scale — drives a forecast blackout and a telemetry
# freeze through the naive, guarded and reactive controllers, asserts
# the degraded-time invariant (degraded exactly when guarded + faulted)
# and writes guardrail_ablation.csv under results-smoke/.
smoke-guardrails:
	cd $(CARGO_DIR) && SAGESERVE_EXP_QUICK=1 cargo run --release -- exp guardrails --out ../results-smoke

# Rustdoc gate: broken intra-doc links, bad HTML in docs and missing
# docs on the audited modules (config, perf, opt, coordinator::router,
# coordinator::queue_manager, coordinator::autoscaler,
# coordinator::controller, coordinator::scheduler, metrics,
# sim::cluster, sim::engine, sim::chunked, sim::event, sim::instance,
# sim::faults, forecast, trace, experiments — see lib.rs) all fail the
# build.
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

# Full-length benches (several minutes): end-to-end simulator throughput
# + the routing/aggregate hot path + the §5 capacity solver (cold vs
# warm re-solve).  Writes ../BENCH_sim.json.
bench:
	cd $(CARGO_DIR) && SAGESERVE_BENCH_OUT=../BENCH_sim.json cargo bench --bench simulator
	cd $(CARGO_DIR) && cargo bench --bench router_hotpath
	cd $(CARGO_DIR) && cargo bench --bench ilp_solver

# Smoke mode: same benches, capped iterations — still emits BENCH_sim.json.
bench-quick:
	cd $(CARGO_DIR) && SAGESERVE_BENCH_QUICK=1 SAGESERVE_BENCH_OUT=../BENCH_sim.json cargo bench --bench simulator
	cd $(CARGO_DIR) && SAGESERVE_BENCH_QUICK=1 cargo bench --bench router_hotpath
	cd $(CARGO_DIR) && SAGESERVE_BENCH_QUICK=1 cargo bench --bench ilp_solver

# Paper-scale wall-clock AND peak-RSS per experiment (PERF.md records
# the numbers).  Each id runs once at --scale 1.0 under
# `/usr/bin/time -v`; the full resource report lands in
# results-timing/<id>.time, and wall-clock + maximum resident set size
# are extracted into results-timing/summary.tsv — the peak-RSS column is
# the streaming-metrics acceptance signal (O(bins), not O(requests)).
# Expect hours, not minutes, for the week-long ids.
TIMING_IDS := fig8 fig11 fig16a fig16b hetero
timing:
	cd $(CARGO_DIR) && cargo build --release
	mkdir -p results-timing
	printf 'id\twall_clock\tpeak_rss_kb\n' > results-timing/summary.tsv
	for id in $(TIMING_IDS); do \
		echo "=== $$id (--scale 1.0) ==="; \
		/usr/bin/time -v $(CARGO_DIR)/target/release/sageserve exp $$id \
			--scale 1.0 --out results-timing \
			> results-timing/$$id.log 2> results-timing/$$id.time; \
		tail -5 results-timing/$$id.log; \
		wall=$$(grep 'Elapsed (wall clock)' results-timing/$$id.time | awk '{print $$NF}'); \
		rss=$$(grep 'Maximum resident set size' results-timing/$$id.time | awk '{print $$NF}'); \
		printf '%s\t%s\t%s\n' "$$id" "$$wall" "$$rss" >> results-timing/summary.tsv; \
		echo "  wall $$wall  peak RSS $$rss kB"; \
	done
	# Sequential vs chunked single runs on the week trace (the PERF.md
	# peak-RSS/wall-clock comparison row for the epoch-sliced executor):
	# identical config, bit-identical results; the chunked run pipelines
	# generation on worker threads with daily chunks.
	for mode in seq chunked; do \
		extra=""; \
		if [ $$mode = chunked ]; then extra="--chunked --chunk-epochs 24"; fi; \
		echo "=== week_$$mode: simulate lt-ua 7 days (--scale 1.0) $$extra ==="; \
		/usr/bin/time -v $(CARGO_DIR)/target/release/sageserve simulate \
			--strategy lt-ua --days 7 --scale 1.0 $$extra \
			> results-timing/week_$$mode.log 2> results-timing/week_$$mode.time; \
		wall=$$(grep 'Elapsed (wall clock)' results-timing/week_$$mode.time | awk '{print $$NF}'); \
		rss=$$(grep 'Maximum resident set size' results-timing/week_$$mode.time | awk '{print $$NF}'); \
		printf '%s\t%s\t%s\n' "week_$$mode" "$$wall" "$$rss" >> results-timing/summary.tsv; \
		echo "  wall $$wall  peak RSS $$rss kB"; \
	done
	@echo; cat results-timing/summary.tsv

clean:
	cd $(CARGO_DIR) && cargo clean
