# SageServe — build / test / bench entry points.
#
# `make check` is the CI gate: tier-1 build + tests plus a bench smoke
# run (SAGESERVE_BENCH_QUICK=1 caps iterations) that refreshes
# BENCH_sim.json at the repo root, so the simulator-throughput
# trajectory stays machine-readable across PRs.  See PERF.md for how to
# read and regenerate the numbers.

CARGO_DIR := rust

.PHONY: check build test bench bench-quick clean

check: build test bench-quick

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

# Full-length benches (several minutes): end-to-end simulator throughput
# + the routing/aggregate hot path.  Writes ../BENCH_sim.json.
bench:
	cd $(CARGO_DIR) && SAGESERVE_BENCH_OUT=../BENCH_sim.json cargo bench --bench simulator
	cd $(CARGO_DIR) && cargo bench --bench router_hotpath

# Smoke mode: same benches, capped iterations — still emits BENCH_sim.json.
bench-quick:
	cd $(CARGO_DIR) && SAGESERVE_BENCH_QUICK=1 SAGESERVE_BENCH_OUT=../BENCH_sim.json cargo bench --bench simulator
	cd $(CARGO_DIR) && SAGESERVE_BENCH_QUICK=1 cargo bench --bench router_hotpath

clean:
	cd $(CARGO_DIR) && cargo clean
