//! Offline stub of the `xla` PJRT bindings (`xla_extension`-style API).
//!
//! This build environment has no XLA/PJRT toolchain, so the crate graph
//! stubs the exact API surface `sageserve::runtime` consumes:
//! `PjRtClient::cpu()` fails fast with a descriptive error, which every
//! PJRT-dependent path (`serve`, `selftest`, `--pjrt` forecasting, the
//! Fig 9 fidelity study) already handles — those paths require `make
//! artifacts` and skip gracefully when the runtime is unavailable.  The
//! simulator, experiments and benches never touch this crate.
//!
//! To run against real PJRT, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual bindings (same API: `cpu`,
//! `compile`, `execute`, `Literal::{vec1, reshape, to_vec, to_tuple}`,
//! `HloModuleProto::from_text_file`, `XlaComputation::from_proto`).

use std::path::Path;

/// Error type mirroring the bindings' — only ever formatted with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable (offline `xla` stub — link the real bindings to serve models)"
    )))
}

/// Host tensor handle.  The stub never materializes data: every
/// constructor that could feed an executable errors out first.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.  `cpu()` is the single entry point the runtime
/// layer calls first, so failing here fails every PJRT path fast.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_descriptive_error() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("offline `xla` stub"));
    }

    #[test]
    fn literal_constructors_are_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
