//! Invariants for the trace-pipeline overhaul: chunk-parallel generation
//! must be byte-identical to sequential streaming for any chunk size and
//! worker count, and a sweep replaying one shared pre-materialized
//! buffer must produce metrics exactly equal to per-run streaming
//! generation.

use sageserve::config::Epoch;
use sageserve::experiments::sweep::{run_configs, share_traces};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};

fn gen_cfg() -> TraceConfig {
    TraceConfig {
        days: 0.3,
        scale: 0.01,
        bursts: true, // exercise the interval-indexed burst factor too
        seed: 1234,
        ..Default::default()
    }
}

/// The tentpole determinism claim: generation is a pure function of the
/// config — chunking and threading decide only *which worker* computes a
/// minute bucket, never its contents.
#[test]
fn chunk_parallel_identical_to_sequential() {
    let g = TraceGenerator::new(gen_cfg());
    let sequential: Vec<_> = g.stream().collect();
    assert!(sequential.len() > 5_000, "trace too small: {}", sequential.len());
    for chunk_minutes in [1u64, 7, 64, 100_000] {
        for workers in [1usize, 2, 3, 8] {
            let parallel = g.materialize_opts(chunk_minutes, workers);
            assert_eq!(
                parallel, sequential,
                "chunk_minutes={chunk_minutes} workers={workers} diverged from stream"
            );
        }
    }
    // The default materializer too (whatever parallelism the host has).
    assert_eq!(g.materialize(), sequential);
}

#[test]
fn chunk_parallel_identical_across_epochs_and_ratios() {
    // Config variations hit different sampler regimes (Nov has zero-rate
    // IW-F streams; the ratio override reshapes tier λs).
    for cfg in [
        TraceConfig { epoch: Epoch::Nov2024, ..gen_cfg() },
        TraceConfig { iw_niw_ratio: Some(9.0), bursts: false, ..gen_cfg() },
        TraceConfig { days: 0.02, scale: 0.2, ..gen_cfg() },
    ] {
        let g = TraceGenerator::new(cfg);
        let sequential: Vec<_> = g.stream().collect();
        assert!(!sequential.is_empty());
        assert_eq!(g.materialize_opts(13, 4), sequential);
    }
}

/// Shared-buffer replay is a pure wall-clock/allocation optimization:
/// every outcome, ledger point and util sample must match the streaming
/// per-run generation exactly.
#[test]
fn shared_buffer_sweep_matches_streaming_generation() {
    let strategies = [Strategy::Reactive, Strategy::LtUa];
    let quick = |s: Strategy| {
        let mut cfg = quick_config(s, 0.05, 0.005);
        cfg.scaling.max_instances = 10;
        cfg
    };

    // run_configs pre-materializes + shares internally.
    let shared = run_configs(strategies.iter().map(|&s| quick(s)).collect());

    for (r, &s) in shared.iter().zip(&strategies) {
        let streamed = run_simulation(quick(s)); // no shared_trace: streams
        assert!(
            r.metrics.completed > 0,
            "{}: sweep produced no completions",
            s.name()
        );
        assert!(
            r.metrics == streamed.metrics,
            "{}: shared-buffer metrics differ from streaming generation",
            s.name()
        );
    }
}

/// `share_traces` must generate each distinct trace config exactly once:
/// same config ⇒ the same `Arc` allocation; different config ⇒ its own.
#[test]
fn share_traces_generates_each_config_once() {
    let mut cfgs: Vec<SimConfig> = vec![
        quick_config(Strategy::Reactive, 0.05, 0.004),
        quick_config(Strategy::LtUa, 0.05, 0.004),
        quick_config(Strategy::Chiron, 0.05, 0.004),
        // A different scenario in the same grid gets its own buffer.
        quick_config(Strategy::Reactive, 0.05, 0.008),
    ];
    share_traces(&mut cfgs);
    let bufs: Vec<_> = cfgs
        .iter()
        .map(|c| c.shared_trace.as_ref().expect("buffer assigned"))
        .collect();
    assert!(std::sync::Arc::ptr_eq(bufs[0], bufs[1]));
    assert!(std::sync::Arc::ptr_eq(bufs[0], bufs[2]));
    assert!(!std::sync::Arc::ptr_eq(bufs[0], bufs[3]));
    // And the shared buffer really is the config's trace.
    let expect: Vec<_> = TraceGenerator::new(cfgs[0].trace.clone()).stream().collect();
    assert_eq!(&bufs[0][..], &expect[..]);
}

/// The engine must accept the borrowed buffer directly (no re-generation
/// hidden in the run path) and conserve every request in it.
#[test]
fn engine_replays_shared_buffer_losslessly() {
    let mut cfg = quick_config(Strategy::Reactive, 0.05, 0.005);
    cfg.scaling.max_instances = 10;
    let buf = TraceGenerator::new(cfg.trace.clone()).materialize_shared();
    let total = buf.len();
    assert!(total > 100);
    cfg.shared_trace = Some(buf);
    let sim = run_simulation(cfg);
    assert_eq!(
        sim.metrics.completed as usize + sim.metrics.dropped as usize,
        total,
        "shared-buffer replay lost requests"
    );
    assert_eq!(sim.metrics.dropped, 0);
}
