//! Equivalence oracle for the bounded-variable solver stack (§5).
//!
//! The dense two-phase simplex + row-based B&B predates the bounded
//! rewrite and shares no tableau code with it, so agreement on randomized
//! instances is a strong independent check.  Objectives are compared at
//! `3e-4·|obj| + 1e-6`: both paths prune at a 1e-4 relative optimality
//! gap, so each may legitimately stop within `gap·|opt|` of the optimum
//! on opposite sides.

use sageserve::opt::capacity::{
    optimize_capacity, optimize_capacity_dense, optimize_capacity_warm, perturb_inputs,
    synthetic_inputs, CapacityInputs, CapacityPlan, CapacitySolver,
};

fn agree(a: f64, b: f64) -> bool {
    (a - b).abs() <= 3e-4 * a.abs().max(b.abs()) + 1e-6
}

/// The executed allocation `current + δ` must satisfy every §5 row: the
/// per-region floors, the global cover, and the per-variable bounds.
fn assert_feasible(inp: &CapacityInputs, plan: &CapacityPlan) {
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    let x: Vec<Vec<f64>> = (0..r)
        .map(|j| (0..g).map(|k| inp.current[j][k] + plan.deltas[j][k] as f64).collect())
        .collect();
    for j in 0..r {
        let peak = inp.forecast_tps[j].iter().copied().fold(0.0, f64::max);
        let cap: f64 = (0..g).map(|k| x[j][k] * inp.tps_per_instance[k]).sum();
        assert!(
            cap >= inp.epsilon * peak - 1e-6,
            "region {j} floor violated: {cap} < {}",
            inp.epsilon * peak
        );
        for k in 0..g {
            assert!(x[j][k] >= inp.min_instances - 1e-6, "x[{j}][{k}] under floor");
            assert!(x[j][k] <= inp.max_instances + 1e-6, "x[{j}][{k}] over cap");
            assert!((x[j][k] - x[j][k].round()).abs() < 1e-6, "x[{j}][{k}] not integral");
        }
    }
    let windows = inp.forecast_tps[0].len();
    let global_peak = (0..windows)
        .map(|w| (0..r).map(|j| inp.forecast_tps[j][w]).sum::<f64>())
        .fold(0.0f64, f64::max);
    let total: f64 =
        (0..r).map(|j| (0..g).map(|k| x[j][k] * inp.tps_per_instance[k]).sum::<f64>()).sum();
    assert!(total >= global_peak - 1e-6, "global cover violated: {total} < {global_peak}");
}

/// Randomized instances: the bounded path and the dense oracle must find
/// plans of equal cost, and both plans must be feasible.
#[test]
fn randomized_instances_agree_with_dense_oracle() {
    for (r, g) in [(3usize, 1usize), (3, 2), (5, 2), (6, 3)] {
        for seed in 0..8u64 {
            let inp = synthetic_inputs(r, g, seed * 1201 + 17);
            let new = optimize_capacity(&inp)
                .unwrap_or_else(|| panic!("bounded failed at r={r} g={g} seed={seed}"));
            let old = optimize_capacity_dense(&inp)
                .unwrap_or_else(|| panic!("dense failed at r={r} g={g} seed={seed}"));
            assert!(
                agree(new.objective, old.objective),
                "objectives diverged at r={r} g={g} seed={seed}: \
                 bounded {} vs dense {}",
                new.objective,
                old.objective
            );
            assert_feasible(&inp, &new);
            assert_feasible(&inp, &old);
        }
    }
}

/// Epoch-over-epoch warm re-solves (rhs swap + dual simplex from the old
/// basis) must match a from-scratch solve of the drifted instance.
#[test]
fn warm_resolves_match_cold_solves() {
    for (r, g) in [(4usize, 1usize), (5, 2), (8, 3)] {
        for seed in 0..4u64 {
            let inp = synthetic_inputs(r, g, seed * 733 + 5);
            let mut solver = CapacitySolver::new();
            let first = optimize_capacity_warm(&inp, &mut solver).expect("first solve");
            assert!(!first.warm, "first epoch must be cold");

            let mut next = inp.clone();
            let mut prev = first;
            for epoch in 0..3 {
                next = perturb_inputs(&next, &prev, 0.02);
                let warm = optimize_capacity_warm(&next, &mut solver)
                    .unwrap_or_else(|| panic!("warm epoch {epoch} failed"));
                assert!(warm.warm, "epoch {epoch} should reuse state (r={r} g={g} seed={seed})");
                let cold = optimize_capacity(&next).expect("cold reference");
                assert!(
                    agree(warm.objective, cold.objective),
                    "warm/cold diverged at r={r} g={g} seed={seed} epoch={epoch}: \
                     {} vs {}",
                    warm.objective,
                    cold.objective
                );
                assert_feasible(&next, &warm);
                prev = warm;
            }
        }
    }
}

/// Deterministic cost regression guard: the work a solve performs is
/// measured in pivots and B&B nodes, never wall-clock (wall-clock
/// assertions flake on loaded CI machines — timing lives in the benches
/// and PERF.md instead).  A warm epoch chain at paper scale must both
/// stay under the cold budget and shrink per-epoch work substantially.
#[test]
fn warm_epoch_chain_stays_within_pivot_budget() {
    let inp = synthetic_inputs(20, 5, 42);
    let mut solver = CapacitySolver::new();
    let cold = optimize_capacity_warm(&inp, &mut solver).expect("cold solve");
    assert!(cold.pivots < 50_000, "cold solve took {} pivots", cold.pivots);
    assert!(cold.nodes < 2_000, "cold solve explored {} nodes", cold.nodes);

    let cold_pivots = cold.pivots;
    let mut next = inp;
    let mut prev = cold;
    let mut warm_pivots = 0u64;
    for epoch in 0..4 {
        next = perturb_inputs(&next, &prev, 0.02);
        let warm = optimize_capacity_warm(&next, &mut solver)
            .unwrap_or_else(|| panic!("warm epoch {epoch} failed"));
        assert!(warm.warm, "epoch {epoch} must reuse the carried basis");
        warm_pivots += warm.pivots;
        prev = warm;
    }
    // Four warm re-solves together must stay well under four cold
    // solves — the whole point of carrying the basis across epochs.
    assert!(
        warm_pivots <= cold_pivots.max(1) * 2 && warm_pivots < 50_000,
        "warm chain took {warm_pivots} pivots vs {cold_pivots} cold"
    );
}

/// The bounded branch-and-bound explores the same tree as the dense
/// oracle (same branching rule, same incumbent seeding) minus the nodes
/// it discards on the parent bound without a solve — so on any fixed
/// instance its solved-node count never exceeds the oracle's.
#[test]
fn bounded_node_counts_never_exceed_dense() {
    for (r, g, seed) in [(3usize, 1usize, 1u64), (3, 1, 2), (4, 2, 1), (4, 2, 3), (6, 2, 2)] {
        let inp = synthetic_inputs(r, g, seed * 5077 + 11);
        let new = optimize_capacity(&inp).expect("bounded");
        let old = optimize_capacity_dense(&inp).expect("dense");
        assert!(
            new.nodes <= old.nodes,
            "bounded explored {} nodes vs dense {} at r={r} g={g} seed={seed}",
            new.nodes,
            old.nodes
        );
    }
}
