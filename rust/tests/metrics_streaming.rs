//! Invariants for the streaming metrics core: shard `merge()` must equal
//! one sequential accumulation bit-for-bit when shards partition the
//! completion stream by key, and `MetricsMode::Exact` must agree with
//! the streaming summaries (counts/means/rates exactly, percentiles
//! within the histogram error bound).

use sageserve::config::{GpuKind, ModelKind, Region, Tier};
use sageserve::metrics::{LatencySummary, Metrics, MetricsConfig, MetricsMode};
use sageserve::sim::engine::{quick_config, run_simulation, Strategy};
use sageserve::trace::types::{AppKind, Request};
use sageserve::util::rng::Rng;

const WEEK: f64 = 7.0 * 86_400.0;

/// One synthetic completion: (request, serving region, ttft, e2e).
fn synth(i: u64, n: u64, rng: &mut Rng) -> (Request, Region, f64, f64) {
    let model = if i % 3 == 0 { ModelKind::Bloom176B } else { ModelKind::Llama2_70B };
    let tier = Tier::ALL[(i % 5) as usize % 3];
    let region = if i % 2 == 0 { Region::EastUs } else { Region::WestUs };
    let req = Request {
        id: i,
        arrival: i as f64 * (WEEK / n as f64),
        model,
        origin: region,
        tier,
        app: AppKind::Chat,
        input_tokens: 200,
        output_tokens: 50,
    };
    let ttft = 0.05 * 10f64.powf(rng.range(0.0, 2.0));
    let e2e = ttft + 10f64.powf(rng.range(-1.0, 2.5));
    (req, region, ttft, e2e)
}

/// A two-region "week run" split into per-region shards must merge to a
/// metrics container **bit-identical** to sequential accumulation of the
/// full stream: counts and histograms merge exactly by construction, and
/// because every floating sum lives in a per-(model, region) cell, a
/// by-region partition gives each shard exclusive ownership of its cells.
#[test]
fn shard_merge_equals_sequential_on_two_region_week() {
    let n = 20_000u64;
    let mut rng = Rng::seed_from_u64(0xA5);
    let mut seq = Metrics::default();
    let mut east = Metrics::default();
    let mut west = Metrics::default();
    for i in 0..n {
        let (req, region, ttft, e2e) = synth(i, n, &mut rng);
        seq.record_outcome(&req, region, ttft, e2e);
        let shard = if region == Region::EastUs { &mut east } else { &mut west };
        shard.record_outcome(&req, region, ttft, e2e);
    }
    // Utilization samples, hourly, per region.
    for h in 0..(7 * 24) {
        let t = h as f64 * 3600.0;
        let u = 0.3 + 0.5 * ((h % 24) as f64 / 24.0);
        seq.record_util(t, ModelKind::Llama2_70B, Region::EastUs, u);
        east.record_util(t, ModelKind::Llama2_70B, Region::EastUs, u);
        seq.record_util(t, ModelKind::Llama2_70B, Region::WestUs, 1.0 - u);
        west.record_util(t, ModelKind::Llama2_70B, Region::WestUs, 1.0 - u);
    }
    // Region-keyed ledgers and (exactly-representable) waste entries.
    for (m, r, shard) in [
        (ModelKind::Llama2_70B, Region::EastUs, &mut east),
        (ModelKind::Llama2_70B, Region::WestUs, &mut west),
    ] {
        let led = seq.instances.entry((m, r)).or_default();
        led.record(0.0, 4);
        led.record(3600.0, 2);
        let led = shard.instances.entry((m, r)).or_default();
        led.record(0.0, 4);
        led.record(3600.0, 2);
        let k = (m, r, GpuKind::H100x8);
        seq.instances_by_gpu.entry(k).or_default().record(0.0, 4);
        shard.instances_by_gpu.entry(k).or_default().record(0.0, 4);
        seq.scaling_waste.record("vm-provision", 600.0);
        shard.scaling_waste.record("vm-provision", 600.0);
        seq.dropped += 1;
        shard.dropped += 1;
    }

    let mut merged = east;
    merged.merge(&west);
    assert!(merged == seq, "merged shards must equal sequential accumulation exactly");

    // Spot-check a few derived summaries too.
    assert_eq!(merged.completed, seq.completed);
    assert_eq!(
        merged.interactive_latency_bins(ModelKind::Llama2_70B, 3.0 * 3600.0, WEEK),
        seq.interactive_latency_bins(ModelKind::Llama2_70B, 3.0 * 3600.0, WEEK)
    );
    assert_eq!(
        merged.mean_util(ModelKind::Llama2_70B),
        seq.mean_util(ModelKind::Llama2_70B)
    );
}

/// Merging shards of the *same* key (e.g. a future time-sliced chunk
/// split) is exact for counts/histograms and within f64 rounding for
/// means — summaries must agree to near machine precision.
#[test]
fn same_key_merge_matches_sequential_summaries() {
    let n = 10_000u64;
    let mut rng = Rng::seed_from_u64(0x77);
    let mut seq = Metrics::default();
    let mut a = Metrics::default();
    let mut b = Metrics::default();
    for i in 0..n {
        let (req, region, ttft, e2e) = synth(i, n, &mut rng);
        seq.record_outcome(&req, region, ttft, e2e);
        // Split by *time* (first half / second half), not by key.
        let shard = if i < n / 2 { &mut a } else { &mut b };
        shard.record_outcome(&req, region, ttft, e2e);
    }
    let mut merged = a;
    merged.merge(&b);
    for tier in Tier::ALL {
        let (s, m) = (seq.latency_by_tier(tier), merged.latency_by_tier(tier));
        assert_eq!(s.count, m.count, "{tier}");
        assert_eq!(s.sla_violation_rate, m.sla_violation_rate, "{tier}");
        // Histogram-derived percentiles are bit-identical (integer merge).
        assert_eq!(s.ttft_p95, m.ttft_p95, "{tier}");
        assert_eq!(s.e2e_p50, m.e2e_p50, "{tier}");
        // Means agree to f64 rounding.
        assert!((s.mean_ttft - m.mean_ttft).abs() < 1e-9 * s.mean_ttft.max(1.0), "{tier}");
    }
}

/// `MetricsMode::Exact` parity on a real simulation: the streaming
/// accumulators must be identical in both modes (every summary API
/// agrees exactly), and the exact outcome log's percentiles must sit
/// within the histogram error bound of the streaming summaries.
#[test]
fn exact_mode_parity_with_streaming_run() {
    let streaming_cfg = || {
        let mut cfg = quick_config(Strategy::LtUa, 0.05, 0.005);
        cfg.scaling.max_instances = 10;
        cfg
    };
    let exact_cfg = || {
        let mut cfg = streaming_cfg();
        cfg.metrics.mode = MetricsMode::Exact;
        cfg
    };
    let s = run_simulation(streaming_cfg());
    let e = run_simulation(exact_cfg());

    assert_eq!(s.metrics.completed, e.metrics.completed);
    assert!(s.metrics.outcomes.is_empty(), "streaming must not log outcomes");
    assert_eq!(e.metrics.outcomes.len() as u64, e.metrics.completed);

    // Identical streaming summaries in both modes.
    assert_eq!(s.metrics.latency_by_model_tier_all(), e.metrics.latency_by_model_tier_all());
    assert_eq!(
        s.metrics.interactive_latency_by_model(),
        e.metrics.interactive_latency_by_model()
    );
    for &m in &s.cfg.trace.models {
        assert_eq!(s.metrics.mean_util(m), e.metrics.mean_util(m));
    }

    // Exact log vs streaming summaries: counts/rates exact, means to
    // rounding, percentiles within the log-bucket bound.
    for tier in Tier::ALL {
        let stream = s.metrics.latency_by_tier(tier);
        let exact = LatencySummary::from_outcomes(
            e.metrics.outcomes.iter().filter(|o| o.tier == tier),
        );
        assert_eq!(stream.count, exact.count, "{tier}");
        if exact.count == 0 {
            continue;
        }
        assert_eq!(stream.sla_violation_rate, exact.sla_violation_rate, "{tier}");
        assert!(
            (stream.mean_e2e - exact.mean_e2e).abs() < 1e-9 * exact.mean_e2e.max(1.0),
            "{tier}"
        );
        for (h, x) in [
            (stream.ttft_p50, exact.ttft_p50),
            (stream.ttft_p95, exact.ttft_p95),
            (stream.e2e_p50, exact.e2e_p50),
            (stream.e2e_p95, exact.e2e_p95),
        ] {
            assert!(
                (h - x).abs() <= 0.045 * x.abs() + 1e-6,
                "{tier}: streaming {h} vs exact {x}"
            );
        }
    }
}

/// Custom streaming bin widths thread through construction, and the
/// report-bin multiple contract holds.
#[test]
fn custom_bin_width_and_report_multiples() {
    let mut m = Metrics::new(MetricsConfig { mode: MetricsMode::Streaming, bin: 60.0 });
    let mut rng = Rng::seed_from_u64(3);
    for i in 0..500u64 {
        let (mut req, region, ttft, e2e) = synth(i, 500, &mut rng);
        req.arrival = i as f64 * 7.0; // ~1 h of arrivals
        m.record_outcome(&req, region, ttft, e2e);
    }
    assert_eq!(m.bin_width(), 60.0);
    let fine = m.interactive_latency_bins(ModelKind::Llama2_70B, 60.0, 3600.0);
    let coarse = m.interactive_latency_bins(ModelKind::Llama2_70B, 600.0, 3600.0);
    assert_eq!(fine.len(), 60);
    assert_eq!(coarse.len(), 6);
    let fine_total: usize = fine.iter().map(|s| s.count).sum();
    let coarse_total: usize = coarse.iter().map(|s| s.count).sum();
    assert_eq!(fine_total, coarse_total, "report bins must cover the same completions");
}
