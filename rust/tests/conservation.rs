//! Disaggregation invariant layer, part 2: conservation ledgers.
//!
//! Table-driven sweep over {strategy} × {unified, disaggregated} ×
//! {no-fault, data-fault, control-fault, combined}.  Each cell must
//! satisfy, exactly:
//!
//! * **Request conservation** — `completed + dropped + lost + shed`
//!   equals the arrival count of the materialized trace; nothing is
//!   double-counted or silently forgotten, even when an outage kills
//!   work mid-phase.
//! * **Handoff conservation** — every prefill→decode handoff is either
//!   admitted to a decode instance or explicitly dropped, exactly once
//!   (in-flight handoffs at the drain cutoff are counted as drops).
//! * **Hour-ledger consistency** — the per-SKU GPU-hour ledgers and the
//!   per-model instance-hour ledgers are recorded at the same change
//!   points, so their fleet totals must agree.
//! * **Gate hygiene** — unified cells keep every disaggregation counter
//!   at zero (the bit-identity guarantee rests on this), and no cell
//!   ever sheds interactive traffic.
//!
//! The control-fault rows additionally pin the fault *plane* boundary:
//! control faults rot the controller's inputs and outputs but never
//! touch the data plane, so a control-only cell must kill nothing,
//! while the per-cause exposure counters (and, on the guarded path,
//! degraded time) must be non-zero exactly where the windows fired.

use sageserve::config::{DisaggParams, GuardrailParams, ModelKind, Region};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::sim::faults::{ActuationDelay, ControlFaultPlan, FaultPlan};
use sageserve::trace::generator::TraceGenerator;

#[derive(Clone, Copy, PartialEq)]
enum FaultMix {
    None,
    /// Data plane only: a region outage kills in-flight work.
    Data,
    /// Control plane only: blackout + freeze + solver + actuation rot.
    Control,
    /// Both planes at once.
    Both,
}

impl FaultMix {
    fn name(self) -> &'static str {
        match self {
            FaultMix::None => "no-fault",
            FaultMix::Data => "region-dark",
            FaultMix::Control => "control-fault",
            FaultMix::Both => "combined",
        }
    }

    fn data(self) -> bool {
        matches!(self, FaultMix::Data | FaultMix::Both)
    }

    fn control(self) -> bool {
        matches!(self, FaultMix::Control | FaultMix::Both)
    }
}

struct Cell {
    strategy: Strategy,
    disagg: bool,
    fault: FaultMix,
}

/// Every control-fault kind at once, with windows placed so that the
/// quick trace's hourly control epochs (t = 0, 3600, 7200 over the
/// 8640 s span) land inside them: the blackout covers t = 3600, the
/// telemetry freeze and solver window cover t = 7200.
fn control_plan() -> ControlFaultPlan {
    let mut p = ControlFaultPlan::forecast_blackout(3000.0, 5000.0);
    p.telemetry_freezes.push((5000.0, 7500.0));
    p.solver_failures.push((7000.0, 8000.0));
    p.actuation_drops.push((2000.0, 4000.0));
    p.actuation_delays.push(ActuationDelay { start: 4000.0, end: 6000.0, extra: 60.0 });
    p
}

fn cell_config(cell: &Cell) -> SimConfig {
    let mut cfg = quick_config(cell.strategy, 0.1, 0.005);
    cfg.scaling.max_instances = 10;
    if cell.disagg {
        cfg.disagg = DisaggParams::enabled();
    }
    if cell.fault.data() {
        cfg.faults = FaultPlan::region_dark(Region::EastUs, 2000.0, 5000.0);
    }
    if cell.fault.control() {
        cfg.control_faults = control_plan();
        cfg.guardrails = GuardrailParams::enabled();
    }
    cfg
}

#[test]
fn every_cell_conserves_requests_handoffs_and_hours() {
    let mut cells = Vec::new();
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        for disagg in [false, true] {
            for fault in [FaultMix::None, FaultMix::Data, FaultMix::Control, FaultMix::Both] {
                cells.push(Cell { strategy, disagg, fault });
            }
        }
    }

    for cell in &cells {
        let tag = format!(
            "{}/{}/{}",
            cell.strategy.name(),
            if cell.disagg { "disagg" } else { "unified" },
            cell.fault.name()
        );
        let sim = run_simulation(cell_config(cell));
        let m = &sim.metrics;
        let f = &m.failures;
        let arrivals = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert!(arrivals > 100, "{tag}: trace too small to exercise anything");

        // Request conservation, exact.
        assert_eq!(
            m.completed + m.dropped + f.lost_total() + f.shed_total(),
            arrivals,
            "{tag}: every arrival must complete, drop, be lost, or be shed — once"
        );
        assert_eq!(f.shed_interactive_total(), 0, "{tag}: IW traffic must never be shed");

        // Handoff conservation and gate hygiene.
        if cell.disagg {
            assert!(m.handoffs > 0, "{tag}: disaggregated cell never handed off");
            assert_eq!(
                m.handoffs,
                m.handoff_admissions + m.handoff_drops,
                "{tag}: handoffs must be admitted or dropped, exactly once"
            );
            assert!(m.kv_transfer_secs > 0.0, "{tag}: handoffs must pay KV transfer");
        } else {
            assert_eq!(m.handoffs, 0, "{tag}: unified cell must not hand off");
            assert_eq!(m.handoff_admissions, 0, "{tag}");
            assert_eq!(m.handoff_drops, 0, "{tag}");
            assert_eq!(m.kv_transfer_secs, 0.0, "{tag}: unified cell must not pay KV");
        }

        // Hour-ledger consistency: the per-SKU and per-model ledgers
        // observe the same roster change points.
        let end = sim.end_time();
        let by_sku: f64 = m.gpu_hours_by_sku(end).values().sum();
        let by_model: f64 =
            ModelKind::ALL.iter().map(|&mk| m.model_instance_hours(mk, end)).sum();
        assert!(
            (by_sku - by_model).abs() < 1e-6 * by_model.max(1.0),
            "{tag}: per-SKU hours {by_sku} diverge from per-model hours {by_model}"
        );
        assert!(by_model > 0.0, "{tag}: the fleet must have run *something*");

        // The phase rosters themselves stayed coherent.
        assert!(sim.cluster.aggregates_consistent(), "{tag}: cluster aggregates drifted");
        if cell.fault.data() {
            assert!(f.killed_total() > 0, "{tag}: the outage must kill in-flight work");
        }

        // Fault-plane boundary: control faults must never reach the
        // data plane (nothing killed), and a cell without control
        // faults must leave every guardrail counter untouched.
        let g = &m.guardrails;
        match cell.fault {
            FaultMix::Control => {
                assert_eq!(f.killed_total(), 0, "{tag}: control faults must kill nothing");
            }
            FaultMix::None | FaultMix::Data => {
                assert!(g.is_empty(), "{tag}: guardrail counters moved without control faults");
            }
            FaultMix::Both => {}
        }
        if cell.fault.control() && !cell.disagg && cell.strategy.uses_forecast() {
            // Exposure stamps: the blackout window covers the t=3600
            // epoch and the freeze window covers t=7200, so both
            // per-cause counters must have fired...
            assert!(g.blackout_epochs >= 1, "{tag}: blackout epoch never stamped");
            assert!(g.stale_epochs >= 1, "{tag}: stale-telemetry epoch never stamped");
            // ...and the guarded cascade must have left Fresh mode for
            // exactly as long as the watchdog saw rotten inputs.
            assert!(g.degraded_secs > 0.0, "{tag}: guarded cell never went degraded");
            assert!(g.transition_count() > 0, "{tag}: guarded cell never transitioned");
        }
        if !cell.fault.control() {
            assert_eq!(g.degraded_secs, 0.0, "{tag}: degraded time without control faults");
        }
    }
}
