//! Disaggregation invariant layer, part 2: conservation ledgers.
//!
//! Table-driven sweep over {strategy} × {unified, disaggregated} ×
//! {no-fault, region-dark}.  Each cell must satisfy, exactly:
//!
//! * **Request conservation** — `completed + dropped + lost + shed`
//!   equals the arrival count of the materialized trace; nothing is
//!   double-counted or silently forgotten, even when an outage kills
//!   work mid-phase.
//! * **Handoff conservation** — every prefill→decode handoff is either
//!   admitted to a decode instance or explicitly dropped, exactly once
//!   (in-flight handoffs at the drain cutoff are counted as drops).
//! * **Hour-ledger consistency** — the per-SKU GPU-hour ledgers and the
//!   per-model instance-hour ledgers are recorded at the same change
//!   points, so their fleet totals must agree.
//! * **Gate hygiene** — unified cells keep every disaggregation counter
//!   at zero (the bit-identity guarantee rests on this), and no cell
//!   ever sheds interactive traffic.

use sageserve::config::{DisaggParams, ModelKind, Region};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::sim::faults::FaultPlan;
use sageserve::trace::generator::TraceGenerator;

struct Cell {
    strategy: Strategy,
    disagg: bool,
    fault: bool,
}

fn cell_config(cell: &Cell) -> SimConfig {
    let mut cfg = quick_config(cell.strategy, 0.1, 0.005);
    cfg.scaling.max_instances = 10;
    if cell.disagg {
        cfg.disagg = DisaggParams::enabled();
    }
    if cell.fault {
        cfg.faults = FaultPlan::region_dark(Region::EastUs, 2000.0, 5000.0);
    }
    cfg
}

#[test]
fn every_cell_conserves_requests_handoffs_and_hours() {
    let mut cells = Vec::new();
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        for disagg in [false, true] {
            for fault in [false, true] {
                cells.push(Cell { strategy, disagg, fault });
            }
        }
    }

    for cell in &cells {
        let tag = format!(
            "{}/{}/{}",
            cell.strategy.name(),
            if cell.disagg { "disagg" } else { "unified" },
            if cell.fault { "region-dark" } else { "no-fault" }
        );
        let sim = run_simulation(cell_config(cell));
        let m = &sim.metrics;
        let f = &m.failures;
        let arrivals = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert!(arrivals > 100, "{tag}: trace too small to exercise anything");

        // Request conservation, exact.
        assert_eq!(
            m.completed + m.dropped + f.lost_total() + f.shed_total(),
            arrivals,
            "{tag}: every arrival must complete, drop, be lost, or be shed — once"
        );
        assert_eq!(f.shed_interactive_total(), 0, "{tag}: IW traffic must never be shed");

        // Handoff conservation and gate hygiene.
        if cell.disagg {
            assert!(m.handoffs > 0, "{tag}: disaggregated cell never handed off");
            assert_eq!(
                m.handoffs,
                m.handoff_admissions + m.handoff_drops,
                "{tag}: handoffs must be admitted or dropped, exactly once"
            );
            assert!(m.kv_transfer_secs > 0.0, "{tag}: handoffs must pay KV transfer");
        } else {
            assert_eq!(m.handoffs, 0, "{tag}: unified cell must not hand off");
            assert_eq!(m.handoff_admissions, 0, "{tag}");
            assert_eq!(m.handoff_drops, 0, "{tag}");
            assert_eq!(m.kv_transfer_secs, 0.0, "{tag}: unified cell must not pay KV");
        }

        // Hour-ledger consistency: the per-SKU and per-model ledgers
        // observe the same roster change points.
        let end = sim.end_time();
        let by_sku: f64 = m.gpu_hours_by_sku(end).values().sum();
        let by_model: f64 =
            ModelKind::ALL.iter().map(|&mk| m.model_instance_hours(mk, end)).sum();
        assert!(
            (by_sku - by_model).abs() < 1e-6 * by_model.max(1.0),
            "{tag}: per-SKU hours {by_sku} diverge from per-model hours {by_model}"
        );
        assert!(by_model > 0.0, "{tag}: the fleet must have run *something*");

        // The phase rosters themselves stayed coherent.
        assert!(sim.cluster.aggregates_consistent(), "{tag}: cluster aggregates drifted");
        if cell.fault {
            assert!(f.killed_total() > 0, "{tag}: the outage must kill in-flight work");
        }
    }
}
