//! Pure-perf invariants for the simulator hot-path overhaul: the
//! incremental cluster accounting must agree with a from-scratch recount
//! at any point, and the parallel experiment sweep must produce metrics
//! byte-identical to sequential execution.

use sageserve::config::ModelKind;
use sageserve::experiments::sweep::{run_configs, sweep};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};

fn quick(strategy: Strategy) -> SimConfig {
    let mut cfg = quick_config(strategy, 0.05, 0.005);
    cfg.scaling.max_instances = 10;
    cfg
}

/// The incremental endpoint aggregates (per-pool KV, waiting/pending
/// tokens, active counts, busy-instance counter, roster caches, cached
/// per-instance token counters) must match a from-scratch recount after
/// a full simulation run — across strategies with very different
/// mutation mixes (reactive drains, queue-manager releases, Chiron
/// pools).
#[test]
fn incremental_aggregates_match_recount() {
    for strategy in [
        Strategy::Reactive,
        Strategy::Siloed,
        Strategy::LtUa,
        Strategy::Chiron,
    ] {
        let sim = run_simulation(quick(strategy));
        assert!(
            sim.metrics.completed > 0,
            "{}: run produced no completions",
            strategy.name()
        );
        assert!(
            sim.cluster.aggregates_consistent(),
            "{}: incremental aggregates drifted from recount",
            strategy.name()
        );
    }
}

/// The parallel sweep — worker pool AND shared pre-materialized arrival
/// buffers — must be a pure wall-clock optimization: identical
/// per-strategy metrics (every streaming accumulator cell, histogram
/// bucket, ledger point and util bin) to running the same configs
/// sequentially with streaming trace generation.
#[test]
fn parallel_sweep_identical_to_sequential() {
    let strategies = [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron];
    let cfgs: Vec<SimConfig> = strategies.iter().map(|&s| quick(s)).collect();

    let parallel = run_configs(cfgs);
    let sequential: Vec<_> = strategies.iter().map(|&s| run_simulation(quick(s))).collect();

    assert_eq!(parallel.len(), sequential.len());
    for (p, s) in parallel.iter().zip(&sequential) {
        assert_eq!(p.strategy, s.cfg.strategy, "result order must match input order");
        assert!(
            p.metrics == s.metrics,
            "{}: parallel metrics differ from sequential",
            p.strategy.name()
        );
        let ih_p = p.metrics.model_instance_hours(ModelKind::Llama2_70B, p.end_time);
        let ih_s = s.instance_hours(ModelKind::Llama2_70B);
        assert_eq!(ih_p, ih_s, "{}: instance-hours differ", p.strategy.name());
    }
}

/// The generic sweep runner itself: order preservation under contention.
#[test]
fn sweep_runner_is_order_preserving() {
    let items: Vec<u64> = (0..64).collect();
    let out = sweep(items.clone(), |x| x * x);
    assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
}
