//! Heterogeneous-fleet integration tests: the per-SKU plumbing must be
//! invisible for single-SKU fleets (the degenerate case every paper
//! experiment runs), deterministic, conservation-safe for mixed fleets
//! (including the k=3 three-way fleet), and cost-ordered (a mixed fleet
//! must not out-spend the expensive homogeneous fleet it can always
//! imitate).  SKU-aware routing rides the same bars: identical to blind
//! on homogeneous fleets, deterministic on mixed ones, and no worse on
//! net cost at equal SLA attainment in the mixed-fleet ablation.

use sageserve::config::{FleetSpec, GpuKind, ModelKind};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::TraceGenerator;

fn quick(strategy: Strategy) -> SimConfig {
    let mut cfg = quick_config(strategy, 0.05, 0.005);
    cfg.scaling.max_instances = 10;
    cfg
}

fn mixed_fleet() -> FleetSpec {
    FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)])
}

/// A fleet declared through the multi-SKU API but holding one SKU must
/// produce metrics *identical* to the default homogeneous config — every
/// outcome, ledger point and util sample.
#[test]
fn single_sku_fleet_is_the_degenerate_case() {
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        let base = run_simulation(quick(strategy));
        let mut cfg = quick(strategy);
        cfg.fleet = FleetSpec::mixed(&[(GpuKind::H100x8, 1.0)]);
        let via_fleet = run_simulation(cfg);
        assert!(
            base.metrics == via_fleet.metrics,
            "{}: single-SKU fleet diverged from the homogeneous default",
            strategy.name()
        );
    }
}

/// Mixed fleets keep every invariant the single-SKU engine guarantees:
/// request conservation, coherent incremental aggregates, determinism,
/// and per-SKU GPU-hour ledgers that sum to the per-endpoint totals.
#[test]
fn mixed_fleet_conserves_and_accounts_per_sku() {
    let mut cfg = quick(Strategy::LtUa);
    cfg.fleet = mixed_fleet();
    let total = TraceGenerator::new(cfg.trace.clone()).stream().count();
    let sim = run_simulation(cfg);
    assert_eq!(
        sim.metrics.completed as usize + sim.metrics.dropped as usize,
        total,
        "mixed fleet lost requests"
    );
    assert_eq!(sim.metrics.dropped, 0);
    assert!(sim.cluster.aggregates_consistent());

    let end = sim.end_time();
    let by_sku = sim.metrics.gpu_hours_by_sku(end);
    // Both SKUs hosted instances at some point (the initial 3/3 split).
    assert!(by_sku.get(&GpuKind::H100x8).copied().unwrap_or(0.0) > 0.0);
    assert!(by_sku.get(&GpuKind::A100x8).copied().unwrap_or(0.0) > 0.0);
    assert!(sim.metrics.fleet_dollar_cost(end) > 0.0);

    // Per-SKU ledgers are recorded at the same change points as the
    // endpoint totals, so their hours must sum to the total hours.
    let total_h = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, end);
    let sku_h: f64 = by_sku.values().sum();
    assert!(
        (total_h - sku_h).abs() < 1e-6 * total_h.max(1.0),
        "per-SKU hours {sku_h} != total {total_h}"
    );

    // Determinism across runs, mixed fleet included.
    let mut cfg2 = quick(Strategy::LtUa);
    cfg2.fleet = mixed_fleet();
    let sim2 = run_simulation(cfg2);
    assert!(sim.metrics == sim2.metrics, "mixed fleet nondeterministic");
}

/// Cost ordering: a 50/50 mixed fleet drains its expensive H100s first
/// (most-expensive-first scale-in) and grows on the cheaper-per-θ A100s,
/// so it must come in cheaper than the all-H100 fleet on the same trace.
#[test]
fn mixed_fleet_cheaper_than_h100_only() {
    let h100 = run_simulation(quick(Strategy::LtUa));
    let mut cfg = quick(Strategy::LtUa);
    cfg.fleet = mixed_fleet();
    let mixed = run_simulation(cfg);
    let cost_h100 = h100.metrics.fleet_dollar_cost(h100.end_time());
    let cost_mixed = mixed.metrics.fleet_dollar_cost(mixed.end_time());
    assert!(cost_h100 > 0.0 && cost_mixed > 0.0);
    assert!(
        cost_mixed < cost_h100,
        "mixed fleet (${cost_mixed:.0}) must undercut H100-only (${cost_h100:.0})"
    );
}

/// The k=3 three-way fleet keeps every engine invariant: request
/// conservation, coherent aggregates, per-SKU GPU-hour ledgers that sum
/// to the endpoint totals across all three SKUs, and determinism — the
/// ILP-plan-to-execution pipeline conserves instances at k=3.
#[test]
fn three_way_fleet_conserves_and_accounts_per_sku() {
    let mut cfg = quick(Strategy::LtUa);
    cfg.fleet = FleetSpec::mixed_3way();
    let total = TraceGenerator::new(cfg.trace.clone()).stream().count();
    let sim = run_simulation(cfg);
    assert_eq!(
        sim.metrics.completed as usize + sim.metrics.dropped as usize,
        total,
        "three-way fleet lost requests"
    );
    assert_eq!(sim.metrics.dropped, 0);
    assert!(sim.cluster.aggregates_consistent());

    let end = sim.end_time();
    let by_sku = sim.metrics.gpu_hours_by_sku(end);
    for g in GpuKind::ALL {
        assert!(
            by_sku.get(&g).copied().unwrap_or(0.0) > 0.0,
            "{g} hosted no instance-hours in the three-way fleet"
        );
    }
    let total_h = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, end);
    let sku_h: f64 = by_sku.values().sum();
    assert!(
        (total_h - sku_h).abs() < 1e-6 * total_h.max(1.0),
        "per-SKU hours {sku_h} != total {total_h}"
    );
    // The spot-vs-on-demand split is internally consistent.
    let cost_sum: f64 = sim.metrics.fleet_dollar_cost_by_sku(end).values().sum();
    assert!((cost_sum - sim.metrics.fleet_dollar_cost(end)).abs() < 1e-6);
    assert!(sim.metrics.spot_revenue(end) >= 0.0);
    assert!(
        sim.metrics.net_fleet_cost(end) <= sim.metrics.fleet_dollar_cost(end) + 1e-9,
        "spot revenue must not increase net cost"
    );

    // Determinism across runs at k=3.
    let mut cfg2 = quick(Strategy::LtUa);
    cfg2.fleet = FleetSpec::mixed_3way();
    let sim2 = run_simulation(cfg2);
    assert!(sim.metrics == sim2.metrics, "three-way fleet nondeterministic");
}

/// On a homogeneous fleet the SKU-aware router short-circuits to blind
/// JSQ by construction — the two policies must produce *identical*
/// metrics, outcome for outcome.
#[test]
fn sku_routing_is_identity_on_single_sku_fleets() {
    for strategy in [Strategy::Reactive, Strategy::LtUa] {
        let aware = run_simulation(quick(strategy));
        let mut cfg = quick(strategy);
        cfg.routing.sku_affinity = false;
        let blind = run_simulation(cfg);
        assert!(
            aware.metrics == blind.metrics,
            "{}: SKU-aware diverged from blind on a homogeneous fleet",
            strategy.name()
        );
    }
}

/// The routing ablation on the same three-way fleet and trace:
/// SKU-aware must be deterministic, and no worse on net cost at equal
/// SLA attainment (small tolerances — the quick trace is tiny, so the
/// two runs differ by at most a few scaling events).
#[test]
fn sku_aware_routing_no_worse_than_blind_on_mixed_fleet() {
    let run = |sku_aware: bool| {
        let mut cfg = quick(Strategy::LtUa);
        cfg.fleet = FleetSpec::mixed_3way();
        cfg.routing.sku_affinity = sku_aware;
        run_simulation(cfg)
    };
    let aware = run(true);
    let aware2 = run(true);
    assert!(aware.metrics == aware2.metrics, "SKU-aware routing nondeterministic");
    let blind = run(false);

    let end = aware.end_time();
    let net_aware = aware.metrics.net_fleet_cost(end);
    let net_blind = blind.metrics.net_fleet_cost(blind.end_time());
    assert!(net_aware > 0.0 && net_blind > 0.0);
    assert!(
        net_aware <= net_blind * 1.05 + 1.0,
        "SKU-aware net cost ${net_aware:.0} worse than blind ${net_blind:.0}"
    );

    let attainment = |sim: &sageserve::sim::engine::Simulation| {
        let iw = sim.metrics.interactive_latency();
        if iw.count == 0 {
            1.0
        } else {
            1.0 - iw.sla_violation_rate
        }
    };
    let (sla_aware, sla_blind) = (attainment(&aware), attainment(&blind));
    assert!(
        sla_aware >= sla_blind - 0.02,
        "SKU-aware SLA attainment {sla_aware:.4} fell below blind {sla_blind:.4}"
    );
}

/// The §5 ILP at k=3: every per-(model, region) plan entry carries one
/// delta per fleet SKU, and executing a plan never double-counts — the
/// summed per-SKU allocation always matches the endpoint roster.
#[test]
fn k3_epoch_plans_align_with_fleet_axis() {
    use sageserve::coordinator::controller::{run_epoch, SolverStates, Telemetry};
    use sageserve::forecast::SeasonalNaive;
    use sageserve::config::{Region, ScalingParams};
    use sageserve::perf::PerfTable;
    use std::collections::BTreeMap;

    let models = [ModelKind::Llama2_70B];
    let mut telemetry = Telemetry::new(&models, 900.0);
    let mut warm = BTreeMap::new();
    for r in Region::ALL {
        let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
        warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
    }
    telemetry.warmup(&warm);
    let gpus = GpuKind::ALL;
    let perf = PerfTable::for_fleet(&gpus, &models);
    let params = ScalingParams::default();
    let mut forecaster = SeasonalNaive::new(96, 4);
    // Dense per-SKU counts: one row per telemetry key, GpuKind::index order.
    let counts = vec![[1usize, 1, 1]; Region::ALL.len()];
    let plan = run_epoch(
        &telemetry, &mut forecaster, &perf, &gpus, &params, &counts,
        &mut SolverStates::new(), 0.0,
    );
    assert_eq!(plan.len(), 3, "one entry per region");
    for entry in &plan {
        assert_eq!(entry.deltas.len(), 3, "k=3 plans carry one delta per SKU");
        // Plans never shrink below zero instances of any SKU.
        for (k, &d) in entry.deltas.iter().enumerate() {
            assert!(1 + d >= 0, "SKU {k} delta {d} under-runs current count");
        }
    }
    // The hot region must be planned up: ε × its ~20k-TPS peak exceeds
    // the three incumbents' combined θ (≈7.4k TPS), so the §5 local
    // floor forces east growth on some SKU.
    let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
    assert!(east.delta_total() > 0, "east delta {}", east.delta_total());
}
