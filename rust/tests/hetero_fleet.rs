//! Heterogeneous-fleet integration tests: the per-SKU plumbing must be
//! invisible for single-SKU fleets (the degenerate case every paper
//! experiment runs), deterministic, conservation-safe for mixed fleets,
//! and cost-ordered (a mixed fleet must not out-spend the expensive
//! homogeneous fleet it can always imitate).

use sageserve::config::{FleetSpec, GpuKind, ModelKind};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::TraceGenerator;

fn quick(strategy: Strategy) -> SimConfig {
    let mut cfg = quick_config(strategy, 0.05, 0.005);
    cfg.scaling.max_instances = 10;
    cfg
}

fn mixed_fleet() -> FleetSpec {
    FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)])
}

/// A fleet declared through the multi-SKU API but holding one SKU must
/// produce metrics *identical* to the default homogeneous config — every
/// outcome, ledger point and util sample.
#[test]
fn single_sku_fleet_is_the_degenerate_case() {
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        let base = run_simulation(quick(strategy));
        let mut cfg = quick(strategy);
        cfg.fleet = FleetSpec::mixed(&[(GpuKind::H100x8, 1.0)]);
        let via_fleet = run_simulation(cfg);
        assert!(
            base.metrics == via_fleet.metrics,
            "{}: single-SKU fleet diverged from the homogeneous default",
            strategy.name()
        );
    }
}

/// Mixed fleets keep every invariant the single-SKU engine guarantees:
/// request conservation, coherent incremental aggregates, determinism,
/// and per-SKU GPU-hour ledgers that sum to the per-endpoint totals.
#[test]
fn mixed_fleet_conserves_and_accounts_per_sku() {
    let mut cfg = quick(Strategy::LtUa);
    cfg.fleet = mixed_fleet();
    let total = TraceGenerator::new(cfg.trace.clone()).stream().count();
    let sim = run_simulation(cfg);
    assert_eq!(
        sim.metrics.outcomes.len() + sim.metrics.dropped as usize,
        total,
        "mixed fleet lost requests"
    );
    assert_eq!(sim.metrics.dropped, 0);
    assert!(sim.cluster.aggregates_consistent());

    let end = sim.end_time();
    let by_sku = sim.metrics.gpu_hours_by_sku(end);
    // Both SKUs hosted instances at some point (the initial 3/3 split).
    assert!(by_sku.get(&GpuKind::H100x8).copied().unwrap_or(0.0) > 0.0);
    assert!(by_sku.get(&GpuKind::A100x8).copied().unwrap_or(0.0) > 0.0);
    assert!(sim.metrics.fleet_dollar_cost(end) > 0.0);

    // Per-SKU ledgers are recorded at the same change points as the
    // endpoint totals, so their hours must sum to the total hours.
    let total_h = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, end);
    let sku_h: f64 = by_sku.values().sum();
    assert!(
        (total_h - sku_h).abs() < 1e-6 * total_h.max(1.0),
        "per-SKU hours {sku_h} != total {total_h}"
    );

    // Determinism across runs, mixed fleet included.
    let mut cfg2 = quick(Strategy::LtUa);
    cfg2.fleet = mixed_fleet();
    let sim2 = run_simulation(cfg2);
    assert!(sim.metrics == sim2.metrics, "mixed fleet nondeterministic");
}

/// Cost ordering: a 50/50 mixed fleet drains its expensive H100s first
/// (most-expensive-first scale-in) and grows on the cheaper-per-θ A100s,
/// so it must come in cheaper than the all-H100 fleet on the same trace.
#[test]
fn mixed_fleet_cheaper_than_h100_only() {
    let h100 = run_simulation(quick(Strategy::LtUa));
    let mut cfg = quick(Strategy::LtUa);
    cfg.fleet = mixed_fleet();
    let mixed = run_simulation(cfg);
    let cost_h100 = h100.metrics.fleet_dollar_cost(h100.end_time());
    let cost_mixed = mixed.metrics.fleet_dollar_cost(mixed.end_time());
    assert!(cost_h100 > 0.0 && cost_mixed > 0.0);
    assert!(
        cost_mixed < cost_h100,
        "mixed fleet (${cost_mixed:.0}) must undercut H100-only (${cost_h100:.0})"
    );
}
