//! Disaggregation invariant layer, part 1: equivalence.
//!
//! * A fleet with disaggregation **off** must be *bit-identical* to the
//!   pre-disaggregation engine — the same empty-gate discipline the
//!   fault plane established (PR 7): the gates check `disagg.enabled`,
//!   so knob values behind a disabled switch must not perturb a single
//!   accumulator cell.
//! * A fleet with disaggregation **on** must replay bit-identically
//!   under the chunked executor for every (chunk size, worker count) —
//!   the handoff/in-flight maps and the decode-phase solver state ride
//!   the `SimHandoff` or this breaks.

use sageserve::config::DisaggParams;
use sageserve::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};

fn base_config(strategy: Strategy) -> SimConfig {
    let mut cfg = quick_config(strategy, 0.1, 0.005);
    cfg.scaling.max_instances = 10;
    cfg
}

#[test]
fn disabled_disagg_knobs_are_bit_identical_to_default() {
    // The engine's disaggregation paths are gated on `disagg.enabled`,
    // not on byte-equality with the default params: a config whose
    // split/target knobs differ but whose switch is off must leave
    // every accumulator cell bit-identical to the default run.
    for strategy in [Strategy::Reactive, Strategy::LtUa] {
        let reference = run_simulation(base_config(strategy));
        let mut cfg = base_config(strategy);
        cfg.disagg.prefill_fraction = 0.7;
        cfg.disagg.ttft_target = 0.25;
        cfg.disagg.itl_target = 0.05;
        assert!(!cfg.disagg.enabled);
        let sim = run_simulation(cfg);
        assert!(
            sim.metrics == reference.metrics,
            "{}: disabled disagg knobs perturbed the unified engine",
            strategy.name()
        );
        assert_eq!(sim.metrics.handoffs, 0);
        assert_eq!(sim.metrics.kv_transfer_secs, 0.0);
    }
}

#[test]
fn chunked_disagg_bit_identical_to_sequential() {
    // Chunk boundaries must be able to land *between* a prefill
    // completion and its decode admission: the pending-handoff map, the
    // in-flight TTFT map and the decode-column warm-start state all
    // cross the handoff.  A 2-day trace crosses diurnal peaks and many
    // control epochs, so both pools scale while requests are mid-phase.
    let mk = || {
        let mut cfg = quick_config(Strategy::LtUa, 2.0, 0.002);
        cfg.scaling.max_instances = 8;
        cfg.disagg = DisaggParams::enabled();
        cfg
    };
    let seq = run_simulation(mk());
    assert!(
        seq.metrics.handoffs > 0,
        "no prefill ever handed off — the test is vacuous"
    );
    assert!(seq.metrics.completed > 1000, "trace too small to be meaningful");
    for (chunk_epochs, workers) in [(1usize, 1usize), (1, 8), (24, 1), (24, 8)] {
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs, workers });
        assert!(
            seq.metrics == ch.metrics,
            "{chunk_epochs} epoch(s) × {workers} worker(s): chunked disagg \
             diverged from sequential"
        );
    }
}

#[test]
fn disagg_suspend_resume_roundtrip_is_identity() {
    // The explicit handoff roundtrip (the primitive under the chunked
    // executor) with disaggregation on: suspending before the run and
    // resuming must not perturb anything.
    use sageserve::sim::engine::Simulation;
    let mk = || {
        let mut cfg = base_config(Strategy::LtUa);
        cfg.disagg = DisaggParams::enabled();
        cfg
    };
    let (cfg, handoff) = Simulation::new(mk()).suspend();
    let mut resumed = Simulation::resume(cfg, handoff);
    resumed.run();
    let reference = run_simulation(mk());
    assert!(resumed.metrics == reference.metrics);
    assert!(resumed.metrics.handoffs > 0);
}
