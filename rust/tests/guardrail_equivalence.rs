//! Control-plane guardrail invariant layer (ISSUE 10): the fault plane
//! and the fallback cascade must not cost the engine its two headline
//! guarantees.
//!
//! * **Empty-plan bit-identity** — an empty [`ControlFaultPlan`] (and a
//!   parsed-from-"" one) leaves both the sequential and the chunked
//!   engine bit-identical to a build that never heard of control
//!   faults: the windows are pure predicates over `now`, compiled into
//!   no events, and every consumer branches on the sampled values
//!   rather than applying identity arithmetic.
//! * **Chunked == sequential with faults active** — blackout windows,
//!   frozen telemetry, solver failures and actuation rot must all
//!   produce the same `Metrics` (full streaming-state equality) under
//!   epoch-sliced execution, because the window predicates are
//!   stateless and the guardrail state they provoke (residual ring,
//!   held plan, cascade mode) rides the `SimHandoff`.

use sageserve::config::GuardrailParams;
use sageserve::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::sim::faults::{ActuationDelay, ControlFaultPlan};

/// Multi-day config so chunk boundaries cross control epochs that sit
/// inside, at the edges of, and outside the fault windows.
fn multi_day_config(strategy: Strategy) -> SimConfig {
    let mut cfg = quick_config(strategy, 2.0, 0.002);
    cfg.scaling.max_instances = 8;
    cfg
}

/// Every control-fault kind at once, windowed inside the 2-day span
/// (48 hourly control epochs): blackout over hours 20–30, telemetry
/// freeze over 30–40, solver failures over 40–44, actuation drops over
/// hours 5–10 and delays over 10–20.
fn active_plan() -> ControlFaultPlan {
    const H: f64 = 3600.0;
    let mut p = ControlFaultPlan::forecast_blackout(20.0 * H, 30.0 * H);
    p.telemetry_freezes.push((30.0 * H, 40.0 * H));
    p.solver_failures.push((40.0 * H, 44.0 * H));
    p.actuation_drops.push((5.0 * H, 10.0 * H));
    p.actuation_delays.push(ActuationDelay { start: 10.0 * H, end: 20.0 * H, extra: 120.0 });
    p
}

#[test]
fn empty_control_fault_plan_is_bit_identical_even_chunked() {
    // Baseline: no control-fault field ever touched.
    let baseline = run_simulation(multi_day_config(Strategy::LtUa));
    assert!(baseline.metrics.completed > 1000, "trace too small to be meaningful");

    // An explicitly-empty plan — both the Default and the parse("")
    // spelling — through both executors.
    for parsed in [false, true] {
        let mk = || {
            let mut cfg = multi_day_config(Strategy::LtUa);
            cfg.control_faults = if parsed {
                ControlFaultPlan::parse("").expect("empty plan must parse")
            } else {
                ControlFaultPlan::default()
            };
            cfg
        };
        assert!(mk().control_faults.is_empty());
        let seq = run_simulation(mk());
        assert!(
            baseline.metrics == seq.metrics,
            "empty plan (parsed={parsed}) diverged sequentially"
        );
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs: 3, workers: 2 });
        assert!(
            baseline.metrics == ch.metrics,
            "empty plan (parsed={parsed}) diverged under chunked execution"
        );
        assert!(seq.metrics.guardrails.is_empty(), "empty plan moved a guardrail counter");
    }
}

#[test]
fn chunked_bit_identical_with_active_control_faults() {
    // The headline grid: the full control-fault schedule through the
    // naive controller (exposed, never degrades) and the guarded one
    // (walks the cascade; `GuardrailState` must survive every chunk
    // handoff), each at the corner chunk/worker combinations.
    for guarded in [false, true] {
        let mk = || {
            let mut cfg = multi_day_config(Strategy::LtUa);
            cfg.control_faults = active_plan();
            if guarded {
                cfg.guardrails = GuardrailParams::enabled();
            }
            cfg
        };
        let seq = run_simulation(mk());
        let g = &seq.metrics.guardrails;
        // Non-vacuity: every fault kind actually fired on the controller.
        assert!(g.blackout_epochs > 0, "blackout window never hit a control epoch");
        assert!(g.stale_epochs > 0, "freeze window never hit a control epoch");
        assert!(g.solver_fault_epochs > 0, "solver window never hit a control epoch");
        if guarded {
            assert!(g.degraded_secs > 0.0, "guarded run never went degraded");
            assert!(g.transition_count() > 0, "guarded run never transitioned");
        } else {
            assert_eq!(g.degraded_secs, 0.0, "naive run has no cascade to degrade");
            assert_eq!(g.transition_count(), 0, "naive run has no cascade to transition");
        }
        for (chunk_epochs, workers) in [(1usize, 1usize), (1, 8), (24, 1), (24, 8)] {
            let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs, workers });
            assert!(
                seq.metrics == ch.metrics,
                "{} / {chunk_epochs} epoch(s) × {workers} worker(s): chunked diverged \
                 from sequential with control faults active",
                if guarded { "guarded" } else { "naive" }
            );
        }
    }
}

#[test]
fn guarded_fault_free_run_is_chunked_invariant() {
    // Guardrails with *no* faults: the residual tracker still runs
    // (θ inflation is active fault-free), so its ring buffer is live
    // state that must ride the handoff for chunked to stay identical.
    let mk = || {
        let mut cfg = multi_day_config(Strategy::LtUa);
        cfg.guardrails = GuardrailParams::enabled();
        cfg
    };
    let seq = run_simulation(mk());
    let g = &seq.metrics.guardrails;
    assert!(g.epochs_fresh > 0, "guarded run never took a fresh epoch");
    assert_eq!(g.epochs_held + g.epochs_reactive, 0, "degraded rung without faults");
    assert_eq!(g.degraded_secs, 0.0, "degraded time without faults");
    for (chunk_epochs, workers) in [(1usize, 2usize), (24, 2)] {
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs, workers });
        assert!(
            seq.metrics == ch.metrics,
            "{chunk_epochs} epoch(s) × {workers} worker(s): fault-free guarded run \
             diverged under chunked execution — residual state lost in handoff?"
        );
    }
}
