//! Integration: the full AOT bridge — jax-lowered HLO text executed by
//! the Rust PJRT runtime, validated against golden outputs recorded by
//! the Python side at export time.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) when the
//! artifacts directory is missing so `cargo test` stays green pre-build.

use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::runtime::tinylm::TinyLm;
use sageserve::serve::{synthetic_requests, Server};

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

#[test]
fn selftest_golden_outputs() {
    let Some(dir) = artifacts_dir() else { return };
    sageserve::runtime::selftest::run(&dir).expect("golden outputs must match");
}

#[test]
fn forecast_artifact_matches_native_pipeline() {
    let Some(dir) = artifacts_dir() else { return };
    use sageserve::forecast::{Forecaster, NativeArForecaster, PjrtForecaster};
    let mut pjrt = PjrtForecaster::load(&dir).expect("load forecast artifact");
    let (s_max, t_fix, _h) = pjrt.shape();
    // Diurnal synthetic series matching the artifact's fixed shape.
    let history: Vec<Vec<f64>> = (0..s_max)
        .map(|s| {
            (0..t_fix)
                .map(|t| {
                    let phase = 2.0 * std::f64::consts::PI * (t % 96) as f64 / 96.0;
                    100.0 * (s + 1) as f64 * (1.0 + 0.5 * phase.sin())
                })
                .collect()
        })
        .collect();
    let got = pjrt.forecast(&history);
    let mut native = NativeArForecaster::new(96, 8, 4);
    let want = native.forecast(&history);
    for (s, (g, w)) in got.iter().zip(&want).enumerate() {
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1.0);
            assert!(rel < 5e-2, "series {s} step {i}: pjrt {a} native {b}");
        }
    }
}

#[test]
fn served_generation_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let run = || {
        let model = TinyLm::load(&dir).unwrap();
        let mut server = Server::new(model, SchedPolicy::Edf);
        let outcomes = server.serve(synthetic_requests(8, 5, 12)).unwrap();
        outcomes
            .into_iter()
            .map(|o| (o.id, o.generated))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "greedy decoding through PJRT must be deterministic");
    assert!(a.iter().all(|(_, g)| g.len() == 12));
}

#[test]
fn serving_reports_sane_latencies() {
    let Some(dir) = artifacts_dir() else { return };
    let model = TinyLm::load(&dir).unwrap();
    let mut server = Server::new(model, SchedPolicy::Edf);
    let outcomes = server.serve(synthetic_requests(16, 9, 8)).unwrap();
    assert_eq!(outcomes.len(), 16);
    for o in &outcomes {
        assert!(o.ttft > 0.0 && o.ttft.is_finite());
        assert!(o.e2e >= o.ttft);
        assert_eq!(o.generated.len(), 8);
    }
    // Second wave must start after the first (wave batching).
    let summary = Server::latency_summary(&outcomes);
    assert!(summary.e2e_p95 < 120.0, "runaway latency {}", summary.e2e_p95);
}
