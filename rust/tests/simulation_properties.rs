//! Property-style integration tests over the simulator: conservation,
//! SLA/latency invariants, autoscaler bounds and determinism across many
//! seeded configurations (in-tree proptest harness — offline build).

use sageserve::config::{Epoch, ModelKind, Tier};
use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::metrics::MetricsMode;
use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::proptest::run_cases;

fn quick(strategy: Strategy, seed: u64, scale: f64) -> SimConfig {
    SimConfig {
        trace: TraceConfig {
            days: 0.08,
            scale,
            seed,
            bursts: seed % 2 == 0,
            epoch: if seed % 3 == 0 { Epoch::Nov2024 } else { Epoch::Jul2025 },
            models: vec![ModelKind::Llama2_70B, ModelKind::Llama31_8B],
            ..Default::default()
        },
        strategy,
        initial_instances: 4,
        ..Default::default()
    }
}

#[test]
fn conservation_across_strategies_and_seeds() {
    run_cases(0xC0, 10, |rng, case| {
        let strategies = [
            Strategy::Reactive,
            Strategy::Siloed,
            Strategy::LtI,
            Strategy::LtU,
            Strategy::LtUa,
            Strategy::Chiron,
        ];
        let strategy = strategies[case % strategies.len()];
        let seed = rng.next_u64() % 1000;
        let cfg = quick(strategy, seed, 0.004);
        let total = TraceGenerator::new(cfg.trace.clone()).stream().count();
        let sim = run_simulation(cfg);
        assert_eq!(
            sim.metrics.completed as usize + sim.metrics.dropped as usize,
            total,
            "strategy {} seed {seed}: requests lost",
            strategy.name()
        );
        assert_eq!(sim.metrics.dropped, 0, "strategy {} dropped", strategy.name());
    });
}

#[test]
fn latency_invariants_hold() {
    run_cases(0x11, 6, |rng, _| {
        let seed = rng.next_u64() % 1000;
        // Exact mode: this invariant needs the raw per-request log.
        let mut cfg = quick(Strategy::LtUa, seed, 0.004);
        cfg.metrics.mode = MetricsMode::Exact;
        let sim = run_simulation(cfg);
        assert!(!sim.metrics.outcomes.is_empty(), "seed {seed}");
        for o in &sim.metrics.outcomes {
            assert!(o.ttft > 0.0 && o.ttft.is_finite(), "seed {seed}");
            assert!(o.e2e >= o.ttft - 1e-9, "seed {seed}: e2e {} < ttft {}", o.e2e, o.ttft);
        }
    });
}

#[test]
fn instance_counts_respect_bounds() {
    run_cases(0xB0, 6, |rng, case| {
        let strategies = [Strategy::Reactive, Strategy::LtI, Strategy::LtUa];
        let strategy = strategies[case % strategies.len()];
        let seed = rng.next_u64() % 1000;
        let cfg = quick(strategy, seed, 0.01);
        let max = cfg.scaling.max_instances;
        let sim = run_simulation(cfg);
        for ((m, r), ledger) in &sim.metrics.instances {
            for &(_, count) in &ledger.points {
                assert!(
                    count <= max,
                    "{} {m} {r}: count {count} above max {max}",
                    strategy.name()
                );
            }
        }
    });
}

#[test]
fn determinism_full_stack() {
    for seed in [1u64, 7, 13] {
        let a = run_simulation(quick(Strategy::LtUa, seed, 0.006));
        let b = run_simulation(quick(Strategy::LtUa, seed, 0.006));
        // Full streaming-state equality: every accumulator cell,
        // histogram bucket, ledger point and util bin.
        assert!(a.metrics == b.metrics, "seed {seed}: replay diverged");
        assert!(a.metrics.completed > 0, "seed {seed}");
    }
}

#[test]
fn niw_meets_deadlines_even_when_queued() {
    let sim = run_simulation(quick(Strategy::LtU, 3, 0.006));
    let niw = sim.metrics.latency_by_tier(Tier::Niw);
    assert!(niw.count > 0);
    let met = 1.0 - niw.sla_violation_rate;
    assert!(met > 0.95, "NIW deadline hit-rate {met}");
}

#[test]
fn scheduler_policies_all_run_clean() {
    for policy in [SchedPolicy::Fcfs, SchedPolicy::Edf, SchedPolicy::Pf, SchedPolicy::dpa_default()] {
        let mut cfg = quick(Strategy::LtUa, 11, 0.006);
        cfg.sched_policy = policy;
        let sim = run_simulation(cfg);
        assert!(sim.metrics.dropped == 0);
        assert!(sim.metrics.completed > 0);
    }
}

#[test]
fn replayed_trace_matches_generated_run() {
    // Write the generator's trace to CSV, replay it through the engine,
    // and require identical outcomes to the generated run — proving the
    // published-trace path is lossless.  Exact mode: the comparison
    // needs the raw per-request log (the fidelity path the mode exists
    // for).
    let exact = |seed| {
        let mut cfg = quick(Strategy::LtUa, seed, 0.006);
        cfg.metrics.mode = MetricsMode::Exact;
        cfg
    };
    let cfg = exact(5);
    let generated = run_simulation(exact(5));

    let path = sageserve::trace::io::temp_path("replay");
    let gen = TraceGenerator::new(cfg.trace.clone());
    sageserve::trace::io::write_csv(&path, gen.stream()).unwrap();
    let mut replay_cfg = exact(5);
    replay_cfg.replay_trace = Some(path.clone());
    let replayed = run_simulation(replay_cfg);
    std::fs::remove_file(&path).ok();

    assert_eq!(generated.metrics.outcomes.len(), replayed.metrics.outcomes.len());
    let sum = |sim: &sageserve::sim::engine::Simulation| -> f64 {
        sim.metrics.outcomes.iter().map(|o| o.e2e).sum()
    };
    // CSV stores arrivals at µs precision; latencies match to that noise.
    let (a, b) = (sum(&generated), sum(&replayed));
    assert!((a - b).abs() / a.max(1.0) < 1e-3, "generated {a} vs replayed {b}");
}

#[test]
fn unified_beats_siloed_on_instance_hours() {
    // The §4 motivating claim, at small scale: same trace, same thresholds,
    // unified pool uses no more instance-hours than siloed.
    let mk = |strategy| {
        let mut cfg = quick(strategy, 21, 0.02);
        cfg.trace.days = 0.25;
        cfg.initial_instances = 10;
        let sim = run_simulation(cfg);
        let end = sim.end_time();
        let total: f64 = sim
            .metrics
            .instances
            .values()
            .map(|l| l.instance_hours(end))
            .sum();
        (total, sim.metrics.completed)
    };
    let (siloed, n1) = mk(Strategy::Siloed);
    let (unified, n2) = mk(Strategy::Reactive);
    assert_eq!(n1, n2);
    assert!(
        unified <= siloed * 1.05,
        "unified {unified:.1} should not exceed siloed {siloed:.1}"
    );
}
