//! Property tests on the §5 capacity optimizer: every returned plan must
//! satisfy the formulation's constraints exactly, across many random
//! instances (in-tree proptest harness).

use sageserve::opt::capacity::{optimize_capacity, synthetic_inputs, CapacityInputs};
use sageserve::util::proptest::run_cases;

fn check_plan_feasible(inp: &CapacityInputs, deltas: &[Vec<i64>]) {
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    let x = |j: usize, k: usize| inp.current[j][k] + deltas[j][k] as f64;

    // Bounds.
    for j in 0..r {
        for k in 0..g {
            assert!(x(j, k) >= inp.min_instances - 1e-9, "min bound at ({j},{k})");
            assert!(x(j, k) <= inp.max_instances + 1e-9, "max bound at ({j},{k})");
            assert!(deltas[j][k] as f64 >= -inp.current[j][k] - 1e-9, "δ ≥ -n");
        }
    }
    // Local floor: Σ_k x θ_k ≥ ε · max_w ρ_j(w).
    for j in 0..r {
        let cap: f64 = (0..g).map(|k| x(j, k) * inp.tps_per_instance[k]).sum();
        let peak = inp.forecast_tps[j].iter().copied().fold(0.0, f64::max);
        assert!(
            cap + 1e-6 >= inp.epsilon * peak,
            "local floor at region {j}: cap {cap} < ε·peak {}",
            inp.epsilon * peak
        );
    }
    // Global cover: Σ_jk x θ_k ≥ max_w Σ_j ρ_j(w).
    let windows = inp.forecast_tps[0].len();
    let mut global_peak = 0.0f64;
    for w in 0..windows {
        global_peak = global_peak.max((0..r).map(|j| inp.forecast_tps[j][w]).sum());
    }
    let total: f64 =
        (0..r).flat_map(|j| (0..g).map(move |k| (j, k))).map(|(j, k)| x(j, k) * inp.tps_per_instance[k]).sum();
    assert!(total + 1e-6 >= global_peak, "global cover: {total} < {global_peak}");
}

#[test]
fn plans_satisfy_all_constraints() {
    run_cases(0xCAFE, 40, |rng, _| {
        let regions = 2 + (rng.next_u64() % 4) as usize;
        let gpus = 1 + (rng.next_u64() % 2) as usize;
        let inp = synthetic_inputs(regions, gpus, rng.next_u64());
        if let Some(plan) = optimize_capacity(&inp) {
            check_plan_feasible(&inp, &plan.deltas);
        }
    });
}

#[test]
fn plans_are_deterministic() {
    for seed in [3u64, 17, 99] {
        let inp = synthetic_inputs(3, 1, seed);
        let a = optimize_capacity(&inp).unwrap();
        let b = optimize_capacity(&inp).unwrap();
        assert_eq!(a.deltas, b.deltas, "seed {seed}");
    }
}

#[test]
fn near_optimality_vs_exhaustive_small() {
    // 1 region × 1 GPU: brute-force the integer optimum and compare.
    run_cases(0xBEEF, 25, |rng, _| {
        let theta = 100.0 + rng.range(0.0, 400.0);
        let current = (2.0 + rng.range(0.0, 8.0)).floor();
        let peak = rng.range(0.0, 6000.0);
        let inp = CapacityInputs {
            current: vec![vec![current]],
            tps_per_instance: vec![theta],
            forecast_tps: vec![vec![peak]],
            vm_cost: vec![98.0],
            start_cost: vec![16.0],
            epsilon: 0.6,
            min_instances: 2.0,
            max_instances: 20.0,
        };
        let Some(plan) = optimize_capacity(&inp) else {
            // Infeasible ⇒ demand beyond max capacity.
            assert!(peak > 20.0 * theta);
            return;
        };
        // Brute force over x in [2, 20].
        let mut best = f64::INFINITY;
        for x in 2..=20i64 {
            let xf = x as f64;
            if xf * theta + 1e-9 < 0.6 * peak || xf * theta + 1e-9 < peak {
                continue;
            }
            let delta = xf - current;
            let obj = 98.0 * delta + 16.0 * delta.max(0.0);
            best = best.min(obj);
        }
        assert!(
            plan.objective <= best + best.abs() * 2e-4 + 1e-6,
            "objective {} vs brute-force {best}",
            plan.objective
        );
    });
}
