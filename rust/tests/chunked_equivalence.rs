//! The chunked-execution headline invariant (ISSUE 6 / ROADMAP item 1):
//! epoch-sliced execution is **bit-identical** to the sequential engine
//! — not "statistically close", not "within f64 rounding" — for every
//! strategy, fleet, chunk size and worker count.  `Metrics` equality is
//! full streaming-state equality: every accumulator cell, histogram
//! bucket and ledger point.

use sageserve::config::{FleetSpec, Region};
use sageserve::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use sageserve::sim::engine::{quick_config, run_simulation, SimConfig, Strategy};
use sageserve::sim::faults::{FaultPlan, SpotShock};
use sageserve::trace::generator::TraceGenerator;

/// Multi-day config so chunk boundaries cross diurnal peaks, control
/// epochs and scale-in/out transitions, not just a quiet tail.
fn multi_day_config(strategy: Strategy, fleet: Option<&FleetSpec>) -> SimConfig {
    let mut cfg = quick_config(strategy, 2.0, 0.002);
    cfg.scaling.max_instances = 8;
    if let Some(f) = fleet {
        cfg.fleet = f.clone();
    }
    cfg
}

#[test]
fn chunked_bit_identical_across_chunk_sizes_strategies_fleets() {
    // The acceptance grid: chunk sizes {1, 3, 24} epochs × strategies
    // {Reactive, LT-UA, Chiron} × {homogeneous H100, mixed 3-way} on a
    // 2-day trace.  Reactive exercises the queue manager, LT-UA the
    // forecast+ILP epochs, Chiron the hierarchical pools; the mixed
    // fleet adds SKU-aware routing and per-SKU ledgers to the state
    // that must survive each handoff.
    let mixed = FleetSpec::mixed_3way();
    let fleets: [Option<&FleetSpec>; 2] = [None, Some(&mixed)];
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        for fleet in fleets {
            let seq = run_simulation(multi_day_config(strategy, fleet));
            assert!(
                seq.metrics.completed > 1000,
                "{}: trace too small to be meaningful",
                strategy.name()
            );
            for chunk_epochs in [1usize, 3, 24] {
                let ch = run_simulation_chunked(
                    multi_day_config(strategy, fleet),
                    &ChunkedOptions { chunk_epochs, workers: 2 },
                );
                assert!(
                    seq.metrics == ch.metrics,
                    "{} / {} / {} epoch(s) per chunk: chunked diverged from sequential",
                    strategy.name(),
                    if fleet.is_some() { "mixed3" } else { "h100" },
                    chunk_epochs
                );
            }
        }
    }
}

#[test]
fn chunked_invariant_to_worker_count() {
    // The worker count only decides which thread generates a chunk;
    // results must not depend on it (counter-seeded generation + ordered
    // consumption).
    let mk = || {
        let mut cfg = quick_config(Strategy::LtUa, 1.0, 0.003);
        cfg.scaling.max_instances = 8;
        cfg
    };
    let seq = run_simulation(mk());
    for workers in [1usize, 2, 8] {
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs: 2, workers });
        assert!(seq.metrics == ch.metrics, "{workers} workers diverged");
    }
}

#[test]
fn chunked_shared_buffer_source_matches_generator_pipeline() {
    // Both chunk sources — sliced pre-materialized buffer and pipelined
    // generation — must agree with each other (and hence with the
    // sequential engine, by the tests above).
    let mk = || {
        let mut cfg = quick_config(Strategy::LtUa, 1.0, 0.003);
        cfg.scaling.max_instances = 8;
        cfg
    };
    let piped = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs: 3, workers: 2 });
    let mut cfg = mk();
    cfg.shared_trace = Some(TraceGenerator::new(cfg.trace.clone()).materialize_shared());
    let sliced = run_simulation_chunked(cfg, &ChunkedOptions { chunk_epochs: 3, workers: 2 });
    assert!(piped.metrics == sliced.metrics);
}

#[test]
fn chunked_bit_identical_with_active_fault_schedule() {
    // Fault plane × chunked execution: kills, retry backoff events, shed
    // NIW, recovery provisioning and the counter-seeded crash-tick RNG
    // must all ride the `SimHandoff`.  The schedule stacks a region
    // outage mid-trace, a market-wide spot shock at day 1 and a
    // continuous VM-crash hazard; Reactive exercises the queue-manager
    // shed path, LT-UA the forecast epochs re-provisioning around the
    // dark region.
    let plan = || {
        let mut p =
            FaultPlan::region_dark(Region::EastUs, 0.5 * 86_400.0, 0.7 * 86_400.0);
        p.spot_shocks.push(SpotShock { at: 86_400.0, frac: 0.5 });
        p.crash_rate_per_day = 1.0;
        p
    };
    for strategy in [Strategy::Reactive, Strategy::LtUa] {
        let mk = || {
            let mut cfg = multi_day_config(strategy, None);
            cfg.faults = plan();
            cfg
        };
        let seq = run_simulation(mk());
        assert!(
            seq.metrics.failures.killed_total() > 0,
            "{}: the fault schedule never fired — the test is vacuous",
            strategy.name()
        );
        for (chunk_epochs, workers) in [(1usize, 1usize), (1, 8), (24, 1), (24, 8)] {
            let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs, workers });
            assert!(
                seq.metrics == ch.metrics,
                "{} / {chunk_epochs} epoch(s) × {workers} worker(s): chunked \
                 diverged from sequential with faults active",
                strategy.name()
            );
        }
    }
}

#[test]
fn drain_phase_niw_stragglers_identical() {
    // The trickiest boundary: the end-of-trace drain.  Pin every NIW
    // request in the queue manager until the trace ends — release
    // thresholds at 0 mean capacity signals never fire, and an aging
    // threshold far past the trace length means the QmTick scan never
    // pops them — so the whole NIW population goes through `drain_all`
    // plus the post-trace event flush, under both executors.
    let mk = || {
        let mut cfg = quick_config(Strategy::Reactive, 0.3, 0.004);
        cfg.scaling.max_instances = 8;
        cfg.scaling.niw_release_util_1 = 0.0;
        cfg.scaling.niw_release_util_2 = 0.0;
        cfg.scaling.niw_aging_secs = 100.0 * 86_400.0;
        cfg
    };
    let seq = run_simulation(mk());
    assert!(seq.qm.total_enqueued > 0, "no NIW flowed through the QM");
    assert_eq!(
        seq.qm.total_enqueued, seq.qm.total_released,
        "stragglers must leave via drain_all, not be lost"
    );
    let total = TraceGenerator::new(mk().trace.clone()).stream().count();
    assert_eq!(
        seq.metrics.completed as usize + seq.metrics.dropped as usize,
        total,
        "drained stragglers must still complete or drop explicitly"
    );
    for chunk_epochs in [1usize, 5] {
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs, workers: 2 });
        assert_eq!(ch.qm.total_enqueued, seq.qm.total_enqueued);
        assert!(
            seq.metrics == ch.metrics,
            "drain-phase stragglers diverged at {chunk_epochs} epoch(s) per chunk"
        );
    }
}
