//! Bench: the hourly forecast path — native seasonal-AR vs the
//! AOT/PJRT-compiled Layer-2 graph (with the Layer-1 Pallas kernel), plus
//! the full controller epoch (forecast + per-model ILP).
//!
//! Paper reference: ~0.7 s ARIMA + ~1.5 s ILP per hourly decision.

use std::collections::BTreeMap;

use sageserve::config::{GpuKind, ModelKind, Region, ScalingParams, Tier};
use sageserve::coordinator::controller::{run_epoch, SolverStates, Telemetry};
use sageserve::forecast::{Forecaster, NativeArForecaster, PjrtForecaster};
use sageserve::perf::PerfTable;
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::bench::{bench, quick_iters};

fn history(models: &[ModelKind]) -> Vec<Vec<f64>> {
    let gen = TraceGenerator::new(TraceConfig { days: 7.0, scale: 0.2, ..Default::default() });
    let mut out = Vec::new();
    for &m in models {
        for r in Region::ALL {
            out.push(
                (0..672)
                    .map(|b| {
                        let t = (b as f64 + 0.5) * 900.0;
                        gen.rate(m, r, Tier::IwF, t)
                            * TraceGenerator::mean_tokens_exact(m, Tier::IwF)
                    })
                    .collect(),
            );
        }
    }
    out
}

fn main() {
    println!("forecast + controller epoch (12 series = 4 models x 3 regions)\n");
    let models = ModelKind::EVAL4;
    let hist = history(&models);

    let mut native = NativeArForecaster::new(96, 8, 4);
    bench("native seasonal-AR forecast (12 series)", quick_iters(2_000, 20), || native.forecast(&hist));

    match PjrtForecaster::load("artifacts") {
        Ok(mut pjrt) => {
            bench("PJRT seasonal-AR forecast (AOT artifact)", quick_iters(200, 5), || pjrt.forecast(&hist));
        }
        Err(_) => println!("(skip PJRT forecast bench: run `make artifacts`)"),
    }

    // Full control epoch: forecast + 4 per-model capacity ILPs.
    let mut telemetry = Telemetry::new(&models, 900.0);
    let mut warm = BTreeMap::new();
    let mut i = 0;
    for &m in &models {
        for r in Region::ALL {
            warm.insert((m, r), hist[i].clone());
            i += 1;
        }
    }
    telemetry.warmup(&warm);
    let perf = PerfTable::new(GpuKind::H100x8, &models);
    let params = ScalingParams::default();
    // Dense per-SKU counts: one row per telemetry key, GpuKind::index order.
    let n_keys = models.len() * Region::ALL.len();
    let counts = vec![[6usize, 0, 0]; n_keys];
    // Cold epoch: fresh solver state every iteration (first epoch after
    // a controller restart).
    let mut fc_cold = NativeArForecaster::new(96, 8, 4);
    bench("full control epoch, cold solves (forecast + 4 ILPs)", quick_iters(500, 5), || {
        run_epoch(
            &telemetry, &mut fc_cold, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0,
        )
        .len()
    });

    // Steady state: the solver states persist across iterations, so every
    // epoch after the first dual-re-solves from the previous basis.
    let mut fc = NativeArForecaster::new(96, 8, 4);
    let mut solvers = SolverStates::new();
    bench("full control epoch, warm solves (forecast + 4 ILPs)", quick_iters(500, 5), || {
        run_epoch(
            &telemetry, &mut fc, &perf, &[GpuKind::H100x8], &params, &counts, &mut solvers, 0.0,
        )
        .len()
    });

    // The 2-SKU epoch: per-model ILPs now carry a [region][gpu] grid.
    let fleet = [GpuKind::H100x8, GpuKind::A100x8];
    let perf2 = PerfTable::for_fleet(&fleet, &models);
    let counts2 = vec![[3usize, 3, 0]; n_keys];
    let mut fc2 = NativeArForecaster::new(96, 8, 4);
    let mut solvers2 = SolverStates::new();
    bench("full control epoch, 2-SKU fleet (forecast + 4 ILPs)", quick_iters(500, 5), || {
        run_epoch(
            &telemetry, &mut fc2, &perf2, &fleet, &params, &counts2, &mut solvers2, 0.0,
        )
        .len()
    });

    // The 3-SKU epoch (H100 + A100 + MI300): each per-model ILP carries
    // 3 regions x 3 SKUs = 9 integer x-vars plus the u relaxations —
    // the k axis the MI300 class stresses.
    let fleet3 = GpuKind::ALL;
    let perf3 = PerfTable::for_fleet(&fleet3, &models);
    let counts3 = vec![[2usize, 2, 2]; n_keys];
    let mut fc3 = NativeArForecaster::new(96, 8, 4);
    let mut solvers3 = SolverStates::new();
    bench("full control epoch, 3-SKU fleet (forecast + 4 ILPs)", quick_iters(500, 5), || {
        run_epoch(
            &telemetry, &mut fc3, &perf3, &fleet3, &params, &counts3, &mut solvers3, 0.0,
        )
        .len()
    });
    println!("\npaper reference: ~0.7 s forecast + ~1.5 s ILP per hourly epoch");
}
