//! Bench: end-to-end simulator throughput — simulated requests per
//! wall-clock second across strategies (the number that bounds how big an
//! experiment we can replay; the paper's full traces are 10M requests).
//!
//! Emits a machine-readable `BENCH_sim.json` (path override:
//! `SAGESERVE_BENCH_OUT`) so the perf trajectory is comparable across
//! PRs; `SAGESERVE_BENCH_QUICK=1` caps iterations for CI smoke runs.

use std::collections::BTreeMap;

use sageserve::config::{FleetSpec, GpuKind};
use sageserve::metrics::Metrics;
use sageserve::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::bench::{bench, quick_iters, quick_mode};
use sageserve::util::json::Json;

fn main() {
    println!("simulator end-to-end throughput (0.1 day, 4 models, 3 regions)\n");
    let iters = quick_iters(10, 2);
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert(
        "config".into(),
        Json::Str("days=0.1 scale=0.05 models=EVAL4 regions=3".into()),
    );
    // Smoke runs are high-variance (2 iterations): mark them so the
    // cross-PR perf trajectory never mistakes one for a full run.
    report.insert("quick".into(), Json::Bool(quick_mode()));
    report.insert("max_iters".into(), Json::Num(iters as f64));

    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        let cfg = || SimConfig {
            trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
            strategy,
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result = bench(&format!("simulate {} ({n_requests} reqs)", strategy.name()), iters, || {
            run_simulation(cfg()).metrics.completed as usize
        });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert(format!("simulate_{}", strategy.name()), Json::Obj(entry));
    }

    // Mixed H100/A100 fleet: exercises the per-SKU aggregates, the 2-SKU
    // capacity ILP and the cost-ordered scaling paths end-to-end.
    {
        let cfg = || SimConfig {
            trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
            strategy: Strategy::LtUa,
            fleet: FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]),
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result = bench(&format!("simulate lt-ua mixed fleet ({n_requests} reqs)"), iters, || {
            run_simulation(cfg()).metrics.completed as usize
        });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert("simulate_lt-ua_mixed".to_string(), Json::Obj(entry));
    }

    // Three-way H100/A100/MI300 fleet with SKU-aware routing: the k=3
    // capacity ILP, the spot-first reclaim order and the per-request
    // affinity cascade end-to-end.
    {
        let cfg = || SimConfig {
            trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
            strategy: Strategy::LtUa,
            fleet: FleetSpec::mixed_3way(),
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result =
            bench(&format!("simulate lt-ua 3-way fleet ({n_requests} reqs)"), iters, || {
                run_simulation(cfg()).metrics.completed as usize
            });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert("simulate_lt-ua_mixed3".to_string(), Json::Obj(entry));
    }

    // Single-run engine: sequential loop vs the epoch-sliced chunked
    // executor on the identical config.  The chunked path generates on
    // worker threads while simulating (overlap, O(chunk) memory) and
    // does a full suspend/resume handoff every epoch — this pair records
    // what that pipeline wins (or costs) per PR.  Quick mode covers the
    // chunked path too, so CI smoke always exercises the handoff.
    {
        let cfg = || SimConfig {
            trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
            strategy: Strategy::LtUa,
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        for (key, chunked) in
            [("single_run_sequential", false), ("single_run_chunked", true)]
        {
            let label = if chunked {
                format!("single run, chunked 1-epoch ({n_requests} reqs)")
            } else {
                format!("single run, sequential ({n_requests} reqs)")
            };
            let result = bench(&label, iters, || {
                if chunked {
                    run_simulation_chunked(
                        cfg(),
                        &ChunkedOptions { chunk_epochs: 1, workers: 0 },
                    )
                    .metrics
                    .completed as usize
                } else {
                    run_simulation(cfg()).metrics.completed as usize
                }
            });
            let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
            println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
            let mut entry = BTreeMap::new();
            entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
            entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
            entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
            entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
            report.insert(key.to_string(), Json::Obj(entry));
        }
    }

    // Fault plane: the identical LT-UA single run with an active fault
    // schedule — a region outage spanning the middle of the trace, a
    // market spot shock and a continuous crash hazard.  Compared against
    // `single_run_sequential` this records what the kill/retry/
    // re-provision machinery costs; with no faults firing the plan
    // compiles to zero events and the engine is bit-identical, so the
    // overhead measured here is the *active* fault path only.
    {
        use sageserve::config::Region;
        use sageserve::sim::faults::{FaultPlan, SpotShock};
        let span = 0.1 * 86_400.0;
        let cfg = || {
            let mut plan = FaultPlan::region_dark(Region::EastUs, span * 0.3, span * 0.5);
            plan.spot_shocks.push(SpotShock { at: span * 0.7, frac: 0.5 });
            plan.crash_rate_per_day = 2.0;
            SimConfig {
                trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
                strategy: Strategy::LtUa,
                faults: plan,
                ..Default::default()
            }
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result =
            bench(&format!("fault injection epoch ({n_requests} reqs)"), iters, || {
                run_simulation(cfg()).metrics.completed as usize
            });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert("fault_injection_epoch".to_string(), Json::Obj(entry));
    }

    // Control-plane guardrails: the identical LT-UA single run with the
    // guarded controller armed and a control-fault schedule that walks
    // the full cascade — a forecast blackout over the middle of the
    // trace plus an actuation-delay window.  Compared against
    // `single_run_sequential` this records what the watchdog + residual
    // tracker + fallback machinery costs per epoch; with the guardrails
    // off and an empty plan the engine is bit-identical
    // (`tests/guardrail_equivalence.rs`), so only the armed path can
    // ever move.
    {
        use sageserve::config::GuardrailParams;
        use sageserve::sim::faults::ControlFaultPlan;
        let span = 0.1 * 86_400.0;
        let cfg = || {
            let mut plan = ControlFaultPlan::forecast_blackout(span * 0.3, span * 0.7);
            plan.actuation_delays.push(sageserve::sim::faults::ActuationDelay {
                start: span * 0.5,
                end: span * 0.9,
                extra: 60.0,
            });
            SimConfig {
                trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
                strategy: Strategy::LtUa,
                control_faults: plan,
                guardrails: GuardrailParams::enabled(),
                ..Default::default()
            }
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result = bench(&format!("guardrail epoch ({n_requests} reqs)"), iters, || {
            run_simulation(cfg()).metrics.completed as usize
        });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert("guardrail_epoch".to_string(), Json::Obj(entry));
    }

    // Disaggregated week: LT-UA with prefill/decode pools, the
    // KV-transfer handoff and the paired per-phase capacity solves on a
    // multi-day trace (1 day in quick mode).  Compared against the
    // unified `simulate_lt-ua` entries this records the disaggregation
    // machinery's simulation-throughput cost; a disabled `disagg` gate
    // is bit-identical by `tests/disagg_equivalence.rs`, so only the
    // enabled path can ever move.
    {
        use sageserve::config::DisaggParams;
        let days = if quick_mode() { 1.0 } else { 7.0 };
        let cfg = || SimConfig {
            trace: TraceConfig { days, scale: 0.05, ..Default::default() },
            strategy: Strategy::LtUa,
            disagg: DisaggParams::enabled(),
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result =
            bench(&format!("simulate disagg week, {days} day(s) ({n_requests} reqs)"), iters, || {
                run_simulation(cfg()).metrics.completed as usize
            });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n_requests as f64));
        entry.insert("days".to_string(), Json::Num(days));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(result.p50_ns));
        entry.insert("reqs_per_wall_sec".to_string(), Json::Num(reqs_per_sec));
        report.insert("simulate_disagg_week".to_string(), Json::Obj(entry));
    }

    // Metrics recording alone (the completion hot path): per-request
    // cost of the streaming accumulators — two histogram bucketings plus
    // O(1) cell updates, no outcome-log growth.
    {
        let cfg = TraceConfig { days: 0.1, scale: 0.05, ..Default::default() };
        let reqs = TraceGenerator::new(cfg).materialize();
        let n = reqs.len();
        let result = bench(&format!("metrics record, streaming ({n} reqs)"), iters, || {
            let mut m = Metrics::default();
            for r in &reqs {
                // Synthetic latencies spanning the histogram range.
                let ttft = 0.05 + (r.id % 97) as f64 * 0.01;
                let e2e = ttft + 0.02 * r.output_tokens as f64;
                m.record_outcome(r, r.origin, ttft, e2e);
            }
            m.completed as usize
        });
        let ns_per = result.mean_ns / n as f64;
        println!("    → {ns_per:.1} ns / completion\n");
        let mut entry = BTreeMap::new();
        entry.insert("n_requests".to_string(), Json::Num(n as f64));
        entry.insert("mean_ns".to_string(), Json::Num(result.mean_ns));
        entry.insert("ns_per_record".to_string(), Json::Num(ns_per));
        report.insert("metrics_record".to_string(), Json::Obj(entry));
    }

    // Trace generation alone (the simulator's input pipeline).  The
    // headline `trace_generation` entry is the production path — the
    // chunk-parallel materializer sweep grids replay from;
    // `trace_generation_stream` times the same counter-seeded pipeline
    // through the sequential minute-bucketed iterator (single-run
    // engine path; also what a one-worker materialize costs).
    let cfg = TraceConfig { days: 0.1, scale: 0.05, ..Default::default() };
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let n = TraceGenerator::new(cfg.clone()).materialize().len();
    let r = bench(
        &format!("trace generation, materialize x{workers} ({n} reqs)"),
        iters,
        || TraceGenerator::new(cfg.clone()).materialize().len(),
    );
    let gen_rps = n as f64 / (r.mean_ns / 1e9);
    println!("    → {:.2} M generated requests / wall-second\n", gen_rps / 1e6);
    let mut entry = BTreeMap::new();
    entry.insert("n_requests".to_string(), Json::Num(n as f64));
    entry.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
    entry.insert("reqs_per_wall_sec".to_string(), Json::Num(gen_rps));
    entry.insert("workers".to_string(), Json::Num(workers as f64));
    report.insert("trace_generation".to_string(), Json::Obj(entry));

    let r = bench(&format!("trace generation, sequential stream ({n} reqs)"), iters, || {
        TraceGenerator::new(cfg.clone()).stream().count()
    });
    let stream_rps = n as f64 / (r.mean_ns / 1e9);
    println!("    → {:.2} M generated requests / wall-second", stream_rps / 1e6);
    let mut entry = BTreeMap::new();
    entry.insert("n_requests".to_string(), Json::Num(n as f64));
    entry.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
    entry.insert("reqs_per_wall_sec".to_string(), Json::Num(stream_rps));
    report.insert("trace_generation_stream".to_string(), Json::Obj(entry));

    // Default to the tracked repo-root record regardless of cwd (cargo
    // runs benches from the package root, which would otherwise leave a
    // stray rust/BENCH_sim.json while the tracked file goes stale).
    let out = std::env::var("SAGESERVE_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json").into());
    match std::fs::write(&out, Json::Obj(report).to_string()) {
        Ok(()) => println!("\n  wrote {out}"),
        Err(e) => eprintln!("\n  could not write {out}: {e}"),
    }
}
