//! Bench: end-to-end simulator throughput — simulated requests per
//! wall-clock second across strategies (the number that bounds how big an
//! experiment we can replay; the paper's full traces are 10M requests).

use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::bench::bench;

fn main() {
    println!("simulator end-to-end throughput (0.1 day, 4 models, 3 regions)\n");
    for strategy in [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron] {
        let cfg = || SimConfig {
            trace: TraceConfig { days: 0.1, scale: 0.05, ..Default::default() },
            strategy,
            ..Default::default()
        };
        let n_requests = TraceGenerator::new(cfg().trace.clone()).stream().count();
        let result = bench(&format!("simulate {} ({n_requests} reqs)", strategy.name()), 10, || {
            run_simulation(cfg()).metrics.outcomes.len()
        });
        let reqs_per_sec = n_requests as f64 / (result.mean_ns / 1e9);
        println!("    → {:.2} M simulated requests / wall-second\n", reqs_per_sec / 1e6);
    }

    // Trace generation alone (the simulator's input pipeline).
    let cfg = TraceConfig { days: 0.1, scale: 0.05, ..Default::default() };
    let n = TraceGenerator::new(cfg.clone()).stream().count();
    let r = bench(&format!("trace generation ({n} reqs)"), 10, || {
        TraceGenerator::new(cfg.clone()).stream().count()
    });
    println!(
        "    → {:.2} M generated requests / wall-second",
        n as f64 / (r.mean_ns / 1e9) / 1e6
    );
}
