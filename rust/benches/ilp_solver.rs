//! Bench: §5 ILP solver runtime (paper: 1.41 s at l=4,r=3,g=1; 33 s at
//! l=20,r=20,g=5 with a commercial solver).  Our exact B&B with per-model
//! decomposition should beat both by orders of magnitude.

use sageserve::opt::capacity::{optimize_capacity, synthetic_inputs};
use sageserve::util::bench::{bench, quick_iters};

fn main() {
    println!("ILP capacity solver (per-model decomposition; exact B&B)\n");
    for (l, r, g) in [(4usize, 3usize, 1usize), (8, 6, 2), (20, 20, 5)] {
        bench(&format!("ilp l={l} r={r} g={g} (all {l} models)"), quick_iters(50, 3), || {
            let mut total_delta = 0i64;
            for model in 0..l {
                let inp = synthetic_inputs(r, g, model as u64 * 7919 + 1);
                if let Some(plan) = optimize_capacity(&inp) {
                    total_delta += plan.deltas.iter().flatten().sum::<i64>();
                }
            }
            total_delta
        });
    }
    println!("\npaper reference: 1.41 s (4,3,1) / 33 s (20,20,5)");
}
