//! Bench: §5 ILP solver runtime (paper: 1.41 s at l=4,r=3,g=1; 33 s at
//! l=20,r=20,g=5 with a commercial solver).  Two modes per size:
//!
//! * `cold` — bounded-variable B&B from an empty `CapacitySolver` (first
//!   epoch after a controller restart);
//! * `warm` — the steady state: demand drifted 2%, re-solved through the
//!   retained state (rhs swap + dual re-solve from the previous basis).
//!
//! The warm/cold ratio is the headline of the bounded-solver rewrite —
//! see `cargo run --release -- exp ilp` for the table with the old dense
//! path alongside.

use sageserve::opt::capacity::{
    optimize_capacity_warm, perturb_inputs, synthetic_inputs, CapacitySolver,
};
use sageserve::util::bench::{bench, quick_iters};

fn main() {
    println!("ILP capacity solver (per-model decomposition; bounded-variable B&B)\n");
    for (l, r, g) in [(4usize, 3usize, 1usize), (8, 6, 2), (20, 20, 5), (20, 20, 10)] {
        bench(&format!("ilp_cold l={l} r={r} g={g} (all {l} models)"), quick_iters(50, 3), || {
            let mut total_delta = 0i64;
            for model in 0..l {
                let inp = synthetic_inputs(r, g, model as u64 * 7919 + 1);
                if let Some(plan) = optimize_capacity_warm(&inp, &mut CapacitySolver::new()) {
                    total_delta += plan.deltas.iter().flatten().sum::<i64>();
                }
            }
            total_delta
        });

        // Warm steady state: build each model's state once outside the
        // timed region, then measure the epoch-over-epoch re-solve.
        let mut solvers: Vec<CapacitySolver> = (0..l).map(|_| CapacitySolver::new()).collect();
        let epochs: Vec<_> = (0..l)
            .filter_map(|model| {
                let inp = synthetic_inputs(r, g, model as u64 * 7919 + 1);
                let plan = optimize_capacity_warm(&inp, &mut solvers[model])?;
                Some((model, perturb_inputs(&inp, &plan, 0.02)))
            })
            .collect();
        bench(&format!("ilp_warm l={l} r={r} g={g} (all {l} models)"), quick_iters(50, 3), || {
            let mut total_delta = 0i64;
            for (model, next) in &epochs {
                if let Some(plan) = optimize_capacity_warm(next, &mut solvers[*model]) {
                    total_delta += plan.deltas.iter().flatten().sum::<i64>();
                }
            }
            total_delta
        });
    }

    // Disaggregated control epoch: every model solves *two* capacity
    // columns per epoch (prefill sized by TTFT, decode by ITL), each
    // with its own warm-start state — warm bases never cross phases
    // because the θ columns differ.  This is the steady-state cost the
    // controller pays when `--disagg` is on; compare against `ilp_warm`
    // at the same size for the per-epoch overhead of the second column.
    {
        let (l, r, g) = (20usize, 20usize, 5usize);
        let mut solvers: Vec<[CapacitySolver; 2]> =
            (0..l).map(|_| [CapacitySolver::new(), CapacitySolver::new()]).collect();
        let epochs: Vec<_> = (0..l)
            .filter_map(|model| {
                // Distinct seeds per phase stand in for the distinct
                // per-phase θ columns of the real controller.
                let pre = synthetic_inputs(r, g, model as u64 * 7919 + 1);
                let dec = synthetic_inputs(r, g, model as u64 * 7919 + 4001);
                let pre_plan = optimize_capacity_warm(&pre, &mut solvers[model][0])?;
                let dec_plan = optimize_capacity_warm(&dec, &mut solvers[model][1])?;
                Some((
                    model,
                    perturb_inputs(&pre, &pre_plan, 0.02),
                    perturb_inputs(&dec, &dec_plan, 0.02),
                ))
            })
            .collect();
        bench(
            &format!("ilp_disagg l={l} r={r} g={g} (prefill+decode columns, all {l} models)"),
            quick_iters(50, 3),
            || {
                let mut total_delta = 0i64;
                for (model, pre, dec) in &epochs {
                    for (phase, next) in [(0usize, pre), (1, dec)] {
                        if let Some(plan) =
                            optimize_capacity_warm(next, &mut solvers[*model][phase])
                        {
                            total_delta += plan.deltas.iter().flatten().sum::<i64>();
                        }
                    }
                }
                total_delta
            },
        );
    }

    println!("\npaper reference: 1.41 s (4,3,1) / 33 s (20,20,5)");
}
