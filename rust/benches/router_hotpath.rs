//! Bench: the routing hot path — per-request region selection + JSQ
//! instance pick + scheduler ordering.  L3 must never be the bottleneck
//! (DESIGN.md §Perf target: « 1 µs per decision).

use sageserve::config::{GpuKind, ModelKind, Region, RoutingParams, ScalingParams, Tier};
use sageserve::coordinator::router::{route_instance, route_region};
use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::perf::PerfTable;
use sageserve::sim::cluster::{Cluster, PoolTag};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::bench::bench;

fn main() {
    println!("router + scheduler hot path\n");
    let models = ModelKind::EVAL4;
    let cluster = Cluster::new(
        &models,
        PerfTable::new(GpuKind::H100x8, &models),
        ScalingParams::default(),
        &[(PoolTag::Unified, 20)],
        40,
    );
    let routing = RoutingParams::default();

    bench("route_region (3 regions, util scan)", 2_000_000, || {
        route_region(&cluster, &routing, ModelKind::Llama2_70B, Region::CentralUs)
    });

    bench("route_instance (JSQ over 20 instances)", 2_000_000, || {
        route_instance(&cluster, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF)
    });

    // Scheduler ordering on realistic queue depths.
    let gen = TraceGenerator::new(TraceConfig { days: 0.01, scale: 0.05, ..Default::default() });
    let queue: Vec<_> = gen.stream().take(64).collect();
    for (name, policy) in [
        ("fcfs", SchedPolicy::Fcfs),
        ("edf", SchedPolicy::Edf),
        ("pf", SchedPolicy::Pf),
        ("dpa", SchedPolicy::dpa_default()),
    ] {
        let q = queue.clone();
        bench(&format!("scheduler order {} (64-deep queue)", name), 500_000, move || {
            let mut q2 = q.clone();
            policy.order(&mut q2, 100.0);
            q2.len()
        });
    }
}
