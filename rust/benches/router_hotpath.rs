//! Bench: the routing hot path — per-request region selection + JSQ
//! instance pick + scheduler ordering, plus the O(1) aggregate reads
//! (effective utilization, waiting-aware utilization, pending tokens)
//! that back them.  L3 must never be the bottleneck (DESIGN.md §Perf
//! target: « 1 µs per decision).

use sageserve::config::{FleetSpec, GpuKind, ModelKind, Region, RoutingParams, ScalingParams, Tier};
use sageserve::coordinator::router::{
    route_instance, route_instance_sku_aware, route_region, route_region_sku_aware,
};
use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::perf::PerfTable;
use sageserve::sim::cluster::{Cluster, PoolTag};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::util::bench::{bench, quick_iters};

fn main() {
    println!("router + scheduler hot path\n");
    let models = ModelKind::EVAL4;
    let cluster = Cluster::new(
        &models,
        PerfTable::new(GpuKind::H100x8, &models),
        ScalingParams::default(),
        &[(PoolTag::Unified, 20)],
        40,
    );
    let routing = RoutingParams::default();
    let hot = quick_iters(2_000_000, 50_000);

    bench("route_region (3 regions, O(1) agg reads)", hot, || {
        route_region(&cluster, &routing, ModelKind::Llama2_70B, Region::CentralUs)
    });

    bench("route_instance (JSQ over 20 instances)", hot, || {
        route_instance(&cluster, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF)
    });

    // SKU-aware variants on a three-way fleet: the affinity cascade
    // must stay in the same sub-µs class as blind JSQ.
    let mixed3 = Cluster::new_fleet(
        &models,
        PerfTable::for_fleet(&GpuKind::ALL, &models),
        ScalingParams::default(),
        &[(PoolTag::Unified, 21)],
        40,
        &FleetSpec::mixed_3way(),
    );
    bench("route_region_sku_aware (long-context, 3-way fleet)", hot, || {
        route_region_sku_aware(
            &mixed3, &routing, ModelKind::Llama2_70B, Region::CentralUs, 50_000,
        )
    });
    bench("route_instance_sku_aware (cascade over 21 instances)", hot, || {
        route_instance_sku_aware(
            &mixed3, &routing, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, 50_000,
        )
    });

    // The aggregate reads the engine hits on every routing decision,
    // NIW-release iteration and utilization sample.
    bench("effective_util (incremental)", hot, || {
        cluster.effective_util(ModelKind::Llama2_70B, Region::EastUs)
    });
    bench("effective_util_with_waiting (incremental)", hot, || {
        cluster.effective_util_with_waiting(ModelKind::Llama2_70B, Region::EastUs)
    });
    bench("pending_tokens (incremental)", hot, || {
        cluster.pending_tokens(ModelKind::Llama2_70B, Region::EastUs)
    });
    bench("is_all_idle (busy counter)", hot, || cluster.is_all_idle());

    // Scheduler ordering on realistic queue depths.
    let gen = TraceGenerator::new(TraceConfig { days: 0.01, scale: 0.05, ..Default::default() });
    let queue: Vec<_> = gen.stream().take(64).collect();
    let sched_iters = quick_iters(500_000, 20_000);
    for (name, policy) in [
        ("fcfs", SchedPolicy::Fcfs),
        ("edf", SchedPolicy::Edf),
        ("pf", SchedPolicy::Pf),
        ("dpa", SchedPolicy::dpa_default()),
    ] {
        let q = queue.clone();
        bench(&format!("scheduler order {} (64-deep queue)", name), sched_iters, move || {
            let mut q2 = q.clone();
            policy.order(&mut q2, 100.0);
            q2.len()
        });
    }
}
