//! Paper-calibrated synthetic workload generator (substitute for the
//! proprietary O365 traces — DESIGN.md §1).
//!
//! Calibration targets, all from §3 of the paper:
//! * Jul-2025: ≈10 M requests/day at `scale = 1.0`, tier mix IW-F 45% /
//!   IW-N 27% / NIW 28% (IW together 72%).
//! * Nov-2024: ≈1/5 the Jul-2025 volume, IW:NIW = 3:1, no IW-F/IW-N split
//!   (all interactive traffic is emitted as IW-N).
//! * IW tiers: strong diurnal periodicity (early-afternoon US peak),
//!   weekends quiescing; IW-N additionally grows through the week for
//!   Model B (Wed/Thu/Fri > Mon/Tue).
//! * NIW: aperiodic, stable through the week, negligible in West US.
//! * Region amplitudes E > C > W; Bloom (Model A) 4× East-vs-West for
//!   IW-F; Llama-2 (Model B) peaks in Central (IW-F) and West (IW-N).
//! * Token counts: log-normal; most inputs > 1 k, most outputs < 1 k
//!   (Fig 10); the eval-framework app on Model C in Central US NIW issues
//!   bulk requests with much higher TPS/request.
//! * Random 5–15 min bursts (~2/day per region) at 2–4× base rate;
//!   1-minute-scale arrival noise comes free from Poisson sampling.

use crate::util::rng::Rng;

use crate::config::{Epoch, ModelKind, Region, Tier, Time, DAY, HOUR, MINUTE};
use crate::trace::types::{AppKind, Request};

/// Generator parameters.  `..Default::default()` reproduces the Jul-2025
/// evaluation setup with the four open-source models.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub epoch: Epoch,
    pub models: Vec<ModelKind>,
    /// Trace length in days.
    pub days: f64,
    /// Linear volume multiplier.  1.0 ≈ 10 M req/day (Jul-2025).
    /// Experiments default to smaller scales for runtime; the shape is
    /// scale-invariant.
    pub scale: f64,
    pub seed: u64,
    /// Day-of-week of t=0 (0 = Monday).
    pub start_weekday: usize,
    /// Inject random traffic bursts (disable for forecast-friendly runs).
    pub bursts: bool,
    /// Multiply the burst amplitude (Fig 16a uses 8× synthetic spikes).
    pub burst_amplitude: f64,
    /// Burst duration range in minutes (default 5–15; Fig 16a stretches
    /// bursts so they overlap LT-UA's end-of-hour correction window).
    pub burst_minutes: (f64, f64),
    /// Override the IW:NIW request-count ratio, e.g. `Some(9.0)` for the
    /// 9:1 ablation of §7.2.8.  `None` keeps the epoch default.
    pub iw_niw_ratio: Option<f64>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            epoch: Epoch::Jul2025,
            models: ModelKind::EVAL4.to_vec(),
            days: 1.0,
            scale: 1.0,
            seed: 42,
            start_weekday: 0,
            bursts: true,
            burst_amplitude: 1.0,
            burst_minutes: (5.0, 15.0),
            iw_niw_ratio: None,
        }
    }
}

/// Total mean requests/second across everything, before shape factors.
fn epoch_base_rps(epoch: Epoch) -> f64 {
    match epoch {
        Epoch::Jul2025 => 10.0e6 / DAY, // ≈115.7 RPS (≈10M/day)
        Epoch::Nov2024 => 2.0e6 / DAY,  // 5× smaller, 7 months earlier
    }
}

/// Tier shares of the total request count.
fn tier_share(epoch: Epoch, tier: Tier, iw_niw_ratio: Option<f64>) -> f64 {
    // Default splits; see module docs.
    let (iwf, iwn, niw) = match epoch {
        Epoch::Jul2025 => (0.45, 0.27, 0.28),
        Epoch::Nov2024 => (0.0, 0.75, 0.25),
    };
    let (iwf, iwn, niw) = match iw_niw_ratio {
        None => (iwf, iwn, niw),
        Some(r) => {
            // Re-split keeping the IW-F:IW-N proportion within IW.
            let iw = r / (r + 1.0);
            let f_frac = if iwf + iwn > 0.0 { iwf / (iwf + iwn) } else { 0.0 };
            (iw * f_frac, iw * (1.0 - f_frac), 1.0 - iw)
        }
    };
    match tier {
        Tier::IwF => iwf,
        Tier::IwN => iwn,
        Tier::Niw => niw,
    }
}

/// Region share for a tier (E > C > W for IW; West NIW negligible).
fn region_share(tier: Tier, region: Region) -> f64 {
    match (tier, region) {
        (Tier::Niw, Region::EastUs) => 0.50,
        (Tier::Niw, Region::CentralUs) => 0.45,
        (Tier::Niw, Region::WestUs) => 0.05,
        (_, Region::EastUs) => 0.45,
        (_, Region::CentralUs) => 0.30,
        (_, Region::WestUs) => 0.25,
    }
}

/// Model share within (tier, region).  Indexed by ModelKind::index();
/// Llama4Scout (index 4) gets a share only when included (§7.2.5) — the
/// table is renormalized over the configured model set.
fn model_weight(model: ModelKind, tier: Tier, region: Region) -> f64 {
    let r = region.index();
    match model {
        // Model A: biggest model, dominates East (4× West for IW-F).
        ModelKind::Bloom176B => match tier {
            Tier::IwF => [0.44, 0.18, 0.20][r],
            Tier::IwN => [0.35, 0.20, 0.15][r],
            Tier::Niw => [0.30, 0.15, 0.20][r],
        },
        // Model B: peaks in Central for IW-F and West for IW-N.
        ModelKind::Llama2_70B => match tier {
            Tier::IwF => [0.22, 0.42, 0.34][r],
            Tier::IwN => [0.25, 0.30, 0.45][r],
            Tier::Niw => [0.25, 0.20, 0.30][r],
        },
        // Model C: the eval-framework bulk workload lives in Central NIW.
        ModelKind::Llama31_8B => match tier {
            Tier::IwF => [0.20, 0.25, 0.33][r],
            Tier::IwN => [0.22, 0.28, 0.25][r],
            Tier::Niw => [0.25, 0.50, 0.30][r],
        },
        ModelKind::Llama32_3B => match tier {
            Tier::IwF => [0.14, 0.15, 0.22][r],
            Tier::IwN => [0.18, 0.22, 0.15][r],
            Tier::Niw => [0.20, 0.15, 0.20][r],
        },
        ModelKind::Llama4Scout => 0.15, // uniform share when present
        ModelKind::TinyLm => 0.0,
    }
}

/// Diurnal multiplier (mean 1.0 over a week) — von-Mises-style bump
/// peaking at 13:30 with business-hours mass, plus weekend quiescing.
fn diurnal(tier: Tier, t: Time, start_weekday: usize) -> f64 {
    let day = (t / DAY).floor() as i64;
    let weekday = ((start_weekday as i64 + day) % 7 + 7) % 7; // 0 = Mon
    let hour = (t % DAY) / HOUR;
    match tier {
        Tier::Niw => 1.0, // flat through the week (§3)
        _ => {
            let kappa = 1.6f64;
            let phase = 2.0 * std::f64::consts::PI * (hour - 13.5) / 24.0;
            let bump = (kappa * (phase.cos() - 1.0)).exp();
            // normalize bump mean over 24h ≈ 0.318 for kappa=1.6
            let shape = 0.20 + 2.51 * bump;
            let weekend = if weekday >= 5 {
                if tier == Tier::IwF {
                    0.25
                } else {
                    0.35
                }
            } else {
                1.0
            };
            shape * weekend
        }
    }
}

/// Mid-week growth for Model B IW-N (Wed/Thu/Fri > Mon/Tue — §3).
fn weekday_model_factor(model: ModelKind, tier: Tier, t: Time, start_weekday: usize) -> f64 {
    if model == ModelKind::Llama2_70B && tier == Tier::IwN {
        let day = (t / DAY).floor() as i64;
        let weekday = ((start_weekday as i64 + day) % 7 + 7) % 7;
        match weekday {
            0 | 1 => 0.85,
            2 | 3 | 4 => 1.15,
            _ => 1.0,
        }
    } else {
        1.0
    }
}

/// A randomly scheduled traffic burst.
#[derive(Debug, Clone)]
struct Burst {
    start: Time,
    end: Time,
    factor: f64,
    region: Region,
    tier: Tier,
}

/// App mix per tier (Fig 6a: RAG 41.2% of all requests).
fn app_mix(tier: Tier) -> &'static [(AppKind, f64)] {
    match tier {
        Tier::IwF => &[
            (AppKind::Rag, 0.55),
            (AppKind::Chat, 0.15),
            (AppKind::EmailSuggest, 0.10),
            (AppKind::CodeGen, 0.07),
            (AppKind::Moderation, 0.05),
            (AppKind::InsightsGen, 0.05),
            (AppKind::MeetingRecap, 0.03),
        ],
        Tier::IwN => &[
            (AppKind::Rag, 0.45),
            (AppKind::InsightsGen, 0.18),
            (AppKind::ContentCreation, 0.13),
            (AppKind::MeetingRecap, 0.10),
            (AppKind::DocSummary, 0.09),
            (AppKind::Chat, 0.05),
        ],
        Tier::Niw => &[
            (AppKind::DocSummary, 0.28),
            (AppKind::EvalFramework, 0.25),
            (AppKind::ContentCreation, 0.18),
            (AppKind::InsightsGen, 0.14),
            (AppKind::Rag, 0.15),
        ],
    }
}

/// The generator: deterministic for a given config (seeded ChaCha8).
pub struct TraceGenerator {
    pub cfg: TraceConfig,
    bursts: Vec<Burst>,
    model_norm: Vec<f64>, // per (tier, region): sum of model weights
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xb00b5);
        let mut bursts = Vec::new();
        if cfg.bursts {
            for region in Region::ALL {
                for tier in [Tier::IwF, Tier::IwN] {
                    // ~2 bursts per day per (region, IW tier).
                    let n = (2.0 * cfg.days).round() as usize;
                    for _ in 0..n {
                        let start = rng.range(0.0, cfg.days * DAY);
                        let dur = rng.range(cfg.burst_minutes.0 * MINUTE,
                                            cfg.burst_minutes.1 * MINUTE);
                        let factor = rng.range(2.0, 4.0) * cfg.burst_amplitude;
                        bursts.push(Burst { start, end: start + dur, factor, region, tier });
                    }
                }
            }
        }
        let mut model_norm = vec![0.0; Tier::ALL.len() * Region::ALL.len()];
        for tier in Tier::ALL {
            for region in Region::ALL {
                let s: f64 = cfg.models.iter().map(|&m| model_weight(m, tier, region)).sum();
                model_norm[tier.index() * 3 + region.index()] = s.max(1e-12);
            }
        }
        TraceGenerator { cfg, bursts, model_norm }
    }

    fn burst_factor(&self, region: Region, tier: Tier, t: Time) -> f64 {
        let mut f = 1.0f64;
        for b in &self.bursts {
            if b.region == region && b.tier == tier && t >= b.start && t < b.end {
                f = f.max(b.factor);
            }
        }
        f
    }

    /// Expected arrival rate (requests/sec) for one stream at time `t`.
    /// Also used to synthesize pre-trace history for forecaster warm-up.
    pub fn rate(&self, model: ModelKind, region: Region, tier: Tier, t: Time) -> f64 {
        let share = tier_share(self.cfg.epoch, tier, self.cfg.iw_niw_ratio)
            * region_share(tier, region)
            * model_weight(model, tier, region)
            / self.model_norm[tier.index() * 3 + region.index()];
        epoch_base_rps(self.cfg.epoch)
            * self.cfg.scale
            * share
            * diurnal(tier, t, self.cfg.start_weekday)
            * weekday_model_factor(model, tier, t, self.cfg.start_weekday)
            * self.burst_factor(region, tier, t)
    }

    /// Mean total tokens per request for one stream (for TPS estimates).
    pub fn mean_tokens(&self, model: ModelKind, tier: Tier) -> f64 {
        TraceGenerator::mean_tokens_exact(model, tier)
    }

    /// Generate the full trace as a time-ordered iterator.
    ///
    /// Arrivals are sampled per-minute per stream as Poisson counts with
    /// uniform placement inside the minute — this yields exact
    /// non-homogeneous-Poisson statistics at 1-minute rate resolution and
    /// keeps memory at O(requests per minute).
    pub fn stream(&self) -> TraceStream<'_> {
        TraceStream {
            generator: self,
            rng: Rng::seed_from_u64(self.cfg.seed),
            minute: 0,
            total_minutes: (self.cfg.days * DAY / MINUTE).ceil() as u64,
            bucket: Vec::new(),
            bucket_pos: 0,
            next_id: 0,
        }
    }

    /// Convenience: collect the whole trace (small scales only).
    pub fn collect(&self) -> Vec<Request> {
        self.stream().collect()
    }
}

impl TraceGenerator {
    /// Exact per-(model, tier) mean total tokens from the (mu, sigma)
    /// parameters (LogNormal mean = exp(mu + sigma²/2)).
    pub fn mean_tokens_exact(model: ModelKind, tier: Tier) -> f64 {
        let mut total = 0.0;
        for &(app, w) in app_mix(tier) {
            let (imu, isig, omu, osig) = token_params(model, app);
            total += w * ((imu + isig * isig / 2.0).exp() + (omu + osig * osig / 2.0).exp());
        }
        total
    }
}

/// (input mu, input sigma, output mu, output sigma) in ln-space.
fn token_params(model: ModelKind, app: AppKind) -> (f64, f64, f64, f64) {
    let (imu, isig, omu, osig) = match app {
        AppKind::Rag => (7.8, 0.7, 5.6, 0.8),
        AppKind::EvalFramework => (8.9, 0.6, 7.3, 0.7),
        AppKind::DocSummary => (8.3, 0.8, 6.2, 0.6),
        AppKind::Chat => (7.0, 0.9, 5.9, 0.9),
        AppKind::EmailSuggest => (6.6, 0.7, 4.6, 0.7),
        AppKind::Moderation => (6.9, 0.8, 3.2, 0.6),
        _ => (7.4, 0.8, 5.8, 0.8),
    };
    let shift = match model {
        ModelKind::Llama32_3B => -0.35,
        ModelKind::Llama31_8B => -0.15,
        _ => 0.0,
    };
    (imu + shift, isig, omu, osig)
}

/// Streaming iterator over the trace, minute-bucketed.
pub struct TraceStream<'a> {
    generator: &'a TraceGenerator,
    rng: Rng,
    minute: u64,
    total_minutes: u64,
    bucket: Vec<Request>,
    bucket_pos: usize,
    next_id: u64,
}

impl TraceStream<'_> {
    fn fill_bucket(&mut self) {
        self.bucket.clear();
        self.bucket_pos = 0;
        let g = self.generator;
        let t0 = self.minute as f64 * MINUTE;
        let t_mid = t0 + 0.5 * MINUTE;
        for tier in Tier::ALL {
            for region in Region::ALL {
                for &model in &g.cfg.models {
                    let lambda = g.rate(model, region, tier, t_mid) * MINUTE;
                    if lambda <= 0.0 {
                        continue;
                    }
                    let n = self.rng.poisson(lambda) as usize;
                    for _ in 0..n {
                        let arrival = t0 + self.rng.range(0.0, MINUTE);
                        let app = sample_app(tier, &mut self.rng);
                        let (imu, isig, omu, osig) = token_params(model, app);
                        let input = self.rng.lognormal(imu, isig);
                        let output = self.rng.lognormal(omu, osig);
                        self.bucket.push(Request {
                            id: 0, // assigned after sorting for arrival order
                            arrival,
                            model,
                            origin: region,
                            tier,
                            app,
                            input_tokens: (input.clamp(16.0, 128_000.0)) as u32,
                            output_tokens: (output.clamp(1.0, 32_000.0)) as u32,
                        });
                    }
                }
            }
        }
        self.bucket
            .sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for r in &mut self.bucket {
            r.id = self.next_id;
            self.next_id += 1;
        }
    }
}

fn sample_app(tier: Tier, rng: &mut Rng) -> AppKind {
    let mix = app_mix(tier);
    let total: f64 = mix.iter().map(|&(_, w)| w).sum();
    let mut x = rng.range(0.0, total);
    for &(app, w) in mix {
        if x < w {
            return app;
        }
        x -= w;
    }
    mix.last().unwrap().0
}

impl Iterator for TraceStream<'_> {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        loop {
            if self.bucket_pos < self.bucket.len() {
                let r = self.bucket[self.bucket_pos].clone();
                self.bucket_pos += 1;
                return Some(r);
            }
            if self.minute >= self.total_minutes {
                return None;
            }
            self.fill_bucket();
            self.minute += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TraceConfig {
        TraceConfig { days: 1.0, scale: 0.01, bursts: false, ..Default::default() }
    }

    #[test]
    fn deterministic_for_seed() {
        let g1 = TraceGenerator::new(small_cfg());
        let g2 = TraceGenerator::new(small_cfg());
        let a: Vec<_> = g1.stream().take(500).collect();
        let b: Vec<_> = g2.stream().take(500).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn arrivals_sorted_and_ids_sequential() {
        let g = TraceGenerator::new(small_cfg());
        let reqs = g.collect();
        assert!(reqs.len() > 1000, "got {}", reqs.len());
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert_eq!(w[0].id + 1, w[1].id);
        }
    }

    #[test]
    fn volume_calibration_within_10pct() {
        // 1 day at scale 0.01 of 10M/day ⇒ ≈100k requests.
        let g = TraceGenerator::new(small_cfg());
        let n = g.stream().count() as f64;
        assert!((n - 100_000.0).abs() < 10_000.0, "n = {n}");
    }

    #[test]
    fn tier_mix_matches_paper() {
        let g = TraceGenerator::new(small_cfg());
        let mut counts = [0usize; 3];
        for r in g.stream() {
            counts[r.tier.index()] += 1;
        }
        let total: usize = counts.iter().sum();
        let iw = (counts[0] + counts[1]) as f64 / total as f64;
        assert!((iw - 0.72).abs() < 0.03, "IW share {iw}");
        assert!(counts[0] > counts[1], "IW-F should dominate");
    }

    #[test]
    fn nov_epoch_has_no_iwf_and_3to1_ratio() {
        let cfg = TraceConfig { epoch: Epoch::Nov2024, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let mut counts = [0usize; 3];
        for r in g.stream() {
            counts[r.tier.index()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "IW:NIW = {ratio}");
    }

    #[test]
    fn east_exceeds_west_for_iwf() {
        let g = TraceGenerator::new(small_cfg());
        let mut east = 0usize;
        let mut west = 0usize;
        for r in g.stream() {
            if r.tier == Tier::IwF {
                match r.origin {
                    Region::EastUs => east += 1,
                    Region::WestUs => west += 1,
                    _ => {}
                }
            }
        }
        assert!(east as f64 > 1.4 * west as f64, "east {east} west {west}");
    }

    #[test]
    fn bloom_east_4x_west_iwf() {
        let g = TraceGenerator::new(TraceConfig { scale: 0.05, ..small_cfg() });
        let mut east = 0usize;
        let mut west = 0usize;
        for r in g.stream() {
            if r.tier == Tier::IwF && r.model == ModelKind::Bloom176B {
                match r.origin {
                    Region::EastUs => east += 1,
                    Region::WestUs => west += 1,
                    _ => {}
                }
            }
        }
        let ratio = east as f64 / west.max(1) as f64;
        assert!(ratio > 3.0 && ratio < 5.5, "A east/west = {ratio}");
    }

    #[test]
    fn diurnal_peak_vs_trough() {
        let g = TraceGenerator::new(small_cfg());
        let peak = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, 13.5 * HOUR);
        let trough = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, 2.0 * HOUR);
        assert!(peak > 4.0 * trough, "peak {peak} trough {trough}");
        // NIW is flat.
        let p = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::Niw, 13.5 * HOUR);
        let q = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::Niw, 2.0 * HOUR);
        assert!((p - q).abs() < 1e-9);
    }

    #[test]
    fn weekend_quiesces_iw() {
        let cfg = TraceConfig { days: 7.0, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let weekday = g.rate(ModelKind::Bloom176B, Region::EastUs, Tier::IwF, 13.0 * HOUR);
        let weekend = g.rate(ModelKind::Bloom176B, Region::EastUs, Tier::IwF, 5.0 * DAY + 13.0 * HOUR);
        assert!((weekend / weekday - 0.25).abs() < 0.01);
    }

    #[test]
    fn token_cdf_shape_fig10() {
        let g = TraceGenerator::new(small_cfg());
        let reqs: Vec<_> = g.stream().take(20_000).collect();
        let over_1k_in =
            reqs.iter().filter(|r| r.input_tokens > 1000).count() as f64 / reqs.len() as f64;
        let under_1k_out =
            reqs.iter().filter(|r| r.output_tokens < 1000).count() as f64 / reqs.len() as f64;
        assert!(over_1k_in > 0.5, "majority inputs >1k: {over_1k_in}");
        assert!(under_1k_out > 0.6, "most outputs <1k: {under_1k_out}");
    }

    #[test]
    fn ratio_override_respected() {
        let cfg = TraceConfig { iw_niw_ratio: Some(9.0), ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let mut iw = 0usize;
        let mut niw = 0usize;
        for r in g.stream() {
            if r.tier == Tier::Niw {
                niw += 1;
            } else {
                iw += 1;
            }
        }
        let ratio = iw as f64 / niw as f64;
        assert!((ratio - 9.0).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn burst_raises_rate() {
        let cfg = TraceConfig { bursts: true, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let b = g.bursts.first().expect("bursts scheduled");
        let mid = 0.5 * (b.start + b.end);
        let with = g.rate(ModelKind::Bloom176B, b.region, b.tier, mid);
        let g2 = TraceGenerator::new(TraceConfig { bursts: false, ..small_cfg() });
        let without = g2.rate(ModelKind::Bloom176B, b.region, b.tier, mid);
        assert!(with > 1.5 * without);
    }

    #[test]
    fn rag_dominates_app_mix() {
        // Full day (tier mix shifts overnight, so partial days skew NIW).
        let g = TraceGenerator::new(small_cfg());
        let mut rag = 0usize;
        let mut total = 0usize;
        for r in g.stream() {
            total += 1;
            rag += (r.app == AppKind::Rag) as usize;
        }
        let share = rag as f64 / total as f64;
        assert!((share - 0.412).abs() < 0.06, "rag share {share}");
    }

    #[test]
    fn expected_tps_consistent_with_samples() {
        let g = TraceGenerator::new(TraceConfig { scale: 0.05, bursts: false, ..small_cfg() });
        // Sum sampled tokens in a 1h window vs analytic expectation.
        let (lo, hi) = (12.0 * HOUR, 13.0 * HOUR);
        let mut sampled = 0.0f64;
        for r in g.stream() {
            if r.arrival >= lo && r.arrival < hi && r.tier == Tier::IwF {
                sampled += r.total_tokens() as f64;
            }
            if r.arrival >= hi {
                break;
            }
        }
        let mut expected = 0.0;
        for region in Region::ALL {
            for &m in &g.cfg.models {
                // midpoint rate × mean tokens × 3600
                expected += g.rate(m, region, Tier::IwF, 12.5 * HOUR)
                    * TraceGenerator::mean_tokens_exact(m, Tier::IwF)
                    * HOUR;
            }
        }
        let ratio = sampled / expected;
        assert!(ratio > 0.7 && ratio < 1.3, "sampled/expected = {ratio}");
    }
}
