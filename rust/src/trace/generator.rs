//! Paper-calibrated synthetic workload generator (substitute for the
//! proprietary O365 traces — DESIGN.md §1).
//!
//! Calibration targets, all from §3 of the paper:
//! * Jul-2025: ≈10 M requests/day at `scale = 1.0`, tier mix IW-F 45% /
//!   IW-N 27% / NIW 28% (IW together 72%).
//! * Nov-2024: ≈1/5 the Jul-2025 volume, IW:NIW = 3:1, no IW-F/IW-N split
//!   (all interactive traffic is emitted as IW-N).
//! * IW tiers: strong diurnal periodicity (early-afternoon US peak),
//!   weekends quiescing; IW-N additionally grows through the week for
//!   Model B (Wed/Thu/Fri > Mon/Tue).
//! * NIW: aperiodic, stable through the week, negligible in West US.
//! * Region amplitudes E > C > W; Bloom (Model A) 4× East-vs-West for
//!   IW-F; Llama-2 (Model B) peaks in Central (IW-F) and West (IW-N).
//! * Token counts: log-normal; most inputs > 1 k, most outputs < 1 k
//!   (Fig 10); the eval-framework app on Model C in Central US NIW issues
//!   bulk requests with much higher TPS/request.
//! * Random 5–15 min bursts (~2/day per region) at 2–4× base rate;
//!   1-minute-scale arrival noise comes free from Poisson sampling.
//!
//! ## Pipeline architecture (PERF.md "input pipeline")
//!
//! Every arrival stream (tier × region × model) in every minute bucket
//! draws from its own counter-seeded RNG
//! (`Rng::seed_from_parts(seed, minute, stream)`), so a minute's
//! requests are a pure function of `(config, minute)` — independent of
//! generation order.  That makes three consumption modes byte-identical
//! by construction:
//! * [`TraceGenerator::stream`] — the lazy minute-bucketed iterator
//!   (O(requests-per-minute) memory; single simulation runs);
//! * [`TraceGenerator::materialize`] — chunk-parallel bulk generation
//!   on scoped threads (sweep grids, `--scale 1.0` runs);
//! * [`TraceGenerator::materialize_opts`] — same, with explicit chunk
//!   size / worker count (tests assert all of them agree exactly).
//!
//! Per-request sampling is O(1): alias-table app mix, precomputed
//! per-(model, app) token parameters, paired Box–Muller log-normals,
//! PTRS Poisson for mid/large λ, and an interval-indexed burst factor.

use crate::util::rng::{AliasTable, Rng};

use crate::config::{Epoch, ModelKind, Region, Tier, Time, DAY, HOUR, MINUTE};
use crate::trace::types::{AppKind, Request};

/// Generator parameters.  `..Default::default()` reproduces the Jul-2025
/// evaluation setup with the four open-source models.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Calibration epoch (Jul-2025 evaluation or Nov-2024 validation).
    pub epoch: Epoch,
    /// Model families the trace targets (drives per-model rate shares).
    pub models: Vec<ModelKind>,
    /// Trace length in days.
    pub days: f64,
    /// Linear volume multiplier.  1.0 ≈ 10 M req/day (Jul-2025).
    /// Experiments default to smaller scales for runtime; the shape is
    /// scale-invariant.
    pub scale: f64,
    /// RNG seed — same seed, same trace, byte for byte.
    pub seed: u64,
    /// Day-of-week of t=0 (0 = Monday).
    pub start_weekday: usize,
    /// Inject random traffic bursts (disable for forecast-friendly runs).
    pub bursts: bool,
    /// Multiply the burst amplitude (Fig 16a uses 8× synthetic spikes).
    pub burst_amplitude: f64,
    /// Burst duration range in minutes (default 5–15; Fig 16a stretches
    /// bursts so they overlap LT-UA's end-of-hour correction window).
    pub burst_minutes: (f64, f64),
    /// Override the IW:NIW request-count ratio, e.g. `Some(9.0)` for the
    /// 9:1 ablation of §7.2.8.  `None` keeps the epoch default.
    pub iw_niw_ratio: Option<f64>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            epoch: Epoch::Jul2025,
            models: ModelKind::EVAL4.to_vec(),
            days: 1.0,
            scale: 1.0,
            seed: 42,
            start_weekday: 0,
            bursts: true,
            burst_amplitude: 1.0,
            burst_minutes: (5.0, 15.0),
            iw_niw_ratio: None,
        }
    }
}

/// Total mean requests/second across everything, before shape factors.
fn epoch_base_rps(epoch: Epoch) -> f64 {
    match epoch {
        Epoch::Jul2025 => 10.0e6 / DAY, // ≈115.7 RPS (≈10M/day)
        Epoch::Nov2024 => 2.0e6 / DAY,  // 5× smaller, 7 months earlier
    }
}

/// Tier shares of the total request count.
fn tier_share(epoch: Epoch, tier: Tier, iw_niw_ratio: Option<f64>) -> f64 {
    // Default splits; see module docs.
    let (iwf, iwn, niw) = match epoch {
        Epoch::Jul2025 => (0.45, 0.27, 0.28),
        Epoch::Nov2024 => (0.0, 0.75, 0.25),
    };
    let (iwf, iwn, niw) = match iw_niw_ratio {
        None => (iwf, iwn, niw),
        Some(r) => {
            // Re-split keeping the IW-F:IW-N proportion within IW.
            let iw = r / (r + 1.0);
            let f_frac = if iwf + iwn > 0.0 { iwf / (iwf + iwn) } else { 0.0 };
            (iw * f_frac, iw * (1.0 - f_frac), 1.0 - iw)
        }
    };
    match tier {
        Tier::IwF => iwf,
        Tier::IwN => iwn,
        Tier::Niw => niw,
    }
}

/// Region share for a tier (E > C > W for IW; West NIW negligible).
fn region_share(tier: Tier, region: Region) -> f64 {
    match (tier, region) {
        (Tier::Niw, Region::EastUs) => 0.50,
        (Tier::Niw, Region::CentralUs) => 0.45,
        (Tier::Niw, Region::WestUs) => 0.05,
        (_, Region::EastUs) => 0.45,
        (_, Region::CentralUs) => 0.30,
        (_, Region::WestUs) => 0.25,
    }
}

/// Model share within (tier, region).  Indexed by ModelKind::index();
/// Llama4Scout (index 4) gets a share only when included (§7.2.5) — the
/// table is renormalized over the configured model set.
fn model_weight(model: ModelKind, tier: Tier, region: Region) -> f64 {
    let r = region.index();
    match model {
        // Model A: biggest model, dominates East (4× West for IW-F).
        ModelKind::Bloom176B => match tier {
            Tier::IwF => [0.44, 0.18, 0.20][r],
            Tier::IwN => [0.35, 0.20, 0.15][r],
            Tier::Niw => [0.30, 0.15, 0.20][r],
        },
        // Model B: peaks in Central for IW-F and West for IW-N.
        ModelKind::Llama2_70B => match tier {
            Tier::IwF => [0.22, 0.42, 0.34][r],
            Tier::IwN => [0.25, 0.30, 0.45][r],
            Tier::Niw => [0.25, 0.20, 0.30][r],
        },
        // Model C: the eval-framework bulk workload lives in Central NIW.
        ModelKind::Llama31_8B => match tier {
            Tier::IwF => [0.20, 0.25, 0.33][r],
            Tier::IwN => [0.22, 0.28, 0.25][r],
            Tier::Niw => [0.25, 0.50, 0.30][r],
        },
        ModelKind::Llama32_3B => match tier {
            Tier::IwF => [0.14, 0.15, 0.22][r],
            Tier::IwN => [0.18, 0.22, 0.15][r],
            Tier::Niw => [0.20, 0.15, 0.20][r],
        },
        ModelKind::Llama4Scout => 0.15, // uniform share when present
        ModelKind::TinyLm => 0.0,
    }
}

/// Diurnal multiplier (mean 1.0 over a week) — von-Mises-style bump
/// peaking at 13:30 with business-hours mass, plus weekend quiescing.
fn diurnal(tier: Tier, t: Time, start_weekday: usize) -> f64 {
    let day = (t / DAY).floor() as i64;
    let weekday = ((start_weekday as i64 + day) % 7 + 7) % 7; // 0 = Mon
    let hour = (t % DAY) / HOUR;
    match tier {
        Tier::Niw => 1.0, // flat through the week (§3)
        _ => {
            let kappa = 1.6f64;
            let phase = 2.0 * std::f64::consts::PI * (hour - 13.5) / 24.0;
            let bump = (kappa * (phase.cos() - 1.0)).exp();
            // normalize bump mean over 24h ≈ 0.318 for kappa=1.6
            let shape = 0.20 + 2.51 * bump;
            let weekend = if weekday >= 5 {
                if tier == Tier::IwF {
                    0.25
                } else {
                    0.35
                }
            } else {
                1.0
            };
            shape * weekend
        }
    }
}

/// Mid-week growth for Model B IW-N (Wed/Thu/Fri > Mon/Tue — §3).
fn weekday_model_factor(model: ModelKind, tier: Tier, t: Time, start_weekday: usize) -> f64 {
    if model == ModelKind::Llama2_70B && tier == Tier::IwN {
        let day = (t / DAY).floor() as i64;
        let weekday = ((start_weekday as i64 + day) % 7 + 7) % 7;
        match weekday {
            0 | 1 => 0.85,
            2 | 3 | 4 => 1.15,
            _ => 1.0,
        }
    } else {
        1.0
    }
}

/// A randomly scheduled traffic burst.
#[derive(Debug, Clone)]
struct Burst {
    start: Time,
    end: Time,
    factor: f64,
    region: Region,
    tier: Tier,
}

/// App mix per tier (Fig 6a: RAG 41.2% of all requests).
fn app_mix(tier: Tier) -> &'static [(AppKind, f64)] {
    match tier {
        Tier::IwF => &[
            (AppKind::Rag, 0.55),
            (AppKind::Chat, 0.15),
            (AppKind::EmailSuggest, 0.10),
            (AppKind::CodeGen, 0.07),
            (AppKind::Moderation, 0.05),
            (AppKind::InsightsGen, 0.05),
            (AppKind::MeetingRecap, 0.03),
        ],
        Tier::IwN => &[
            (AppKind::Rag, 0.45),
            (AppKind::InsightsGen, 0.18),
            (AppKind::ContentCreation, 0.13),
            (AppKind::MeetingRecap, 0.10),
            (AppKind::DocSummary, 0.09),
            (AppKind::Chat, 0.05),
        ],
        Tier::Niw => &[
            (AppKind::DocSummary, 0.28),
            (AppKind::EvalFramework, 0.25),
            (AppKind::ContentCreation, 0.18),
            (AppKind::InsightsGen, 0.14),
            (AppKind::Rag, 0.15),
        ],
    }
}

/// Alias-table app sampler for one tier.
#[derive(Debug, Clone)]
struct AppSampler {
    apps: Vec<AppKind>,
    alias: AliasTable,
}

impl AppSampler {
    fn new(tier: Tier) -> Self {
        let mix = app_mix(tier);
        let weights: Vec<f64> = mix.iter().map(|&(_, w)| w).collect();
        AppSampler {
            apps: mix.iter().map(|&(a, _)| a).collect(),
            alias: AliasTable::new(&weights),
        }
    }

    #[inline]
    fn sample(&self, rng: &mut Rng) -> AppKind {
        self.apps[self.alias.sample(rng)]
    }
}

/// Token-parameter table stride (one row per [`AppKind`]).
const N_APPS: usize = AppKind::ALL.len();

/// Default minute-chunk size for parallel materialization: small enough
/// that the diurnal peak doesn't skew per-chunk work, large enough to
/// amortize per-chunk overhead.
const DEFAULT_CHUNK_MINUTES: u64 = 16;

/// The generator: deterministic for a given config.  Arrival streams are
/// counter-seeded per (minute, stream), so every consumption mode —
/// streaming, bulk, chunk-parallel — produces the identical trace.
pub struct TraceGenerator {
    /// The configuration this generator was built from.
    pub cfg: TraceConfig,
    bursts: Vec<Burst>,
    model_norm: Vec<f64>, // per (tier, region): sum of model weights
    /// Arrival streams in fixed (tier, region, model) order — the
    /// per-minute generation order and the stream index space for
    /// counter-based seeding.
    streams: Vec<(Tier, Region, ModelKind)>,
    /// Time-invariant λ prefactor per stream: base_rps × scale ×
    /// tier/region/model shares (diurnal, weekday and burst factors are
    /// applied per minute).
    stream_base: Vec<f64>,
    /// Alias-table app samplers, one per tier.
    app_samplers: [AppSampler; 3],
    /// Precomputed token parameters: `[model.index() * N_APPS + app.index()]`.
    token_tbl: Vec<(f64, f64, f64, f64)>,
    /// Piecewise-constant burst factor per (region, IW tier):
    /// `[region.index() * 2 + tier.index()]`, each a time-sorted
    /// `(segment_start, factor)` list starting at -∞ — binary-searched
    /// by `burst_factor` instead of scanning every burst per call.
    burst_segments: Vec<Vec<(Time, f64)>>,
}

impl TraceGenerator {
    /// Build the generator: sample burst schedules, precompute stream
    /// prefactors, alias tables and token parameters.
    pub fn new(cfg: TraceConfig) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xb00b5);
        let mut bursts = Vec::new();
        if cfg.bursts {
            for region in Region::ALL {
                for tier in [Tier::IwF, Tier::IwN] {
                    // ~2 bursts per day per (region, IW tier).
                    let n = (2.0 * cfg.days).round() as usize;
                    for _ in 0..n {
                        let start = rng.range(0.0, cfg.days * DAY);
                        let dur = rng.range(cfg.burst_minutes.0 * MINUTE,
                                            cfg.burst_minutes.1 * MINUTE);
                        let factor = rng.range(2.0, 4.0) * cfg.burst_amplitude;
                        bursts.push(Burst { start, end: start + dur, factor, region, tier });
                    }
                }
            }
        }
        let mut model_norm = vec![0.0; Tier::ALL.len() * Region::ALL.len()];
        for tier in Tier::ALL {
            for region in Region::ALL {
                let s: f64 = cfg.models.iter().map(|&m| model_weight(m, tier, region)).sum();
                model_norm[tier.index() * 3 + region.index()] = s.max(1e-12);
            }
        }

        let app_samplers = [
            AppSampler::new(Tier::IwF),
            AppSampler::new(Tier::IwN),
            AppSampler::new(Tier::Niw),
        ];

        let mut token_tbl = vec![(0.0, 0.0, 0.0, 0.0); ModelKind::ALL.len() * N_APPS];
        for model in ModelKind::ALL {
            for app in AppKind::ALL {
                token_tbl[model.index() * N_APPS + app.index()] = token_params(model, app);
            }
        }

        let burst_segments = build_burst_segments(&bursts);

        let mut gen = TraceGenerator {
            cfg,
            bursts,
            model_norm,
            streams: Vec::new(),
            stream_base: Vec::new(),
            app_samplers,
            token_tbl,
            burst_segments,
        };
        // Fixed stream enumeration: tier-major, then region, then model —
        // the same order the per-minute fill visits, and the index space
        // for counter-based RNG streams.  Prefactors come from the same
        // `stream_base_rate` that `rate()` uses (single λ source).
        let models = gen.cfg.models.clone();
        for tier in Tier::ALL {
            for region in Region::ALL {
                for &model in &models {
                    gen.streams.push((tier, region, model));
                    gen.stream_base.push(gen.stream_base_rate(model, region, tier));
                }
            }
        }
        gen
    }

    /// Trace length in whole minute buckets.
    pub fn total_minutes(&self) -> u64 {
        (self.cfg.days * DAY / MINUTE).ceil() as u64
    }

    /// Max burst factor covering `t` for (region, tier) — O(log bursts)
    /// via the precomputed piecewise-constant segments.
    fn burst_factor(&self, region: Region, tier: Tier, t: Time) -> f64 {
        if tier == Tier::Niw || self.bursts.is_empty() {
            return 1.0;
        }
        let seg = &self.burst_segments[region.index() * 2 + tier.index()];
        let i = seg.partition_point(|&(start, _)| start <= t);
        seg[i - 1].1
    }

    /// Time-invariant λ prefactor (requests/sec) for one stream:
    /// base RPS × scale × tier/region/model shares.  The single source
    /// for both `rate()` and the precomputed `stream_base` table.
    fn stream_base_rate(&self, model: ModelKind, region: Region, tier: Tier) -> f64 {
        let share = tier_share(self.cfg.epoch, tier, self.cfg.iw_niw_ratio)
            * region_share(tier, region)
            * model_weight(model, tier, region)
            / self.model_norm[tier.index() * 3 + region.index()];
        epoch_base_rps(self.cfg.epoch) * self.cfg.scale * share
    }

    /// Time-varying shape multiplier at `t`: diurnal × weekday-growth ×
    /// burst.  Shared by `rate()` and the per-minute fill, so the λ
    /// formula exists in exactly one place.
    fn shape_factor(&self, model: ModelKind, region: Region, tier: Tier, t: Time) -> f64 {
        diurnal(tier, t, self.cfg.start_weekday)
            * weekday_model_factor(model, tier, t, self.cfg.start_weekday)
            * self.burst_factor(region, tier, t)
    }

    /// Expected arrival rate (requests/sec) for one stream at time `t`.
    /// Also used to synthesize pre-trace history for forecaster warm-up.
    pub fn rate(&self, model: ModelKind, region: Region, tier: Tier, t: Time) -> f64 {
        self.stream_base_rate(model, region, tier) * self.shape_factor(model, region, tier, t)
    }

    /// Mean total tokens per request for one stream (for TPS estimates).
    pub fn mean_tokens(&self, model: ModelKind, tier: Tier) -> f64 {
        TraceGenerator::mean_tokens_exact(model, tier)
    }

    /// Generate one minute bucket into `out` (cleared first): Poisson
    /// arrival counts per stream with uniform placement inside the
    /// minute, sorted by arrival.  Request ids are left 0 — the caller
    /// assigns them in final arrival order.  Pure function of
    /// `(config, minute)`: every stream draws from its own
    /// counter-seeded RNG.
    fn fill_minute(&self, minute: u64, out: &mut Vec<Request>) {
        out.clear();
        let t0 = minute as f64 * MINUTE;
        let t_mid = t0 + 0.5 * MINUTE;
        for (s, &(tier, region, model)) in self.streams.iter().enumerate() {
            let lambda =
                self.stream_base[s] * self.shape_factor(model, region, tier, t_mid) * MINUTE;
            if lambda <= 0.0 {
                continue;
            }
            let mut rng = Rng::seed_from_parts(self.cfg.seed, minute, s as u64);
            let n = rng.poisson(lambda);
            if n == 0 {
                continue;
            }
            let sampler = &self.app_samplers[tier.index()];
            out.reserve(n as usize);
            for _ in 0..n {
                let arrival = t0 + rng.range(0.0, MINUTE);
                let app = sampler.sample(&mut rng);
                let (imu, isig, omu, osig) =
                    self.token_tbl[model.index() * N_APPS + app.index()];
                let input = rng.lognormal(imu, isig);
                let output = rng.lognormal(omu, osig);
                out.push(Request {
                    id: 0, // assigned by the consumer in arrival order
                    arrival,
                    model,
                    origin: region,
                    tier,
                    app,
                    input_tokens: (input.clamp(16.0, 128_000.0)) as u32,
                    output_tokens: (output.clamp(1.0, 32_000.0)) as u32,
                });
            }
        }
        // Deterministic regardless of generation path: the input order is
        // a pure function of (config, minute), so the unstable sort is
        // too.  Arrivals are continuous draws — ties are measure-zero.
        out.sort_unstable_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    }

    /// Generate a contiguous run of minute buckets (ids still 0).
    fn fill_chunk(&self, first_minute: u64, last_minute: u64) -> Vec<Request> {
        let mut out = Vec::new();
        let mut bucket = Vec::new();
        for minute in first_minute..last_minute {
            self.fill_minute(minute, &mut bucket);
            out.extend_from_slice(&bucket);
        }
        out
    }

    /// Generate the minute window `[first_minute, last_minute)` as a
    /// time-ordered buffer, ids left 0 — the caller assigns ids in
    /// global arrival order (chunk order), exactly as [`TraceStream`]
    /// would.  Pure function of `(config, window)`: any partition of
    /// `0..total_minutes()` into windows concatenates to the identical
    /// trace, which is what lets `sim::chunked` generate chunk k+1 on
    /// worker threads while chunk k simulates.
    pub fn generate_window(&self, first_minute: u64, last_minute: u64) -> Vec<Request> {
        self.fill_chunk(first_minute, last_minute.min(self.total_minutes()))
    }

    /// Generate the full trace as a time-ordered iterator.
    ///
    /// Arrivals are sampled per-minute per stream as Poisson counts with
    /// uniform placement inside the minute — this yields exact
    /// non-homogeneous-Poisson statistics at 1-minute rate resolution and
    /// keeps memory at O(requests per minute).
    pub fn stream(&self) -> TraceStream<'_> {
        TraceStream {
            generator: self,
            minute: 0,
            total_minutes: self.total_minutes(),
            bucket: Vec::new(),
            bucket_pos: 0,
            next_id: 0,
        }
    }

    /// Materialize the whole trace with chunk-parallel generation
    /// (scoped threads, one work unit per minute chunk).  Byte-identical
    /// to `stream().collect()` — asserted by `tests/trace_pipeline.rs`.
    pub fn materialize(&self) -> Vec<Request> {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        self.materialize_opts(DEFAULT_CHUNK_MINUTES, workers)
    }

    /// Materialize into a shareable buffer: one generation feeds every
    /// strategy run of a sweep grid (`SimConfig::shared_trace`).
    pub fn materialize_shared(&self) -> std::sync::Arc<[Request]> {
        self.materialize().into()
    }

    /// [`TraceGenerator::materialize`] with explicit chunk size and
    /// worker count.  The output does not depend on either parameter:
    /// every (minute, stream) bucket has its own counter-seeded RNG, so
    /// chunking only decides which thread computes it.
    pub fn materialize_opts(&self, chunk_minutes: u64, workers: usize) -> Vec<Request> {
        let total_minutes = self.total_minutes();
        let chunk_minutes = chunk_minutes.max(1);
        let n_chunks = ((total_minutes + chunk_minutes - 1) / chunk_minutes) as usize;
        if n_chunks == 0 {
            return Vec::new();
        }
        let chunk_bounds = |c: usize| -> (u64, u64) {
            let lo = c as u64 * chunk_minutes;
            (lo, (lo + chunk_minutes).min(total_minutes))
        };
        let workers = workers.max(1).min(n_chunks);
        let mut chunk_bufs: Vec<Vec<Request>>;
        if workers <= 1 {
            chunk_bufs = (0..n_chunks)
                .map(|c| {
                    let (lo, hi) = chunk_bounds(c);
                    self.fill_chunk(lo, hi)
                })
                .collect();
        } else {
            use std::sync::atomic::{AtomicUsize, Ordering};
            use std::sync::Mutex;
            let slots: Vec<Mutex<Vec<Request>>> =
                (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
            let cursor = AtomicUsize::new(0);
            let (slots_ref, cursor_ref) = (&slots, &cursor);
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(move || loop {
                        let c = cursor_ref.fetch_add(1, Ordering::Relaxed);
                        if c >= n_chunks {
                            break;
                        }
                        let (lo, hi) = chunk_bounds(c);
                        *slots_ref[c].lock().unwrap() = self.fill_chunk(lo, hi);
                    });
                }
            });
            chunk_bufs = slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
        }
        // Splice in chunk order and assign ids in final arrival order.
        let total: usize = chunk_bufs.iter().map(|b| b.len()).sum();
        let mut out = Vec::with_capacity(total);
        let mut id = 0u64;
        for buf in &mut chunk_bufs {
            for mut r in buf.drain(..) {
                r.id = id;
                id += 1;
                out.push(r);
            }
        }
        out
    }

    /// Convenience: collect the whole trace (small scales only).
    pub fn collect(&self) -> Vec<Request> {
        self.stream().collect()
    }
}

/// Build the piecewise-constant max-burst-factor segments per
/// (region, IW tier).  Exact: between two consecutive breakpoints no
/// burst starts or ends, so the max factor at the left edge holds for
/// the whole half-open segment.
fn build_burst_segments(bursts: &[Burst]) -> Vec<Vec<(Time, f64)>> {
    let mut out = vec![Vec::new(); Region::ALL.len() * 2];
    for region in Region::ALL {
        for tier in [Tier::IwF, Tier::IwN] {
            let mine: Vec<&Burst> = bursts
                .iter()
                .filter(|b| b.region == region && b.tier == tier)
                .collect();
            let seg = &mut out[region.index() * 2 + tier.index()];
            seg.push((f64::NEG_INFINITY, 1.0));
            if mine.is_empty() {
                continue;
            }
            let mut cuts: Vec<Time> = Vec::with_capacity(mine.len() * 2);
            for b in &mine {
                cuts.push(b.start);
                cuts.push(b.end);
            }
            cuts.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            cuts.dedup();
            for &t in &cuts {
                let mut f = 1.0f64;
                for b in &mine {
                    if t >= b.start && t < b.end {
                        f = f.max(b.factor);
                    }
                }
                if seg.last().map(|&(_, lf)| lf != f).unwrap_or(true) {
                    seg.push((t, f));
                }
            }
        }
    }
    out
}

impl TraceGenerator {
    /// Exact per-(model, tier) mean total tokens from the (mu, sigma)
    /// parameters (LogNormal mean = exp(mu + sigma²/2)).
    pub fn mean_tokens_exact(model: ModelKind, tier: Tier) -> f64 {
        let mut total = 0.0;
        for &(app, w) in app_mix(tier) {
            let (imu, isig, omu, osig) = token_params(model, app);
            total += w * ((imu + isig * isig / 2.0).exp() + (omu + osig * osig / 2.0).exp());
        }
        total
    }
}

/// (input mu, input sigma, output mu, output sigma) in ln-space.
fn token_params(model: ModelKind, app: AppKind) -> (f64, f64, f64, f64) {
    let (imu, isig, omu, osig) = match app {
        AppKind::Rag => (7.8, 0.7, 5.6, 0.8),
        AppKind::EvalFramework => (8.9, 0.6, 7.3, 0.7),
        AppKind::DocSummary => (8.3, 0.8, 6.2, 0.6),
        AppKind::Chat => (7.0, 0.9, 5.9, 0.9),
        AppKind::EmailSuggest => (6.6, 0.7, 4.6, 0.7),
        AppKind::Moderation => (6.9, 0.8, 3.2, 0.6),
        _ => (7.4, 0.8, 5.8, 0.8),
    };
    let shift = match model {
        ModelKind::Llama32_3B => -0.35,
        ModelKind::Llama31_8B => -0.15,
        _ => 0.0,
    };
    (imu + shift, isig, omu, osig)
}

/// Streaming iterator over the trace, minute-bucketed.  Draws each
/// minute through the same counter-seeded `TraceGenerator::fill_minute`
/// as the parallel materializer, so the sequences are identical.
pub struct TraceStream<'a> {
    generator: &'a TraceGenerator,
    minute: u64,
    total_minutes: u64,
    bucket: Vec<Request>,
    bucket_pos: usize,
    next_id: u64,
}

impl TraceStream<'_> {
    fn fill_bucket(&mut self) {
        self.generator.fill_minute(self.minute, &mut self.bucket);
        self.bucket_pos = 0;
        for r in &mut self.bucket {
            r.id = self.next_id;
            self.next_id += 1;
        }
    }
}

impl Iterator for TraceStream<'_> {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        loop {
            if self.bucket_pos < self.bucket.len() {
                let r = self.bucket[self.bucket_pos];
                self.bucket_pos += 1;
                return Some(r);
            }
            if self.minute >= self.total_minutes {
                return None;
            }
            self.fill_bucket();
            self.minute += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TraceConfig {
        TraceConfig { days: 1.0, scale: 0.01, bursts: false, ..Default::default() }
    }

    #[test]
    fn deterministic_for_seed() {
        let g1 = TraceGenerator::new(small_cfg());
        let g2 = TraceGenerator::new(small_cfg());
        let a: Vec<_> = g1.stream().take(500).collect();
        let b: Vec<_> = g2.stream().take(500).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn arrivals_sorted_and_ids_sequential() {
        let g = TraceGenerator::new(small_cfg());
        let reqs = g.collect();
        assert!(reqs.len() > 1000, "got {}", reqs.len());
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert_eq!(w[0].id + 1, w[1].id);
        }
    }

    #[test]
    fn materialize_matches_stream() {
        let g = TraceGenerator::new(TraceConfig { bursts: true, ..small_cfg() });
        let streamed: Vec<_> = g.stream().collect();
        assert_eq!(g.materialize(), streamed);
    }

    #[test]
    fn window_partition_concatenates_to_stream() {
        // Any partition into windows + sequential id assignment must
        // reproduce the streamed trace byte-for-byte (the `sim::chunked`
        // consumer contract).
        let g = TraceGenerator::new(TraceConfig { bursts: true, ..small_cfg() });
        let streamed: Vec<_> = g.stream().collect();
        for window in [1u64, 7, 60] {
            let mut out = Vec::new();
            let mut next_id = 0u64;
            let mut lo = 0;
            while lo < g.total_minutes() {
                let mut buf = g.generate_window(lo, lo + window);
                for r in &mut buf {
                    r.id = next_id;
                    next_id += 1;
                }
                out.extend_from_slice(&buf);
                lo += window;
            }
            assert_eq!(out, streamed, "window {window}");
        }
    }

    #[test]
    fn volume_calibration_within_10pct() {
        // 1 day at scale 0.01 of 10M/day ⇒ ≈100k requests.
        let g = TraceGenerator::new(small_cfg());
        let n = g.stream().count() as f64;
        assert!((n - 100_000.0).abs() < 10_000.0, "n = {n}");
    }

    #[test]
    fn tier_mix_matches_paper() {
        let g = TraceGenerator::new(small_cfg());
        let mut counts = [0usize; 3];
        for r in g.stream() {
            counts[r.tier.index()] += 1;
        }
        let total: usize = counts.iter().sum();
        let iw = (counts[0] + counts[1]) as f64 / total as f64;
        assert!((iw - 0.72).abs() < 0.03, "IW share {iw}");
        assert!(counts[0] > counts[1], "IW-F should dominate");
    }

    #[test]
    fn nov_epoch_has_no_iwf_and_3to1_ratio() {
        let cfg = TraceConfig { epoch: Epoch::Nov2024, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let mut counts = [0usize; 3];
        for r in g.stream() {
            counts[r.tier.index()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "IW:NIW = {ratio}");
    }

    #[test]
    fn east_exceeds_west_for_iwf() {
        let g = TraceGenerator::new(small_cfg());
        let mut east = 0usize;
        let mut west = 0usize;
        for r in g.stream() {
            if r.tier == Tier::IwF {
                match r.origin {
                    Region::EastUs => east += 1,
                    Region::WestUs => west += 1,
                    _ => {}
                }
            }
        }
        assert!(east as f64 > 1.4 * west as f64, "east {east} west {west}");
    }

    #[test]
    fn bloom_east_4x_west_iwf() {
        let g = TraceGenerator::new(TraceConfig { scale: 0.05, ..small_cfg() });
        let mut east = 0usize;
        let mut west = 0usize;
        for r in g.stream() {
            if r.tier == Tier::IwF && r.model == ModelKind::Bloom176B {
                match r.origin {
                    Region::EastUs => east += 1,
                    Region::WestUs => west += 1,
                    _ => {}
                }
            }
        }
        let ratio = east as f64 / west.max(1) as f64;
        assert!(ratio > 3.0 && ratio < 5.5, "A east/west = {ratio}");
    }

    #[test]
    fn diurnal_peak_vs_trough() {
        let g = TraceGenerator::new(small_cfg());
        let peak = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, 13.5 * HOUR);
        let trough = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, 2.0 * HOUR);
        assert!(peak > 4.0 * trough, "peak {peak} trough {trough}");
        // NIW is flat.
        let p = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::Niw, 13.5 * HOUR);
        let q = g.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::Niw, 2.0 * HOUR);
        assert!((p - q).abs() < 1e-9);
    }

    #[test]
    fn weekend_quiesces_iw() {
        let cfg = TraceConfig { days: 7.0, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let weekday = g.rate(ModelKind::Bloom176B, Region::EastUs, Tier::IwF, 13.0 * HOUR);
        let weekend = g.rate(ModelKind::Bloom176B, Region::EastUs, Tier::IwF, 5.0 * DAY + 13.0 * HOUR);
        assert!((weekend / weekday - 0.25).abs() < 0.01);
    }

    #[test]
    fn token_cdf_shape_fig10() {
        let g = TraceGenerator::new(small_cfg());
        let reqs: Vec<_> = g.stream().take(20_000).collect();
        let over_1k_in =
            reqs.iter().filter(|r| r.input_tokens > 1000).count() as f64 / reqs.len() as f64;
        let under_1k_out =
            reqs.iter().filter(|r| r.output_tokens < 1000).count() as f64 / reqs.len() as f64;
        assert!(over_1k_in > 0.5, "majority inputs >1k: {over_1k_in}");
        assert!(under_1k_out > 0.6, "most outputs <1k: {under_1k_out}");
    }

    #[test]
    fn ratio_override_respected() {
        let cfg = TraceConfig { iw_niw_ratio: Some(9.0), ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let mut iw = 0usize;
        let mut niw = 0usize;
        for r in g.stream() {
            if r.tier == Tier::Niw {
                niw += 1;
            } else {
                iw += 1;
            }
        }
        let ratio = iw as f64 / niw as f64;
        assert!((ratio - 9.0).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn burst_raises_rate() {
        let cfg = TraceConfig { bursts: true, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        let b = g.bursts.first().expect("bursts scheduled");
        let mid = 0.5 * (b.start + b.end);
        let with = g.rate(ModelKind::Bloom176B, b.region, b.tier, mid);
        let g2 = TraceGenerator::new(TraceConfig { bursts: false, ..small_cfg() });
        let without = g2.rate(ModelKind::Bloom176B, b.region, b.tier, mid);
        assert!(with > 1.5 * without);
    }

    #[test]
    fn burst_index_matches_linear_scan() {
        // The interval-indexed burst factor must agree with the brute
        // force max-over-bursts at arbitrary times, including overlap
        // regions, burst edges and times outside every burst.
        let cfg = TraceConfig { bursts: true, days: 3.0, ..small_cfg() };
        let g = TraceGenerator::new(cfg);
        assert!(!g.bursts.is_empty());
        let brute = |region: Region, tier: Tier, t: Time| -> f64 {
            let mut f = 1.0f64;
            for b in &g.bursts {
                if b.region == region && b.tier == tier && t >= b.start && t < b.end {
                    f = f.max(b.factor);
                }
            }
            f
        };
        let mut probes: Vec<Time> = Vec::new();
        for b in &g.bursts {
            probes.extend([b.start, b.end, 0.5 * (b.start + b.end), b.start - 1.0, b.end + 1.0]);
        }
        let mut t = -HOUR;
        while t < 4.0 * DAY {
            probes.push(t);
            t += 977.0; // irregular stride: avoid aligning with bursts
        }
        for region in Region::ALL {
            for tier in Tier::ALL {
                for &t in &probes {
                    assert_eq!(
                        g.burst_factor(region, tier, t),
                        brute(region, tier, t),
                        "({region}, {tier}, {t})"
                    );
                }
            }
        }
    }

    #[test]
    fn rag_dominates_app_mix() {
        // Full day (tier mix shifts overnight, so partial days skew NIW).
        let g = TraceGenerator::new(small_cfg());
        let mut rag = 0usize;
        let mut total = 0usize;
        for r in g.stream() {
            total += 1;
            rag += (r.app == AppKind::Rag) as usize;
        }
        let share = rag as f64 / total as f64;
        assert!((share - 0.412).abs() < 0.06, "rag share {share}");
    }

    #[test]
    fn expected_tps_consistent_with_samples() {
        let g = TraceGenerator::new(TraceConfig { scale: 0.05, bursts: false, ..small_cfg() });
        // Sum sampled tokens in a 1h window vs analytic expectation.
        let (lo, hi) = (12.0 * HOUR, 13.0 * HOUR);
        let mut sampled = 0.0f64;
        for r in g.stream() {
            if r.arrival >= lo && r.arrival < hi && r.tier == Tier::IwF {
                sampled += r.total_tokens() as f64;
            }
            if r.arrival >= hi {
                break;
            }
        }
        let mut expected = 0.0;
        for region in Region::ALL {
            for &m in &g.cfg.models {
                // midpoint rate × mean tokens × 3600
                expected += g.rate(m, region, Tier::IwF, 12.5 * HOUR)
                    * TraceGenerator::mean_tokens_exact(m, Tier::IwF)
                    * HOUR;
            }
        }
        let ratio = sampled / expected;
        assert!(ratio > 0.7 && ratio < 1.3, "sampled/expected = {ratio}");
    }
}
