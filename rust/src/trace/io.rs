//! Trace persistence: CSV, one `Request` per line, with a header.
//!
//! The paper promises to publish its traces in a flat record format; we
//! read/write the same records the generator produces so external traces
//! can be swapped in without touching the simulator.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::trace::types::Request;

/// The CSV header line (field order of [`Request::to_csv`]).
pub const HEADER: &str = "id,arrival,model,region,tier,app,input_tokens,output_tokens";

/// Write a trace to a CSV file (one request per line, arrival-ordered).
pub fn write_csv(path: impl AsRef<Path>, requests: impl Iterator<Item = Request>) -> Result<u64> {
    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "{HEADER}")?;
    let mut n = 0u64;
    for r in requests {
        writeln!(w, "{}", r.to_csv())?;
        n += 1;
    }
    w.flush()?;
    Ok(n)
}

/// Read a trace eagerly.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    read_csv_iter(path)?.collect()
}

/// Read a trace lazily (streaming, O(1) memory).
pub fn read_csv_iter(path: impl AsRef<Path>) -> Result<impl Iterator<Item = Result<Request>>> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut lines = BufReader::new(file).lines();
    match lines.next() {
        Some(Ok(h)) if h.trim() == HEADER => {}
        Some(Ok(h)) => bail!("unexpected header: {h}"),
        Some(Err(e)) => return Err(e.into()),
        None => bail!("empty trace file"),
    }
    Ok(lines.map(|line| {
        let line = line.context("read line")?;
        Request::from_csv(&line).map_err(|e| anyhow::anyhow!("parse: {e}"))
    }))
}

/// Unique temp-file path helper for tests (offline stand-in for the
/// `tempfile` crate).
pub fn temp_path(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "sageserve-{tag}-{}-{n}.csv",
        std::process::id()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::{TraceConfig, TraceGenerator};

    #[test]
    fn roundtrip_preserves_requests() {
        let g = TraceGenerator::new(TraceConfig {
            days: 0.05,
            scale: 0.02,
            bursts: false,
            ..Default::default()
        });
        let orig: Vec<Request> = g.collect();
        assert!(!orig.is_empty());
        let path = temp_path("roundtrip");
        let n = write_csv(&path, orig.iter().cloned()).unwrap();
        assert_eq!(n as usize, orig.len());
        let back = read_csv(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(orig.len(), back.len());
        for (a, b) in orig.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.model, b.model);
            assert_eq!(a.tier, b.tier);
            assert!((a.arrival - b.arrival).abs() < 1e-5);
            assert_eq!(a.input_tokens, b.input_tokens);
        }
    }

    #[test]
    fn missing_file_is_error() {
        assert!(read_csv("/nonexistent/trace.csv").is_err());
    }

    #[test]
    fn bad_header_is_error() {
        let path = temp_path("badheader");
        std::fs::write(&path, "nope\n1,2,3\n").unwrap();
        let r = read_csv(&path);
        std::fs::remove_file(&path).ok();
        assert!(r.is_err());
    }
}
