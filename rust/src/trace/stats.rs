//! Workload characterization statistics (§3, Figs 3–6, Fig 10).
//!
//! Pure aggregation over a request stream: RPS/TPS time series per
//! (tier, model, region), token-count CDFs, and app leaderboards — the
//! machinery behind the characterization experiments.

use std::collections::BTreeMap;

use crate::config::{ModelKind, Region, Tier, Time};
use crate::trace::types::{AppKind, Request};

/// One bucketed load series: requests and tokens per bucket.
#[derive(Debug, Clone, Default)]
pub struct LoadSeries {
    /// Bucket width in seconds.
    pub bucket_secs: Time,
    /// Request count per bucket.
    pub requests: Vec<u64>,
    /// Token count per bucket.
    pub tokens: Vec<u64>,
}

impl LoadSeries {
    /// Zeroed series covering `horizon` seconds.
    pub fn new(bucket_secs: Time, horizon: Time) -> Self {
        let n = (horizon / bucket_secs).ceil() as usize;
        LoadSeries { bucket_secs, requests: vec![0; n], tokens: vec![0; n] }
    }

    /// Record one request of `tokens` total tokens arriving at `t`.
    pub fn add(&mut self, t: Time, tokens: u64) {
        let idx = (t / self.bucket_secs) as usize;
        if idx < self.requests.len() {
            self.requests[idx] += 1;
            self.tokens[idx] += tokens;
        }
    }

    /// Requests per second in bucket `i`.
    pub fn rps(&self, i: usize) -> f64 {
        self.requests[i] as f64 / self.bucket_secs
    }

    /// Total tokens per second in bucket `i`.
    pub fn tps(&self, i: usize) -> f64 {
        self.tokens[i] as f64 / self.bucket_secs
    }

    /// Number of buckets.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the series covers no buckets.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Highest per-bucket RPS across the series.
    pub fn peak_rps(&self) -> f64 {
        (0..self.len()).map(|i| self.rps(i)).fold(0.0, f64::max)
    }
}

/// Stream aggregator for the characterization study.
pub struct WorkloadStats {
    /// Time span the series cover, seconds.
    pub horizon: Time,
    /// Bucket width in seconds.
    pub bucket_secs: Time,
    /// (tier, model, region) → load series.
    pub series: BTreeMap<(Tier, ModelKind, Region), LoadSeries>,
    /// tier → cumulative series.
    pub tier_series: BTreeMap<Tier, LoadSeries>,
    /// app → (requests, tokens).
    pub apps: BTreeMap<AppKind, (u64, u64)>,
    /// model → sampled (input, output) token counts, decimated.
    pub token_samples: BTreeMap<ModelKind, Vec<(u32, u32)>>,
    /// Requests observed so far.
    pub total_requests: u64,
    sample_stride: u64,
}

impl WorkloadStats {
    /// Empty aggregator over `horizon` seconds of `bucket_secs` buckets.
    pub fn new(horizon: Time, bucket_secs: Time) -> Self {
        WorkloadStats {
            horizon,
            bucket_secs,
            series: BTreeMap::new(),
            tier_series: BTreeMap::new(),
            apps: BTreeMap::new(),
            token_samples: BTreeMap::new(),
            total_requests: 0,
            sample_stride: 7,
        }
    }

    /// Fold one request into every series it belongs to.
    pub fn observe(&mut self, r: &Request) {
        let tokens = r.total_tokens();
        self.series
            .entry((r.tier, r.model, r.origin))
            .or_insert_with(|| LoadSeries::new(self.bucket_secs, self.horizon))
            .add(r.arrival, tokens);
        self.tier_series
            .entry(r.tier)
            .or_insert_with(|| LoadSeries::new(self.bucket_secs, self.horizon))
            .add(r.arrival, tokens);
        let e = self.apps.entry(r.app).or_insert((0, 0));
        e.0 += 1;
        e.1 += tokens;
        if self.total_requests % self.sample_stride == 0 {
            let v = self.token_samples.entry(r.model).or_default();
            if v.len() < 200_000 {
                v.push((r.input_tokens, r.output_tokens));
            }
        }
        self.total_requests += 1;
    }

    /// Top applications by request count (Fig 6a).
    pub fn top_apps(&self) -> Vec<(AppKind, u64, u64)> {
        let mut v: Vec<_> = self.apps.iter().map(|(&a, &(r, t))| (a, r, t)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Empirical CDF of a token column for a model (Fig 10).
    /// Returns (sorted values, cumulative fraction).
    pub fn token_cdf(&self, model: ModelKind, output: bool) -> (Vec<u32>, Vec<f64>) {
        let samples = match self.token_samples.get(&model) {
            Some(s) => s,
            None => return (vec![], vec![]),
        };
        let mut vals: Vec<u32> =
            samples.iter().map(|&(i, o)| if output { o } else { i }).collect();
        vals.sort_unstable();
        let n = vals.len() as f64;
        let frac = (1..=vals.len()).map(|i| i as f64 / n).collect();
        (vals, frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::{TraceConfig, TraceGenerator};

    fn stats_for(days: f64, scale: f64) -> WorkloadStats {
        let g = TraceGenerator::new(TraceConfig { days, scale, bursts: false, ..Default::default() });
        let mut st = WorkloadStats::new(days * 86_400.0, 900.0);
        for r in g.stream() {
            st.observe(&r);
        }
        st
    }

    #[test]
    fn series_counts_sum_to_total() {
        let st = stats_for(0.2, 0.01);
        let sum: u64 = st.series.values().flat_map(|s| s.requests.iter()).sum();
        assert_eq!(sum, st.total_requests);
    }

    #[test]
    fn rag_tops_the_app_table() {
        let st = stats_for(1.0, 0.005);
        let top = st.top_apps();
        assert_eq!(top[0].0, AppKind::Rag);
        let share = top[0].1 as f64 / st.total_requests as f64;
        assert!((share - 0.412).abs() < 0.06, "rag share {share}");
    }

    #[test]
    fn token_cdf_monotone() {
        let st = stats_for(0.1, 0.01);
        let (vals, frac) = st.token_cdf(ModelKind::Llama2_70B, false);
        assert!(!vals.is_empty());
        assert!(vals.windows(2).all(|w| w[0] <= w[1]));
        assert!((frac.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn diurnal_visible_in_tier_series() {
        let st = stats_for(1.0, 0.02);
        let s = &st.tier_series[&Tier::IwF];
        // peak bucket (≈13:30 → bucket 54 of 96) vs trough (≈02:00 → bucket 8)
        let peak = s.rps(54);
        let trough = s.rps(8);
        assert!(peak > 3.0 * trough, "peak {peak} trough {trough}");
    }
}
