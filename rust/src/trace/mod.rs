//! Workload traces: request types, the paper-calibrated synthetic
//! generator, JSONL I/O and characterization statistics.
//!
//! The paper's traces are proprietary Microsoft O365 telemetry; per
//! DESIGN.md §1 we substitute a parametric generator calibrated to every
//! quantitative statement of the characterization study (§3) — tier mix,
//! per-region amplitudes, diurnal/weekly periodicity, token-count CDFs,
//! the 5× Nov-2024 → Jul-2025 growth, and the application mix of Fig 6a.

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

pub mod generator;
pub mod io;
pub mod stats;
pub mod types;

pub use generator::{TraceConfig, TraceGenerator};
pub use types::{AppKind, Request, RequestId};
