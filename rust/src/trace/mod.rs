//! Workload traces: request types, the paper-calibrated synthetic
//! generator, JSONL I/O and characterization statistics.
//!
//! The paper's traces are proprietary Microsoft O365 telemetry; per
//! DESIGN.md §1 we substitute a parametric generator calibrated to every
//! quantitative statement of the characterization study (§3) — tier mix,
//! per-region amplitudes, diurnal/weekly periodicity, token-count CDFs,
//! the 5× Nov-2024 → Jul-2025 growth, and the application mix of Fig 6a.

/// The paper-calibrated synthetic workload generator.
pub mod generator;
/// Trace CSV interchange (write/read the generator's format).
pub mod io;
/// Characterization statistics over traces (§3 figures).
pub mod stats;
/// The request record and its enum/CSV plumbing.
pub mod types;

pub use generator::{TraceConfig, TraceGenerator};
pub use types::{AppKind, Request, RequestId};
