//! The request record — the unit every layer of the stack operates on.

use crate::config::{ModelKind, Region, Tier, Time};

/// Unique request identifier (dense, assigned at generation time).
pub type RequestId = u64;

/// Top O365 application families (Fig 6a).  `Rag` alone contributes 41.2%
/// of requests and drives the heavy-input token distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[allow(missing_docs)] // variants are application names; see `AppKind::name`
pub enum AppKind {
    Rag,
    InsightsGen,
    ContentCreation,
    Chat,
    EvalFramework,
    EmailSuggest,
    CodeGen,
    MeetingRecap,
    DocSummary,
    Moderation,
}

impl AppKind {
    /// Every application family, in dense-index order.
    pub const ALL: [AppKind; 10] = [
        AppKind::Rag,
        AppKind::InsightsGen,
        AppKind::ContentCreation,
        AppKind::Chat,
        AppKind::EvalFramework,
        AppKind::EmailSuggest,
        AppKind::CodeGen,
        AppKind::MeetingRecap,
        AppKind::DocSummary,
        AppKind::Moderation,
    ];

    /// Dense index (position in [`AppKind::ALL`]) for precomputed
    /// per-(model, app) lookup tables.
    pub fn index(self) -> usize {
        match self {
            AppKind::Rag => 0,
            AppKind::InsightsGen => 1,
            AppKind::ContentCreation => 2,
            AppKind::Chat => 3,
            AppKind::EvalFramework => 4,
            AppKind::EmailSuggest => 5,
            AppKind::CodeGen => 6,
            AppKind::MeetingRecap => 7,
            AppKind::DocSummary => 8,
            AppKind::Moderation => 9,
        }
    }

    /// Stable display name (the trace CSV's `app` column).
    pub fn name(self) -> &'static str {
        match self {
            AppKind::Rag => "rag-search",
            AppKind::InsightsGen => "insights-gen",
            AppKind::ContentCreation => "content-creation",
            AppKind::Chat => "chat-assistant",
            AppKind::EvalFramework => "eval-framework",
            AppKind::EmailSuggest => "email-suggest",
            AppKind::CodeGen => "code-gen",
            AppKind::MeetingRecap => "meeting-recap",
            AppKind::DocSummary => "doc-summary",
            AppKind::Moderation => "moderation",
        }
    }
}

/// One inference request, as it appears in the trace.  `Copy` (48
/// bytes of plain data) so the trace pipeline and the engine move
/// requests by value instead of cloning.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Unique id (dense, generation order).
    pub id: RequestId,
    /// Arrival at the global router, seconds since trace start.
    pub arrival: Time,
    /// The model family the request targets.
    pub model: ModelKind,
    /// The client's nearest region (the router may send it elsewhere).
    pub origin: Region,
    /// Service tier (IW-F / IW-N / NIW) — drives SLAs and scheduling.
    pub tier: Tier,
    /// Originating application family (token-distribution driver).
    pub app: AppKind,
    /// Prompt length in tokens.
    pub input_tokens: u32,
    /// Generated length in tokens.
    pub output_tokens: u32,
}

impl Request {
    /// Total tokens processed for this request (the TPS unit of §2.1).
    pub fn total_tokens(&self) -> u64 {
        self.input_tokens as u64 + self.output_tokens as u64
    }

    /// Absolute completion deadline (NIW only).
    pub fn deadline(&self) -> Option<Time> {
        self.tier.deadline().map(|d| self.arrival + d)
    }

    /// Remaining time to the TTFT deadline at `now` (`d_r` of §6.5).
    /// NIW requests fall back to their completion deadline.
    pub fn ttft_slack(&self, now: Time) -> Time {
        let sla = self.tier.ttft_sla().unwrap_or_else(|| self.tier.deadline().unwrap_or(f64::MAX));
        self.arrival + sla - now
    }

    /// CSV record (the trace interchange format — see `trace::io`).
    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6},{},{},{},{},{},{}",
            self.id,
            self.arrival,
            self.model,
            self.origin,
            self.tier,
            self.app.name(),
            self.input_tokens,
            self.output_tokens
        )
    }

    /// Parse one CSV record (inverse of [`Request::to_csv`]).
    pub fn from_csv(line: &str) -> Result<Request, String> {
        let parts: Vec<&str> = line.trim_end().split(',').collect();
        if parts.len() != 8 {
            return Err(format!("expected 8 fields, got {}", parts.len()));
        }
        Ok(Request {
            id: parts[0].parse().map_err(|e| format!("id: {e}"))?,
            arrival: parts[1].parse().map_err(|e| format!("arrival: {e}"))?,
            model: parse_model(parts[2]).ok_or_else(|| format!("model '{}'", parts[2]))?,
            origin: parse_region(parts[3]).ok_or_else(|| format!("region '{}'", parts[3]))?,
            tier: parse_tier(parts[4]).ok_or_else(|| format!("tier '{}'", parts[4]))?,
            app: parse_app(parts[5]).ok_or_else(|| format!("app '{}'", parts[5]))?,
            input_tokens: parts[6].parse().map_err(|e| format!("input: {e}"))?,
            output_tokens: parts[7].parse().map_err(|e| format!("output: {e}"))?,
        })
    }
}

/// Parse a model display name back to the enum.
pub fn parse_model(s: &str) -> Option<ModelKind> {
    use crate::config::ModelKind::*;
    Some(match s {
        "bloom-176b" => Bloom176B,
        "llama2-70b" => Llama2_70B,
        "llama3.1-8b" => Llama31_8B,
        "llama3.2-3b" => Llama32_3B,
        "llama4-scout" => Llama4Scout,
        "tinylm" => TinyLm,
        _ => return None,
    })
}

/// Parse a region display name back to the enum.
pub fn parse_region(s: &str) -> Option<Region> {
    Some(match s {
        "eastus" => Region::EastUs,
        "centralus" => Region::CentralUs,
        "westus" => Region::WestUs,
        _ => return None,
    })
}

/// Parse a tier display name back to the enum.
pub fn parse_tier(s: &str) -> Option<Tier> {
    Some(match s {
        "IW-F" => Tier::IwF,
        "IW-N" => Tier::IwN,
        "NIW" => Tier::Niw,
        _ => return None,
    })
}

/// Parse an application name back to the enum.
pub fn parse_app(s: &str) -> Option<AppKind> {
    AppKind::ALL.into_iter().find(|a| a.name() == s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tier: Tier) -> Request {
        Request {
            id: 1,
            arrival: 100.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier,
            app: AppKind::Chat,
            input_tokens: 1000,
            output_tokens: 200,
        }
    }

    #[test]
    fn app_index_matches_all_order() {
        for (i, app) in AppKind::ALL.into_iter().enumerate() {
            assert_eq!(app.index(), i, "{}", app.name());
        }
    }

    #[test]
    fn total_tokens_sums_both_directions() {
        assert_eq!(req(Tier::IwF).total_tokens(), 1200);
    }

    #[test]
    fn slack_counts_down() {
        let r = req(Tier::IwF);
        assert!((r.ttft_slack(100.0) - 1.0).abs() < 1e-9);
        assert!(r.ttft_slack(102.0) < 0.0);
    }

    #[test]
    fn niw_deadline_is_24h() {
        let r = req(Tier::Niw);
        assert_eq!(r.deadline(), Some(100.0 + 86_400.0));
        assert!(r.ttft_slack(100.0) > 86_000.0);
    }

    #[test]
    fn csv_roundtrip() {
        let r = req(Tier::IwN);
        let line = r.to_csv();
        assert_eq!(Request::from_csv(&line).unwrap(), r);
    }
}
