//! Core domain types and experiment configuration.
//!
//! Everything the paper parameterizes lives here: regions, model types, GPU
//! SKUs, workload tiers and their SLAs, and the scaling/provisioning
//! constants of §2.3/§4/§6 (thresholds, cooldowns, redeploy delays).

use std::fmt;

/// Simulated/real time, in seconds since experiment start.
pub type Time = f64;

/// One minute, in [`Time`] seconds.
pub const MINUTE: Time = 60.0;
/// One hour, in [`Time`] seconds.
pub const HOUR: Time = 3600.0;
/// One day, in [`Time`] seconds.
pub const DAY: Time = 86_400.0;
/// One week, in [`Time`] seconds.
pub const WEEK: Time = 7.0 * DAY;

/// US data-center regions used throughout the paper (§2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Region {
    /// East US (the highest-traffic region in the trace).
    EastUs,
    /// Central US.
    CentralUs,
    /// West US.
    WestUs,
}

impl Region {
    /// Every region, in [`Region::index`] order.
    pub const ALL: [Region; 3] = [Region::EastUs, Region::CentralUs, Region::WestUs];

    /// Dense index (position in [`Region::ALL`]) for per-region arrays.
    pub fn index(self) -> usize {
        match self {
            Region::EastUs => 0,
            Region::CentralUs => 1,
            Region::WestUs => 2,
        }
    }

    /// Inverse of [`Region::index`].  Panics on an out-of-range index.
    pub fn from_index(i: usize) -> Region {
        Region::ALL[i]
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Region::EastUs => "eastus",
            Region::CentralUs => "centralus",
            Region::WestUs => "westus",
        };
        f.write_str(s)
    }
}

/// Open-source model types used in the evaluation (§7.1), plus the
/// Llama-4-Scout MoE added in the scalability test (§7.2.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    /// Bloom-176B — the KV-heaviest model (multi-head attention, no GQA).
    Bloom176B,
    /// Llama-2-70B — the paper's headline evaluation model.
    Llama2_70B,
    /// Llama-3.1-8B.
    Llama31_8B,
    /// Llama-3.2-3B.
    Llama32_3B,
    /// Llama-4-Scout (109B MoE / 17B active), from the §7.2.5
    /// scalability test.
    Llama4Scout,
    /// The ~3M-parameter byte-level transformer actually served end-to-end
    /// through PJRT by `serve/` (examples/serve_model.rs).
    TinyLm,
}

impl ModelKind {
    /// Every model variant, in [`ModelKind::index`] order (dense-table
    /// iteration; guarded by a test).
    pub const ALL: [ModelKind; 6] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
        ModelKind::Llama4Scout,
        ModelKind::TinyLm,
    ];

    /// The four standard evaluation models (§7.1).
    pub const EVAL4: [ModelKind; 4] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
    ];

    /// EVAL4 plus the MoE model of the scalability test (§7.2.5).
    pub const EVAL5: [ModelKind; 5] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
        ModelKind::Llama4Scout,
    ];

    /// Dense index (position in [`ModelKind::ALL`]) for per-model arrays.
    pub fn index(self) -> usize {
        match self {
            ModelKind::Bloom176B => 0,
            ModelKind::Llama2_70B => 1,
            ModelKind::Llama31_8B => 2,
            ModelKind::Llama32_3B => 3,
            ModelKind::Llama4Scout => 4,
            ModelKind::TinyLm => 5,
        }
    }
}

impl fmt::Display for ModelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ModelKind::Bloom176B => "bloom-176b",
            ModelKind::Llama2_70B => "llama2-70b",
            ModelKind::Llama31_8B => "llama3.1-8b",
            ModelKind::Llama32_3B => "llama3.2-3b",
            ModelKind::Llama4Scout => "llama4-scout",
            ModelKind::TinyLm => "tinylm",
        };
        f.write_str(s)
    }
}

/// GPU SKUs (§2.1).  One *instance* is a whole 8-GPU VM.
///
/// Three classes span the §5 SKU axis `k`:
/// * [`GpuKind::H100x8`] — highest throughput, highest price;
/// * [`GpuKind::A100x8`] — lowest price, best $-per-throughput for
///   compute-bound models;
/// * [`GpuKind::Mi300x8`] — MI300-class: ~2.4x the HBM of the others at
///   mid throughput and a distinct price point, the natural home for
///   long-context and KV-heavy work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuKind {
    /// 8x NVIDIA H100 (80 GB each): fastest, dearest.
    H100x8,
    /// 8x NVIDIA A100 (80 GB each): ~1.8x slower than H100, cheapest.
    A100x8,
    /// 8x AMD MI300-class (192 GB each): mid throughput, 1.5 TiB HBM.
    Mi300x8,
}

impl GpuKind {
    /// Number of SKUs — the dense per-SKU array width used by the
    /// cluster aggregates and ledgers.
    pub const COUNT: usize = 3;

    /// Every SKU, in [`GpuKind::index`] order.
    pub const ALL: [GpuKind; GpuKind::COUNT] =
        [GpuKind::H100x8, GpuKind::A100x8, GpuKind::Mi300x8];

    /// Dense index (position in [`GpuKind::ALL`]) for per-SKU arrays.
    pub fn index(self) -> usize {
        match self {
            GpuKind::H100x8 => 0,
            GpuKind::A100x8 => 1,
            GpuKind::Mi300x8 => 2,
        }
    }

    /// Inverse of [`GpuKind::index`].  Panics on an out-of-range index.
    pub fn from_index(i: usize) -> GpuKind {
        GpuKind::ALL[i]
    }

    /// CLI-friendly SKU name parsing.
    pub fn parse(s: &str) -> Option<GpuKind> {
        match s.to_ascii_lowercase().as_str() {
            "h100" | "h100x8" | "8xh100" => Some(GpuKind::H100x8),
            "a100" | "a100x8" | "8xa100" => Some(GpuKind::A100x8),
            "mi300" | "mi300x" | "mi300x8" | "8xmi300" => Some(GpuKind::Mi300x8),
            _ => None,
        }
    }

    /// Total HBM per instance VM (GiB): 8 x 80 GB for the NVIDIA SKUs,
    /// 8 x 192 GB for the MI300 class — the axis SKU-aware routing
    /// steers long-context requests along.
    pub fn hbm_gib(self) -> f64 {
        match self {
            GpuKind::H100x8 | GpuKind::A100x8 => 640.0,
            GpuKind::Mi300x8 => 1536.0,
        }
    }

    /// On-demand $/hour for the 8-GPU VM — the §5 α_k
    /// (§7.2.1 quotes $98.32/h for H100).
    pub fn dollars_per_hour(self) -> f64 {
        match self {
            GpuKind::H100x8 => 98.32,
            GpuKind::A100x8 => 54.20,
            GpuKind::Mi300x8 => 78.00,
        }
    }

    /// Base spot-market $/hour a *donated* VM of this SKU earns (before
    /// the [`SpotMarket`] time-of-day multiplier).  Donated H100s are
    /// worth far more than A100s — the per-SKU spot market the ROADMAP
    /// called for.  The most-valuable SKU is also reclaimed first on
    /// scale-out (external claimants compete hardest for it).
    pub fn spot_dollars_per_hour(self) -> f64 {
        match self {
            GpuKind::H100x8 => 44.00,
            GpuKind::A100x8 => 14.00,
            GpuKind::Mi300x8 => 27.00,
        }
    }
}

impl fmt::Display for GpuKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            GpuKind::H100x8 => "8xH100",
            GpuKind::A100x8 => "8xA100",
            GpuKind::Mi300x8 => "8xMI300",
        })
    }
}

/// The per-SKU spot-market price curve: a deterministic business-hours
/// shape on top of each SKU's [`GpuKind::spot_dollars_per_hour`] base.
/// Donated (spot) instance-hours are valued at this price by
/// [`crate::metrics::Metrics::spot_revenue`]; the price is
/// hour-constant, so ledger integration splits segments at wall-clock
/// hour boundaries and stays exact.
#[derive(Debug, Clone, Copy)]
pub struct SpotMarket;

impl SpotMarket {
    /// Price multiplier outside business hours.
    pub const OFF_PEAK: f64 = 0.8;
    /// Price multiplier during business hours (09:00–17:59), when
    /// external spot demand peaks.
    pub const PEAK: f64 = 1.25;

    /// Time-of-day multiplier at simulated time `t` (hour-constant).
    pub fn multiplier(t: Time) -> f64 {
        let hour_of_day = (t / HOUR).rem_euclid(24.0).floor() as u32;
        if (9..=17).contains(&hour_of_day) {
            SpotMarket::PEAK
        } else {
            SpotMarket::OFF_PEAK
        }
    }

    /// Spot $/hour for `gpu` at simulated time `t`.
    pub fn price(gpu: GpuKind, t: Time) -> f64 {
        gpu.spot_dollars_per_hour() * SpotMarket::multiplier(t)
    }
}

/// GPU fleet composition for one run — the §5 SKU axis `k`.  The fleet
/// lists which SKUs the cluster may provision (the ILP's columns, the
/// per-SKU delta axis, the ledger keys) and what fraction of the initial
/// per-endpoint allocation each SKU hosts.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// `(SKU, initial-allocation weight)`, fleet order.  Weights are
    /// relative (normalized by their sum); SKUs must be distinct.
    pub skus: Vec<(GpuKind, f64)>,
}

impl FleetSpec {
    /// Single-SKU fleet — the paper's per-experiment assumption (§7.1)
    /// and the degenerate case every pre-heterogeneity experiment runs.
    pub fn homogeneous(gpu: GpuKind) -> Self {
        FleetSpec { skus: vec![(gpu, 1.0)] }
    }

    /// Multi-SKU fleet with explicit initial-allocation weights.
    pub fn mixed(skus: &[(GpuKind, f64)]) -> Self {
        assert!(!skus.is_empty(), "fleet needs at least one SKU");
        debug_assert!(
            skus.iter()
                .enumerate()
                .all(|(i, &(g, _))| skus[..i].iter().all(|&(h, _)| h != g)),
            "fleet SKUs must be distinct"
        );
        FleetSpec { skus: skus.to_vec() }
    }

    /// The SKUs available for provisioning, fleet order.
    pub fn gpus(&self) -> Vec<GpuKind> {
        self.skus.iter().map(|&(g, _)| g).collect()
    }

    /// True when the fleet holds exactly one SKU — the degenerate case
    /// every pre-heterogeneity experiment runs.
    pub fn is_homogeneous(&self) -> bool {
        self.skus.len() == 1
    }

    /// The first SKU — the default for single-SKU call sites.
    pub fn primary(&self) -> GpuKind {
        self.skus[0].0
    }

    /// Split `total` instances across the fleet by weight
    /// (largest-remainder apportionment; deterministic, sums to `total`,
    /// ties favour earlier SKUs).
    pub fn split(&self, total: usize) -> Vec<(GpuKind, usize)> {
        let weight: f64 = self.skus.iter().map(|&(_, w)| w).sum();
        let mut out: Vec<(GpuKind, usize)> =
            self.skus.iter().map(|&(g, _)| (g, 0)).collect();
        if weight <= 0.0 {
            out[0].1 = total;
            return out;
        }
        let mut rema: Vec<(usize, f64)> = Vec::with_capacity(self.skus.len());
        let mut assigned = 0usize;
        for (i, &(_, w)) in self.skus.iter().enumerate() {
            let share = total as f64 * w / weight;
            let base = share.floor() as usize;
            out[i].1 = base;
            assigned += base;
            rema.push((i, share - base as f64));
        }
        rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for k in 0..total.saturating_sub(assigned) {
            out[rema[k % rema.len()].0].1 += 1;
        }
        out
    }

    /// The three-way evaluation fleet: H100 + A100 + MI300, equal
    /// initial weights — the `k = 3` stress case for the §5 ILP.
    pub fn mixed_3way() -> Self {
        FleetSpec::mixed(&[
            (GpuKind::H100x8, 1.0),
            (GpuKind::A100x8, 1.0),
            (GpuKind::Mi300x8, 1.0),
        ])
    }

    /// Parse a CLI fleet spec: a SKU name (`h100`, `a100`, `mi300`),
    /// `mixed` (50/50 H100+A100), `mixed3` (equal three-way
    /// H100+A100+MI300), or explicit weights (`h100:0.5,mi300:0.5`).
    pub fn parse(s: &str) -> Option<FleetSpec> {
        match s.to_ascii_lowercase().as_str() {
            "h100" | "h100x8" | "8xh100" => return Some(FleetSpec::homogeneous(GpuKind::H100x8)),
            "a100" | "a100x8" | "8xa100" => return Some(FleetSpec::homogeneous(GpuKind::A100x8)),
            "mi300" | "mi300x8" | "8xmi300" => {
                return Some(FleetSpec::homogeneous(GpuKind::Mi300x8))
            }
            "mixed" => {
                return Some(FleetSpec::mixed(&[
                    (GpuKind::H100x8, 0.5),
                    (GpuKind::A100x8, 0.5),
                ]))
            }
            "mixed3" | "mixed-3way" | "3way" => return Some(FleetSpec::mixed_3way()),
            _ => {}
        }
        let mut skus = Vec::new();
        for part in s.split(',') {
            let (name, frac) = part.split_once(':')?;
            let gpu = GpuKind::parse(name.trim())?;
            let w: f64 = frac.trim().parse().ok()?;
            if !w.is_finite() || w < 0.0 || skus.iter().any(|&(g, _)| g == gpu) {
                return None;
            }
            skus.push((gpu, w));
        }
        if skus.is_empty() {
            None
        } else {
            Some(FleetSpec { skus })
        }
    }
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec::homogeneous(GpuKind::H100x8)
    }
}

/// Workload tiers and their SLAs (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// Interactive-fast: TTFT < 1 s @ p95.
    IwF,
    /// Interactive-normal: TTFT < 1 min @ p95.
    IwN,
    /// Non-interactive: 24 h completion deadline, queued by the Queue Manager.
    Niw,
}

impl Tier {
    /// Every tier, in [`Tier::index`] order.
    pub const ALL: [Tier; 3] = [Tier::IwF, Tier::IwN, Tier::Niw];

    /// Dense index (position in [`Tier::ALL`]) for per-tier arrays.
    pub fn index(self) -> usize {
        match self {
            Tier::IwF => 0,
            Tier::IwN => 1,
            Tier::Niw => 2,
        }
    }

    /// True for the IW tiers (TTFT SLA); false for NIW (deadline only).
    pub fn is_interactive(self) -> bool {
        !matches!(self, Tier::Niw)
    }

    /// TTFT SLA in seconds (IW tiers) — §2.2.
    pub fn ttft_sla(self) -> Option<Time> {
        match self {
            Tier::IwF => Some(1.0),
            Tier::IwN => Some(60.0),
            Tier::Niw => None,
        }
    }

    /// Completion deadline for NIW (§6.2).
    pub fn deadline(self) -> Option<Time> {
        match self {
            Tier::Niw => Some(24.0 * HOUR),
            _ => None,
        }
    }
}

impl fmt::Display for Tier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Tier::IwF => "IW-F",
            Tier::IwN => "IW-N",
            Tier::Niw => "NIW",
        })
    }
}

/// Trace epochs characterized in §3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epoch {
    /// November 2024: ~1/5 the Jul-2025 load, no IW-F/IW-N split.
    Nov2024,
    /// July 2025: 5x growth, three tiers.
    Jul2025,
}

/// Provisioning and scaling constants (§2.3, §4, §6).
#[derive(Debug, Clone)]
pub struct ScalingParams {
    /// Reclaim a spot instance already hosting the same model type.
    pub spot_reclaim_secs: Time,
    /// Redeploy weights available in the local region repository.
    pub local_redeploy_secs: Time,
    /// Pull weights from a remote region.
    pub remote_redeploy_secs: Time,
    /// Reactive scale-out threshold on effective memory utilization.
    pub scale_out_util: f64,
    /// Reactive scale-in threshold.
    pub scale_in_util: f64,
    /// Cooldown between reactive scaling events (§4: 15 s).
    pub cooldown_secs: Time,
    /// Minimum instances per (model, region) endpoint.
    pub min_instances: usize,
    /// Maximum instances per (model, region).
    pub max_instances: usize,
    /// NIW release threshold: below this util, release 1 queued request.
    pub niw_release_util_1: f64,
    /// Below this util, release 2 queued requests.
    pub niw_release_util_2: f64,
    /// NIW age (secs) past which priority is upgraded to 0 (§6.2: 10 h).
    pub niw_aging_secs: Time,
    /// Decision epoch of the forecast + ILP controller (§6.3: hourly).
    pub control_interval: Time,
    /// LT-UA: continue scaling out if observed TPS >= this multiple of the
    /// forecast during the last 20 min of the hour (§6.4: 5x).
    pub ua_over_factor: f64,
    /// LT-UA: continue scaling in below this multiple (§6.4: 0.5x).
    pub ua_under_factor: f64,
    /// LT-UA: length of the end-of-hour correction window (20 min).
    pub ua_window: Time,
    /// Forecast headroom buffer beta = this fraction of last hour's NIW
    /// load (§6.3: 10%).
    pub niw_buffer_frac: f64,
    /// Fraction of a model-region's peak that must be serveable locally
    /// (§5's epsilon).
    pub epsilon: f64,
}

impl Default for ScalingParams {
    fn default() -> Self {
        ScalingParams {
            spot_reclaim_secs: 1.0 * MINUTE,
            local_redeploy_secs: 10.0 * MINUTE,
            remote_redeploy_secs: 2.0 * HOUR,
            scale_out_util: 0.70,
            scale_in_util: 0.30,
            cooldown_secs: 15.0,
            min_instances: 2,
            max_instances: 20,
            niw_release_util_1: 0.60,
            niw_release_util_2: 0.50,
            niw_aging_secs: 10.0 * HOUR,
            control_interval: HOUR,
            ua_over_factor: 5.0,
            ua_under_factor: 0.5,
            ua_window: 20.0 * MINUTE,
            niw_buffer_frac: 0.10,
            epsilon: 0.6,
        }
    }
}

/// Routing constants (§6.1), including the SKU-affinity policy the
/// heterogeneous-fleet router applies on top of region selection + JSQ.
#[derive(Debug, Clone)]
pub struct RoutingParams {
    /// Route to the first preferred region whose effective memory
    /// utilization is below this threshold (70% in production).
    pub region_util_threshold: f64,
    /// Mean inter-region network latency (§2.1: ~50 ms).
    pub inter_region_latency: Time,
    /// Enable SKU-aware routing: long-context requests steer to
    /// high-HBM SKUs, short interactive ones to the cheapest SKU with
    /// headroom, with a fallback cascade when the preferred SKU has no
    /// capacity.  On single-SKU fleets this is a no-op by construction
    /// (the router short-circuits to plain JSQ), so every homogeneous
    /// paper experiment is bit-identical either way.
    pub sku_affinity: bool,
    /// HBM threshold, in prompt+decode tokens: a request at or above it
    /// counts as *long-context* and prefers the fleet's highest-HBM SKU.
    /// 12 k tokens ≈ the top few percent of the Jul-2025 token CDF
    /// (RAG / doc-summary / eval tails).
    pub long_ctx_tokens: u64,
    /// Instance-level headroom test for the affinity cascade: an
    /// instance "has headroom" while (reserved KV + queued tokens) stays
    /// under this fraction of its KV capacity.
    pub sku_headroom_util: f64,
}

impl Default for RoutingParams {
    fn default() -> Self {
        RoutingParams {
            region_util_threshold: 0.70,
            inter_region_latency: 0.050,
            sku_affinity: true,
            long_ctx_tokens: 12_000,
            sku_headroom_util: 0.70,
        }
    }
}

/// Prefill/decode disaggregation parameters (ROADMAP item 2; the sageLLM
/// / OServe spatial-temporal split).  When enabled, every endpoint's
/// instances are partitioned into a prefill pool (sized against the TTFT
/// target) and a decode pool (sized against the ITL target); a completed
/// prefill hands its KV cache to a decode instance at an explicit
/// per-SKU transfer cost.  When disabled — the default — every instance
/// runs both phases (`Phase::Unified`) and **no disaggregation code path
/// executes**, so disagg-off runs are bit-identical to the
/// pre-disaggregation engine (guarded by `tests/disagg_equivalence.rs`,
/// the PR-7 empty-`FaultPlan` pattern).
#[derive(Debug, Clone, PartialEq)]
pub struct DisaggParams {
    /// Master switch.  `false` (default) keeps the unified engine.
    pub enabled: bool,
    /// Initial fraction of each endpoint's instances assigned to the
    /// prefill pool; the controller refines it each epoch from the
    /// per-phase capacity solves.
    pub prefill_fraction: f64,
    /// TTFT target (seconds) that gates prefill-pool sizing.
    pub ttft_target: Time,
    /// Inter-token-latency target (seconds/token) that gates decode-pool
    /// sizing.
    pub itl_target: Time,
}

impl DisaggParams {
    /// Disaggregation on, with the default pool split and SLO targets.
    pub fn enabled() -> Self {
        DisaggParams { enabled: true, ..DisaggParams::default() }
    }
}

impl Default for DisaggParams {
    fn default() -> Self {
        DisaggParams {
            enabled: false,
            prefill_fraction: 0.35,
            ttft_target: 1.0,
            itl_target: 0.2,
        }
    }
}

/// Control-plane guardrail parameters (the defense half of the
/// control-fault plane; see `coordinator::controller`'s guardrail
/// layer).  When enabled, every control epoch runs through a watchdog
/// (input-age stamping), a residual tracker (trailing forecast error →
/// θ safety margin, ROADMAP item 4) and the fallback cascade — fresh
/// ILP plan → held last-good plan with safety inflation → reactive
/// proportional control.  When disabled — the default — **no guardrail
/// code path executes**, so guardrail-off runs are bit-identical to the
/// pre-guardrail engine (guarded by `tests/guardrail_equivalence.rs`,
/// the empty-`FaultPlan` / disagg-off pattern).
#[derive(Debug, Clone, PartialEq)]
pub struct GuardrailParams {
    /// Master switch.  `false` (default) keeps the naive controller.
    pub enabled: bool,
    /// Watchdog tolerance: telemetry older than this (seconds) at epoch
    /// time trips the fallback cascade.
    pub max_telemetry_age: Time,
    /// Trailing residuals kept per (model, region) for the
    /// forecast-error variance estimate.
    pub residual_window: usize,
    /// θ margin per unit of residual standard deviation (the
    /// error-variance inflation gain).
    pub inflation_gain: f64,
    /// Hard cap on the θ margin (a fraction; 0.5 = at most 50% extra
    /// capacity commanded by the residual tracker).
    pub max_inflation: f64,
    /// Multiplier applied to the held last-good targets while on the
    /// middle cascade rung.
    pub held_inflation: f64,
    /// Control epochs the last-good plan may be held before the cascade
    /// drops to reactive control.
    pub max_held_epochs: u32,
}

impl GuardrailParams {
    /// Guardrails on, with the default watchdog/margin/cascade tuning.
    pub fn enabled() -> Self {
        GuardrailParams { enabled: true, ..GuardrailParams::default() }
    }
}

impl Default for GuardrailParams {
    fn default() -> Self {
        GuardrailParams {
            enabled: false,
            max_telemetry_age: 30.0 * MINUTE,
            residual_window: 24,
            inflation_gain: 1.0,
            max_inflation: 0.5,
            held_inflation: 1.25,
            max_held_epochs: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_index_roundtrip() {
        for r in Region::ALL {
            assert_eq!(Region::from_index(r.index()), r);
        }
    }

    #[test]
    fn model_index_matches_all_order() {
        for (i, m) in ModelKind::ALL.into_iter().enumerate() {
            assert_eq!(m.index(), i, "{m}");
        }
    }

    #[test]
    fn tier_slas_match_paper() {
        assert_eq!(Tier::IwF.ttft_sla(), Some(1.0));
        assert_eq!(Tier::IwN.ttft_sla(), Some(60.0));
        assert_eq!(Tier::Niw.ttft_sla(), None);
        assert_eq!(Tier::Niw.deadline(), Some(24.0 * 3600.0));
    }

    #[test]
    fn default_scaling_params_match_paper() {
        let p = ScalingParams::default();
        assert_eq!(p.scale_out_util, 0.70);
        assert_eq!(p.scale_in_util, 0.30);
        assert_eq!(p.cooldown_secs, 15.0);
        assert_eq!(p.local_redeploy_secs, 600.0);
        assert_eq!(p.remote_redeploy_secs, 7200.0);
        assert_eq!(p.ua_over_factor, 5.0);
        assert_eq!(p.ua_under_factor, 0.5);
    }

    #[test]
    fn gpu_index_roundtrip_and_parse() {
        for (i, g) in GpuKind::ALL.into_iter().enumerate() {
            assert_eq!(g.index(), i);
            assert_eq!(GpuKind::from_index(i), g);
        }
        assert_eq!(GpuKind::parse("h100"), Some(GpuKind::H100x8));
        assert_eq!(GpuKind::parse("8xA100"), Some(GpuKind::A100x8));
        assert_eq!(GpuKind::parse("MI300"), Some(GpuKind::Mi300x8));
        assert_eq!(GpuKind::parse("mi300x8"), Some(GpuKind::Mi300x8));
        assert_eq!(GpuKind::parse("tpu"), None);
    }

    #[test]
    fn sku_price_sheets_are_ordered() {
        // On-demand: A100 < MI300 < H100; spot mirrors the same order
        // (donated H100s are worth the most).
        assert!(GpuKind::A100x8.dollars_per_hour() < GpuKind::Mi300x8.dollars_per_hour());
        assert!(GpuKind::Mi300x8.dollars_per_hour() < GpuKind::H100x8.dollars_per_hour());
        assert!(GpuKind::A100x8.spot_dollars_per_hour() < GpuKind::Mi300x8.spot_dollars_per_hour());
        assert!(GpuKind::Mi300x8.spot_dollars_per_hour() < GpuKind::H100x8.spot_dollars_per_hour());
        for g in GpuKind::ALL {
            // Spot never pays more than on-demand costs, even at peak.
            assert!(
                g.spot_dollars_per_hour() * SpotMarket::PEAK < g.dollars_per_hour(),
                "{g}"
            );
        }
        // MI300 is the high-HBM class.
        assert!(GpuKind::Mi300x8.hbm_gib() > 2.0 * GpuKind::H100x8.hbm_gib());
    }

    #[test]
    fn spot_market_curve_is_diurnal_and_hour_constant() {
        // 03:00 is off-peak, 12:00 is peak; the multiplier is constant
        // within an hour and 24 h-periodic.
        assert_eq!(SpotMarket::multiplier(3.0 * HOUR), SpotMarket::OFF_PEAK);
        assert_eq!(SpotMarket::multiplier(12.0 * HOUR), SpotMarket::PEAK);
        assert_eq!(SpotMarket::multiplier(12.0 * HOUR + 1800.0), SpotMarket::PEAK);
        assert_eq!(
            SpotMarket::multiplier(12.0 * HOUR),
            SpotMarket::multiplier(12.0 * HOUR + 3.0 * DAY)
        );
        let p = SpotMarket::price(GpuKind::H100x8, 12.0 * HOUR);
        assert!((p - 44.0 * 1.25).abs() < 1e-9);
    }

    #[test]
    fn fleet_split_is_exact_and_deterministic() {
        let homo = FleetSpec::homogeneous(GpuKind::A100x8);
        assert_eq!(homo.split(7), vec![(GpuKind::A100x8, 7)]);
        assert!(homo.is_homogeneous());

        let mixed = FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]);
        assert_eq!(mixed.split(4), vec![(GpuKind::H100x8, 2), (GpuKind::A100x8, 2)]);
        // Odd totals: the tie goes to the earlier SKU.
        assert_eq!(mixed.split(5), vec![(GpuKind::H100x8, 3), (GpuKind::A100x8, 2)]);
        assert_eq!(mixed.split(0), vec![(GpuKind::H100x8, 0), (GpuKind::A100x8, 0)]);
        let lopsided = FleetSpec::mixed(&[(GpuKind::H100x8, 1.0), (GpuKind::A100x8, 3.0)]);
        assert_eq!(lopsided.split(8), vec![(GpuKind::H100x8, 2), (GpuKind::A100x8, 6)]);
        for total in 0..40 {
            let sum: usize = mixed.split(total).iter().map(|&(_, n)| n).sum();
            assert_eq!(sum, total);
        }
    }

    #[test]
    fn fleet_parse_accepts_names_and_weights() {
        assert_eq!(FleetSpec::parse("h100"), Some(FleetSpec::homogeneous(GpuKind::H100x8)));
        let mixed = FleetSpec::parse("mixed").unwrap();
        assert_eq!(mixed.gpus(), vec![GpuKind::H100x8, GpuKind::A100x8]);
        let custom = FleetSpec::parse("a100:0.75,h100:0.25").unwrap();
        assert_eq!(custom.primary(), GpuKind::A100x8);
        assert_eq!(custom.split(4), vec![(GpuKind::A100x8, 3), (GpuKind::H100x8, 1)]);
        assert_eq!(FleetSpec::parse("tpu"), None);
        assert_eq!(FleetSpec::parse("h100:0.5,h100:0.5"), None);
        // The MI300 class and the three-way fleet parse too.
        assert_eq!(
            FleetSpec::parse("mi300"),
            Some(FleetSpec::homogeneous(GpuKind::Mi300x8))
        );
        let three = FleetSpec::parse("mixed3").unwrap();
        assert_eq!(three, FleetSpec::mixed_3way());
        assert_eq!(
            three.gpus(),
            vec![GpuKind::H100x8, GpuKind::A100x8, GpuKind::Mi300x8]
        );
        assert_eq!(
            three.split(6),
            vec![(GpuKind::H100x8, 2), (GpuKind::A100x8, 2), (GpuKind::Mi300x8, 2)]
        );
        let custom = FleetSpec::parse("mi300:0.5,a100:0.5").unwrap();
        assert_eq!(custom.primary(), GpuKind::Mi300x8);
    }

    #[test]
    fn disagg_defaults_are_off_and_targets_match_tier_slas() {
        let d = DisaggParams::default();
        assert!(!d.enabled);
        assert!(d.prefill_fraction > 0.0 && d.prefill_fraction < 1.0);
        // The TTFT target mirrors the IW-F SLA; ITL is a streaming
        // smoothness target well under it.
        assert_eq!(Some(d.ttft_target), Tier::IwF.ttft_sla());
        assert!(d.itl_target < d.ttft_target);
        let on = DisaggParams::enabled();
        assert!(on.enabled);
        assert_eq!(on.prefill_fraction, d.prefill_fraction);
    }

    #[test]
    fn guardrail_defaults_are_off_and_sane() {
        let g = GuardrailParams::default();
        assert!(!g.enabled, "guardrails must default off (bit-identity gate)");
        // Watchdog tolerance sits between the telemetry bucket (15 min)
        // and the control interval (1 h): one stale bucket is normal,
        // a whole stale epoch is not.
        assert!(g.max_telemetry_age > 15.0 * MINUTE);
        assert!(g.max_telemetry_age < HOUR);
        assert!(g.residual_window > 0);
        assert!(g.inflation_gain >= 0.0);
        assert!(g.max_inflation > 0.0 && g.max_inflation <= 1.0);
        assert!(g.held_inflation >= 1.0, "holding must never shrink the plan");
        assert!(g.max_held_epochs >= 1);
        let on = GuardrailParams::enabled();
        assert!(on.enabled);
        assert_eq!(on.held_inflation, g.held_inflation);
    }

    #[test]
    fn display_names_stable() {
        assert_eq!(ModelKind::Bloom176B.to_string(), "bloom-176b");
        assert_eq!(Region::WestUs.to_string(), "westus");
        assert_eq!(Tier::IwF.to_string(), "IW-F");
        assert_eq!(GpuKind::H100x8.to_string(), "8xH100");
        assert_eq!(GpuKind::Mi300x8.to_string(), "8xMI300");
    }
}
