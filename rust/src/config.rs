//! Core domain types and experiment configuration.
//!
//! Everything the paper parameterizes lives here: regions, model types, GPU
//! SKUs, workload tiers and their SLAs, and the scaling/provisioning
//! constants of §2.3/§4/§6 (thresholds, cooldowns, redeploy delays).

use std::fmt;

/// Simulated/real time, in seconds since experiment start.
pub type Time = f64;

pub const MINUTE: Time = 60.0;
pub const HOUR: Time = 3600.0;
pub const DAY: Time = 86_400.0;
pub const WEEK: Time = 7.0 * DAY;

/// US data-center regions used throughout the paper (§2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Region {
    EastUs,
    CentralUs,
    WestUs,
}

impl Region {
    pub const ALL: [Region; 3] = [Region::EastUs, Region::CentralUs, Region::WestUs];

    pub fn index(self) -> usize {
        match self {
            Region::EastUs => 0,
            Region::CentralUs => 1,
            Region::WestUs => 2,
        }
    }

    pub fn from_index(i: usize) -> Region {
        Region::ALL[i]
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Region::EastUs => "eastus",
            Region::CentralUs => "centralus",
            Region::WestUs => "westus",
        };
        f.write_str(s)
    }
}

/// Open-source model types used in the evaluation (§7.1), plus the
/// Llama-4-Scout MoE added in the scalability test (§7.2.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    Bloom176B,
    Llama2_70B,
    Llama31_8B,
    Llama32_3B,
    Llama4Scout,
    /// The ~3M-parameter byte-level transformer actually served end-to-end
    /// through PJRT by `serve/` (examples/serve_model.rs).
    TinyLm,
}

impl ModelKind {
    /// Every model variant, in [`ModelKind::index`] order (dense-table
    /// iteration; guarded by a test).
    pub const ALL: [ModelKind; 6] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
        ModelKind::Llama4Scout,
        ModelKind::TinyLm,
    ];

    /// The four standard evaluation models (§7.1).
    pub const EVAL4: [ModelKind; 4] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
    ];

    /// EVAL4 plus the MoE model of the scalability test (§7.2.5).
    pub const EVAL5: [ModelKind; 5] = [
        ModelKind::Bloom176B,
        ModelKind::Llama2_70B,
        ModelKind::Llama31_8B,
        ModelKind::Llama32_3B,
        ModelKind::Llama4Scout,
    ];

    pub fn index(self) -> usize {
        match self {
            ModelKind::Bloom176B => 0,
            ModelKind::Llama2_70B => 1,
            ModelKind::Llama31_8B => 2,
            ModelKind::Llama32_3B => 3,
            ModelKind::Llama4Scout => 4,
            ModelKind::TinyLm => 5,
        }
    }
}

impl fmt::Display for ModelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ModelKind::Bloom176B => "bloom-176b",
            ModelKind::Llama2_70B => "llama2-70b",
            ModelKind::Llama31_8B => "llama3.1-8b",
            ModelKind::Llama32_3B => "llama3.2-3b",
            ModelKind::Llama4Scout => "llama4-scout",
            ModelKind::TinyLm => "tinylm",
        };
        f.write_str(s)
    }
}

/// GPU SKUs (§2.1).  One *instance* is a whole 8-GPU VM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuKind {
    H100x8,
    A100x8,
}

impl GpuKind {
    /// Number of SKUs — the dense per-SKU array width used by the
    /// cluster aggregates and ledgers.
    pub const COUNT: usize = 2;

    /// Every SKU, in [`GpuKind::index`] order.
    pub const ALL: [GpuKind; GpuKind::COUNT] = [GpuKind::H100x8, GpuKind::A100x8];

    pub fn index(self) -> usize {
        match self {
            GpuKind::H100x8 => 0,
            GpuKind::A100x8 => 1,
        }
    }

    pub fn from_index(i: usize) -> GpuKind {
        GpuKind::ALL[i]
    }

    /// CLI-friendly SKU name parsing.
    pub fn parse(s: &str) -> Option<GpuKind> {
        match s.to_ascii_lowercase().as_str() {
            "h100" | "h100x8" | "8xh100" => Some(GpuKind::H100x8),
            "a100" | "a100x8" | "8xa100" => Some(GpuKind::A100x8),
            _ => None,
        }
    }

    /// Total HBM per instance VM (GiB).
    pub fn hbm_gib(self) -> f64 {
        640.0 // 8 x 80 GB for both SKUs
    }

    /// On-demand $/hour for the 8-GPU VM (§7.2.1 quotes $98.32/h for H100).
    pub fn dollars_per_hour(self) -> f64 {
        match self {
            GpuKind::H100x8 => 98.32,
            GpuKind::A100x8 => 54.20,
        }
    }
}

impl fmt::Display for GpuKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            GpuKind::H100x8 => "8xH100",
            GpuKind::A100x8 => "8xA100",
        })
    }
}

/// GPU fleet composition for one run — the §5 SKU axis `k`.  The fleet
/// lists which SKUs the cluster may provision (the ILP's columns, the
/// per-SKU delta axis, the ledger keys) and what fraction of the initial
/// per-endpoint allocation each SKU hosts.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// `(SKU, initial-allocation weight)`, fleet order.  Weights are
    /// relative (normalized by their sum); SKUs must be distinct.
    pub skus: Vec<(GpuKind, f64)>,
}

impl FleetSpec {
    /// Single-SKU fleet — the paper's per-experiment assumption (§7.1)
    /// and the degenerate case every pre-heterogeneity experiment runs.
    pub fn homogeneous(gpu: GpuKind) -> Self {
        FleetSpec { skus: vec![(gpu, 1.0)] }
    }

    /// Multi-SKU fleet with explicit initial-allocation weights.
    pub fn mixed(skus: &[(GpuKind, f64)]) -> Self {
        assert!(!skus.is_empty(), "fleet needs at least one SKU");
        debug_assert!(
            skus.iter()
                .enumerate()
                .all(|(i, &(g, _))| skus[..i].iter().all(|&(h, _)| h != g)),
            "fleet SKUs must be distinct"
        );
        FleetSpec { skus: skus.to_vec() }
    }

    /// The SKUs available for provisioning, fleet order.
    pub fn gpus(&self) -> Vec<GpuKind> {
        self.skus.iter().map(|&(g, _)| g).collect()
    }

    pub fn is_homogeneous(&self) -> bool {
        self.skus.len() == 1
    }

    /// The first SKU — the default for single-SKU call sites.
    pub fn primary(&self) -> GpuKind {
        self.skus[0].0
    }

    /// Split `total` instances across the fleet by weight
    /// (largest-remainder apportionment; deterministic, sums to `total`,
    /// ties favour earlier SKUs).
    pub fn split(&self, total: usize) -> Vec<(GpuKind, usize)> {
        let weight: f64 = self.skus.iter().map(|&(_, w)| w).sum();
        let mut out: Vec<(GpuKind, usize)> =
            self.skus.iter().map(|&(g, _)| (g, 0)).collect();
        if weight <= 0.0 {
            out[0].1 = total;
            return out;
        }
        let mut rema: Vec<(usize, f64)> = Vec::with_capacity(self.skus.len());
        let mut assigned = 0usize;
        for (i, &(_, w)) in self.skus.iter().enumerate() {
            let share = total as f64 * w / weight;
            let base = share.floor() as usize;
            out[i].1 = base;
            assigned += base;
            rema.push((i, share - base as f64));
        }
        rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for k in 0..total.saturating_sub(assigned) {
            out[rema[k % rema.len()].0].1 += 1;
        }
        out
    }

    /// Parse a CLI fleet spec: a SKU name (`h100`, `a100`), `mixed`
    /// (50/50 H100+A100), or explicit weights (`h100:0.5,a100:0.5`).
    pub fn parse(s: &str) -> Option<FleetSpec> {
        match s.to_ascii_lowercase().as_str() {
            "h100" | "h100x8" | "8xh100" => return Some(FleetSpec::homogeneous(GpuKind::H100x8)),
            "a100" | "a100x8" | "8xa100" => return Some(FleetSpec::homogeneous(GpuKind::A100x8)),
            "mixed" => {
                return Some(FleetSpec::mixed(&[
                    (GpuKind::H100x8, 0.5),
                    (GpuKind::A100x8, 0.5),
                ]))
            }
            _ => {}
        }
        let mut skus = Vec::new();
        for part in s.split(',') {
            let (name, frac) = part.split_once(':')?;
            let gpu = GpuKind::parse(name.trim())?;
            let w: f64 = frac.trim().parse().ok()?;
            if !w.is_finite() || w < 0.0 || skus.iter().any(|&(g, _)| g == gpu) {
                return None;
            }
            skus.push((gpu, w));
        }
        if skus.is_empty() {
            None
        } else {
            Some(FleetSpec { skus })
        }
    }
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec::homogeneous(GpuKind::H100x8)
    }
}

/// Workload tiers and their SLAs (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// Interactive-fast: TTFT < 1 s @ p95.
    IwF,
    /// Interactive-normal: TTFT < 1 min @ p95.
    IwN,
    /// Non-interactive: 24 h completion deadline, queued by the Queue Manager.
    Niw,
}

impl Tier {
    pub const ALL: [Tier; 3] = [Tier::IwF, Tier::IwN, Tier::Niw];

    pub fn index(self) -> usize {
        match self {
            Tier::IwF => 0,
            Tier::IwN => 1,
            Tier::Niw => 2,
        }
    }

    pub fn is_interactive(self) -> bool {
        !matches!(self, Tier::Niw)
    }

    /// TTFT SLA in seconds (IW tiers) — §2.2.
    pub fn ttft_sla(self) -> Option<Time> {
        match self {
            Tier::IwF => Some(1.0),
            Tier::IwN => Some(60.0),
            Tier::Niw => None,
        }
    }

    /// Completion deadline for NIW (§6.2).
    pub fn deadline(self) -> Option<Time> {
        match self {
            Tier::Niw => Some(24.0 * HOUR),
            _ => None,
        }
    }
}

impl fmt::Display for Tier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Tier::IwF => "IW-F",
            Tier::IwN => "IW-N",
            Tier::Niw => "NIW",
        })
    }
}

/// Trace epochs characterized in §3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epoch {
    /// November 2024: ~1/5 the Jul-2025 load, no IW-F/IW-N split.
    Nov2024,
    /// July 2025: 5x growth, three tiers.
    Jul2025,
}

/// Provisioning and scaling constants (§2.3, §4, §6).
#[derive(Debug, Clone)]
pub struct ScalingParams {
    /// Reclaim a spot instance already hosting the same model type.
    pub spot_reclaim_secs: Time,
    /// Redeploy weights available in the local region repository.
    pub local_redeploy_secs: Time,
    /// Pull weights from a remote region.
    pub remote_redeploy_secs: Time,
    /// Reactive scale-out threshold on effective memory utilization.
    pub scale_out_util: f64,
    /// Reactive scale-in threshold.
    pub scale_in_util: f64,
    /// Cooldown between reactive scaling events (§4: 15 s).
    pub cooldown_secs: Time,
    /// Minimum instances per (model, region) endpoint.
    pub min_instances: usize,
    /// Maximum instances per (model, region).
    pub max_instances: usize,
    /// NIW release threshold: below this util, release 1 queued request.
    pub niw_release_util_1: f64,
    /// Below this util, release 2 queued requests.
    pub niw_release_util_2: f64,
    /// NIW age (secs) past which priority is upgraded to 0 (§6.2: 10 h).
    pub niw_aging_secs: Time,
    /// Decision epoch of the forecast + ILP controller (§6.3: hourly).
    pub control_interval: Time,
    /// LT-UA: continue scaling out if observed TPS >= this multiple of the
    /// forecast during the last 20 min of the hour (§6.4: 5x).
    pub ua_over_factor: f64,
    /// LT-UA: continue scaling in below this multiple (§6.4: 0.5x).
    pub ua_under_factor: f64,
    /// LT-UA: length of the end-of-hour correction window (20 min).
    pub ua_window: Time,
    /// Forecast headroom buffer beta = this fraction of last hour's NIW
    /// load (§6.3: 10%).
    pub niw_buffer_frac: f64,
    /// Fraction of a model-region's peak that must be serveable locally
    /// (§5's epsilon).
    pub epsilon: f64,
}

impl Default for ScalingParams {
    fn default() -> Self {
        ScalingParams {
            spot_reclaim_secs: 1.0 * MINUTE,
            local_redeploy_secs: 10.0 * MINUTE,
            remote_redeploy_secs: 2.0 * HOUR,
            scale_out_util: 0.70,
            scale_in_util: 0.30,
            cooldown_secs: 15.0,
            min_instances: 2,
            max_instances: 20,
            niw_release_util_1: 0.60,
            niw_release_util_2: 0.50,
            niw_aging_secs: 10.0 * HOUR,
            control_interval: HOUR,
            ua_over_factor: 5.0,
            ua_under_factor: 0.5,
            ua_window: 20.0 * MINUTE,
            niw_buffer_frac: 0.10,
            epsilon: 0.6,
        }
    }
}

/// Routing constants (§6.1).
#[derive(Debug, Clone)]
pub struct RoutingParams {
    /// Route to the first preferred region whose effective memory
    /// utilization is below this threshold (70% in production).
    pub region_util_threshold: f64,
    /// Mean inter-region network latency (§2.1: ~50 ms).
    pub inter_region_latency: Time,
}

impl Default for RoutingParams {
    fn default() -> Self {
        RoutingParams { region_util_threshold: 0.70, inter_region_latency: 0.050 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_index_roundtrip() {
        for r in Region::ALL {
            assert_eq!(Region::from_index(r.index()), r);
        }
    }

    #[test]
    fn model_index_matches_all_order() {
        for (i, m) in ModelKind::ALL.into_iter().enumerate() {
            assert_eq!(m.index(), i, "{m}");
        }
    }

    #[test]
    fn tier_slas_match_paper() {
        assert_eq!(Tier::IwF.ttft_sla(), Some(1.0));
        assert_eq!(Tier::IwN.ttft_sla(), Some(60.0));
        assert_eq!(Tier::Niw.ttft_sla(), None);
        assert_eq!(Tier::Niw.deadline(), Some(24.0 * 3600.0));
    }

    #[test]
    fn default_scaling_params_match_paper() {
        let p = ScalingParams::default();
        assert_eq!(p.scale_out_util, 0.70);
        assert_eq!(p.scale_in_util, 0.30);
        assert_eq!(p.cooldown_secs, 15.0);
        assert_eq!(p.local_redeploy_secs, 600.0);
        assert_eq!(p.remote_redeploy_secs, 7200.0);
        assert_eq!(p.ua_over_factor, 5.0);
        assert_eq!(p.ua_under_factor, 0.5);
    }

    #[test]
    fn gpu_index_roundtrip_and_parse() {
        for (i, g) in GpuKind::ALL.into_iter().enumerate() {
            assert_eq!(g.index(), i);
            assert_eq!(GpuKind::from_index(i), g);
        }
        assert_eq!(GpuKind::parse("h100"), Some(GpuKind::H100x8));
        assert_eq!(GpuKind::parse("8xA100"), Some(GpuKind::A100x8));
        assert_eq!(GpuKind::parse("tpu"), None);
    }

    #[test]
    fn fleet_split_is_exact_and_deterministic() {
        let homo = FleetSpec::homogeneous(GpuKind::A100x8);
        assert_eq!(homo.split(7), vec![(GpuKind::A100x8, 7)]);
        assert!(homo.is_homogeneous());

        let mixed = FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]);
        assert_eq!(mixed.split(4), vec![(GpuKind::H100x8, 2), (GpuKind::A100x8, 2)]);
        // Odd totals: the tie goes to the earlier SKU.
        assert_eq!(mixed.split(5), vec![(GpuKind::H100x8, 3), (GpuKind::A100x8, 2)]);
        assert_eq!(mixed.split(0), vec![(GpuKind::H100x8, 0), (GpuKind::A100x8, 0)]);
        let lopsided = FleetSpec::mixed(&[(GpuKind::H100x8, 1.0), (GpuKind::A100x8, 3.0)]);
        assert_eq!(lopsided.split(8), vec![(GpuKind::H100x8, 2), (GpuKind::A100x8, 6)]);
        for total in 0..40 {
            let sum: usize = mixed.split(total).iter().map(|&(_, n)| n).sum();
            assert_eq!(sum, total);
        }
    }

    #[test]
    fn fleet_parse_accepts_names_and_weights() {
        assert_eq!(FleetSpec::parse("h100"), Some(FleetSpec::homogeneous(GpuKind::H100x8)));
        let mixed = FleetSpec::parse("mixed").unwrap();
        assert_eq!(mixed.gpus(), vec![GpuKind::H100x8, GpuKind::A100x8]);
        let custom = FleetSpec::parse("a100:0.75,h100:0.25").unwrap();
        assert_eq!(custom.primary(), GpuKind::A100x8);
        assert_eq!(custom.split(4), vec![(GpuKind::A100x8, 3), (GpuKind::H100x8, 1)]);
        assert_eq!(FleetSpec::parse("tpu"), None);
        assert_eq!(FleetSpec::parse("h100:0.5,h100:0.5"), None);
    }

    #[test]
    fn display_names_stable() {
        assert_eq!(ModelKind::Bloom176B.to_string(), "bloom-176b");
        assert_eq!(Region::WestUs.to_string(), "westus");
        assert_eq!(Tier::IwF.to_string(), "IW-F");
        assert_eq!(GpuKind::H100x8.to_string(), "8xH100");
    }
}
