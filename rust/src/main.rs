//! `sageserve` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; this build is offline, no clap):
//!
//! ```text
//! sageserve exp <id|all> [--out DIR] [--scale F] [--pjrt] [--seed N]
//! sageserve simulate --strategy S [--days F] [--scale F] [--epoch E] [--policy P]
//!                    [--fleet SPEC] [--routing sku-aware|blind]
//!                    [--metrics streaming|exact] [--pjrt] [--faults PLAN]
//!                    [--control-faults PLAN] [--guardrails]
//!                    [--chunked] [--chunk-epochs N] [--chunk-workers N]
//!                    [--disagg] [--ttft-target S] [--itl-target S]
//! sageserve serve [--requests N] [--max-new N] [--artifacts DIR]
//! sageserve trace --out FILE [--days F] [--scale F] [--epoch E]
//! sageserve selftest [--artifacts DIR]
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

use sageserve::config::Epoch;
use sageserve::coordinator::scheduler::SchedPolicy;
use sageserve::experiments::{self, ExpOptions};
use sageserve::metrics::MetricsMode;
use sageserve::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use sageserve::sim::engine::{run_simulation, SimConfig, Strategy};
use sageserve::trace::generator::{TraceConfig, TraceGenerator};
use sageserve::trace::io::write_csv;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Split args into (positional, flags).  Flags take one value unless
/// boolean (`--pjrt`).
fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let bools = ["--pjrt", "--chunked", "--disagg", "--guardrails"];
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if bools.contains(&a.as_str()) {
                flags.insert(name.to_string(), "true".to_string());
            } else if i + 1 < args.len() {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), String::new());
            }
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    (pos, flags)
}

fn parse_epoch(s: &str) -> Result<Epoch> {
    match s {
        "jul2025" | "jul" => Ok(Epoch::Jul2025),
        "nov2024" | "nov" => Ok(Epoch::Nov2024),
        other => bail!("unknown epoch '{other}' (jul2025|nov2024)"),
    }
}

fn parse_policy(s: &str) -> Result<SchedPolicy> {
    Ok(match s {
        "fcfs" => SchedPolicy::Fcfs,
        "edf" => SchedPolicy::Edf,
        "pf" => SchedPolicy::Pf,
        "dpa" => SchedPolicy::dpa_default(),
        other => bail!("unknown policy '{other}' (fcfs|edf|pf|dpa)"),
    })
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    let (pos, flags) = parse_flags(rest);
    let f = |k: &str| flags.get(k).cloned();
    let ff = |k: &str, d: f64| -> Result<f64> {
        match flags.get(k) {
            Some(v) => v.parse::<f64>().with_context(|| format!("--{k} {v}")),
            None => Ok(d),
        }
    };

    match cmd.as_str() {
        "exp" => {
            let id = pos.first().cloned().unwrap_or_else(|| "all".to_string());
            let mut opts = ExpOptions::default();
            if let Some(o) = f("out") {
                opts.out_dir = o.into();
            }
            opts.scale = ff("scale", opts.scale)?;
            opts.pjrt = flags.contains_key("pjrt");
            if let Some(a) = f("artifacts") {
                opts.artifacts_dir = a;
            }
            if let Some(s) = f("seed") {
                opts.seed = s.parse()?;
            }
            experiments::run(&id, &opts)
        }
        "simulate" => {
            let strategy = match f("strategy") {
                Some(s) => Strategy::parse(&s)
                    .with_context(|| format!("unknown strategy '{s}'"))?,
                None => Strategy::LtUa,
            };
            let mut cfg = SimConfig {
                strategy,
                pjrt_forecaster: flags.contains_key("pjrt"),
                ..Default::default()
            };
            cfg.trace.days = ff("days", 1.0)?;
            cfg.trace.scale = ff("scale", 0.02)?;
            if let Some(e) = f("epoch") {
                cfg.trace.epoch = parse_epoch(&e)?;
            }
            if let Some(p) = f("policy") {
                cfg.sched_policy = parse_policy(&p)?;
            }
            if let Some(v) = f("fleet") {
                cfg.fleet = sageserve::config::FleetSpec::parse(&v).with_context(|| {
                    format!(
                        "unknown fleet '{v}' (h100|a100|mi300|mixed|mixed3 or \
                         h100:0.5,mi300:0.5)"
                    )
                })?;
            }
            if let Some(r) = f("routing") {
                cfg.routing.sku_affinity = match r.as_str() {
                    "sku" | "sku-aware" | "aware" => true,
                    "blind" | "sku-blind" => false,
                    other => bail!("unknown routing policy '{other}' (sku-aware|blind)"),
                };
            }
            if let Some(m) = f("metrics") {
                cfg.metrics.mode = match m.as_str() {
                    "streaming" | "stream" => MetricsMode::Streaming,
                    "exact" => MetricsMode::Exact,
                    other => bail!("unknown metrics mode '{other}' (streaming|exact)"),
                };
            }
            if let Some(a) = f("artifacts") {
                cfg.artifacts_dir = a;
            }
            if let Some(t) = f("replay") {
                cfg.replay_trace = Some(t.into());
            }
            if flags.contains_key("disagg") {
                cfg.disagg = sageserve::config::DisaggParams::enabled();
            }
            if let Some(t) = f("ttft-target") {
                cfg.disagg.ttft_target = t.parse().with_context(|| format!("--ttft-target {t}"))?;
            }
            if let Some(t) = f("itl-target") {
                cfg.disagg.itl_target = t.parse().with_context(|| format!("--itl-target {t}"))?;
            }
            if let Some(spec) = f("faults") {
                // The parser's error already names the offending clause;
                // the context line lists the grammar.
                cfg.faults = sageserve::sim::FaultPlan::parse(&spec)
                    .map_err(|e| anyhow::anyhow!(e))
                    .with_context(|| {
                        format!(
                            "bad fault spec '{spec}' (clauses: \
                             region-dark=<region>@<start>-<end>; \
                             degrade=<region>@<start>-<end>:<extra>; \
                             spot-shock=<frac>@<t>; crash=<per-day-rate>; \
                             retry=<base>/<max>/<attempts>; times take s/m/h/d suffixes)"
                        )
                    })?;
            }
            if let Some(spec) = f("control-faults") {
                cfg.control_faults = sageserve::sim::ControlFaultPlan::parse(&spec)
                    .map_err(|e| anyhow::anyhow!(e))
                    .with_context(|| {
                        format!(
                            "bad control-fault spec '{spec}' (clauses: \
                             forecast-blackout=<start>-<end>; \
                             forecast-corrupt=<scale>@<start>-<end>[:<bias>]; \
                             telemetry-freeze=<start>-<end>; \
                             solver-fail=<start>-<end>; act-drop=<start>-<end>; \
                             act-delay=<extra>@<start>-<end>; times take s/m/h/d suffixes)"
                        )
                    })?;
            }
            if flags.contains_key("guardrails") {
                cfg.guardrails = sageserve::config::GuardrailParams::enabled();
            }
            println!(
                "simulating {} day(s) at scale {} with strategy {} on fleet [{}] ...",
                cfg.trace.days,
                cfg.trace.scale,
                strategy.name(),
                cfg.fleet
                    .gpus()
                    .iter()
                    .map(|g| g.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            let sim = if flags.contains_key("chunked") {
                // Epoch-sliced execution: pipelined generation, O(chunk)
                // peak memory, bit-identical results.
                let opts = ChunkedOptions {
                    chunk_epochs: ff("chunk-epochs", 3.0)? as usize,
                    workers: ff("chunk-workers", 0.0)? as usize,
                };
                run_simulation_chunked(cfg, &opts)
            } else {
                run_simulation(cfg)
            };
            report_simulation(&sim);
            Ok(())
        }
        "serve" => {
            use sageserve::runtime::tinylm::TinyLm;
            use sageserve::serve::{synthetic_requests, Server};
            let artifacts = f("artifacts").unwrap_or_else(|| "artifacts".to_string());
            let n = ff("requests", 32.0)? as usize;
            let max_new = ff("max-new", 32.0)? as usize;
            let model = TinyLm::load(&artifacts)
                .context("load tinylm artifacts (run `make artifacts`)")?;
            println!(
                "serving {n} byte-level requests on the PJRT-compiled transformer \
                 (B={}, S={}, M={}) ...",
                model.cfg.batch, model.cfg.prefill_len, model.cfg.max_len
            );
            let mut server = Server::new(model, SchedPolicy::Edf);
            let outcomes = server.serve(synthetic_requests(n, 7, max_new))?;
            let summary = Server::latency_summary(&outcomes);
            println!(
                "served {} requests: mean TTFT {:.3}s p95 TTFT {:.3}s mean E2E {:.3}s p95 E2E {:.3}s",
                summary.count, summary.mean_ttft, summary.ttft_p95, summary.mean_e2e, summary.e2e_p95
            );
            println!(
                "decode throughput {:.0} tok/s; prefill R² {:.3}, decode R² {:.3}",
                server.decode_throughput(),
                server.phase_r2("prefill").unwrap_or(f64::NAN),
                server.phase_r2("decode").unwrap_or(f64::NAN),
            );
            Ok(())
        }
        "trace" => {
            let out = f("out").context("--out FILE required")?;
            let mut cfg = TraceConfig::default();
            cfg.days = ff("days", 1.0)?;
            cfg.scale = ff("scale", 0.01)?;
            if let Some(e) = f("epoch") {
                cfg.epoch = parse_epoch(&e)?;
            }
            if let Some(s) = f("seed") {
                cfg.seed = s.parse()?;
            }
            let gen = TraceGenerator::new(cfg);
            let n = write_csv(&out, gen.stream())?;
            println!("wrote {n} requests to {out}");
            Ok(())
        }
        "selftest" => {
            let artifacts = f("artifacts").unwrap_or_else(|| "artifacts".to_string());
            sageserve::runtime::selftest::run(&artifacts)
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `sageserve help`)"),
    }
}

fn report_simulation(sim: &sageserve::sim::engine::Simulation) {
    use sageserve::config::Tier;
    let end = sim.end_time();
    println!("completed {} requests ({} dropped)", sim.metrics.completed, sim.metrics.dropped);
    for tier in Tier::ALL {
        let s = sim.metrics.latency_by_tier(tier);
        if s.count == 0 {
            continue;
        }
        println!(
            "  {tier}: n={} ttft p50/p95 {:.2}/{:.2}s e2e p95 {:.2}s sla-viol {:.1}%",
            s.count,
            s.ttft_p50,
            s.ttft_p95,
            s.e2e_p95,
            s.sla_violation_rate * 100.0
        );
    }
    // Per (model, tier) cells in one grouping pass (multi-model runs).
    if sim.cfg.trace.models.len() > 1 {
        for ((m, tier), s) in &sim.metrics.latency_by_model_tier_all() {
            println!(
                "    {m}/{tier}: n={} ttft p95 {:.2}s e2e p95 {:.2}s",
                s.count, s.ttft_p95, s.e2e_p95
            );
        }
    }
    let mut total_ih = 0.0;
    for &m in &sim.cfg.trace.models {
        let ih = sim.metrics.model_instance_hours(m, end);
        total_ih += ih;
        println!("  {m}: {ih:.1} instance-hours, mean util {:.2}", sim.metrics.mean_util(m));
    }
    println!(
        "  total {total_ih:.1} instance-hours; scaling waste {:.2} GPU-h over {} events; \
         spot donated {:.1} inst-h",
        sim.metrics.scaling_waste.total_gpu_hours(),
        sim.metrics.scaling_waste.total_events(),
        sim.metrics.spot_hours(end),
    );
    // Disaggregation accounting (all-zero — and silent — on unified runs).
    if sim.metrics.handoffs > 0 {
        println!(
            "  disagg: {} handoffs ({} admitted, {} dropped), {:.1}s KV transfer; \
             TTFT attainment {:.2}% @ {:.2}s, ITL attainment {:.2}% @ {:.3}s",
            sim.metrics.handoffs,
            sim.metrics.handoff_admissions,
            sim.metrics.handoff_drops,
            sim.metrics.kv_transfer_secs,
            sim.metrics.ttft_attainment(sim.cfg.disagg.ttft_target) * 100.0,
            sim.cfg.disagg.ttft_target,
            sim.metrics.itl_attainment(sim.cfg.disagg.itl_target) * 100.0,
            sim.cfg.disagg.itl_target,
        );
    }
    // Failure accounting (all-zero — and silent — on fault-free runs).
    let fails = &sim.metrics.failures;
    if fails.killed_total() + fails.lost_total() + fails.shed_total() > 0 {
        println!(
            "  faults: {} killed, {} retried, {} lost, {} shed (NIW); \
             retry amplification {:.3}; {} incident(s)",
            fails.killed_total(),
            fails.retries,
            fails.lost_total(),
            fails.shed_total(),
            fails.retry_amplification(sim.metrics.completed),
            fails.incidents.len(),
        );
        for inc in &fails.incidents {
            let ttr = inc
                .time_to_recover()
                .map_or("not recovered".into(), |t| format!("recovered in {t:.0}s"));
            println!("    {} in {} at t={:.0}s: {ttr}", inc.kind, inc.region, inc.start);
        }
    }
    // Control-plane guardrail accounting (all-zero — and silent — when
    // no control-fault schedule ran and the guardrails were off).
    let g = &sim.metrics.guardrails;
    if !g.is_empty() {
        println!(
            "  guardrails: {} fresh / {} held / {} reactive epoch(s); \
             degraded {:.0}s; exposure {} blackout, {} corrupt, {} stale, \
             {} solver-fault epoch(s); {} actuation(s) dropped, {} delayed; \
             safety margin {:.1} instance-hours",
            g.epochs_fresh,
            g.epochs_held,
            g.epochs_reactive,
            g.degraded_secs,
            g.blackout_epochs,
            g.corrupt_epochs,
            g.stale_epochs,
            g.solver_fault_epochs,
            g.actuations_dropped,
            g.actuations_delayed,
            g.margin_instance_hours,
        );
        for t in &g.transitions {
            println!(
                "    t={:.0}s: {} -> {} ({})",
                t.at,
                t.from.name(),
                t.to.name(),
                t.cause
            );
        }
    }
    // Per-SKU GPU-hours and the spot-vs-on-demand cost split (the
    // heterogeneous-fleet view).
    let by_sku = sim.metrics.gpu_hours_by_sku(end);
    if !by_sku.is_empty() {
        let parts: Vec<String> =
            by_sku.iter().map(|(g, h)| format!("{g} {h:.1} GPU-h")).collect();
        println!(
            "  fleet: {}; on-demand ${:.0}, spot revenue ${:.0}, net ${:.0}",
            parts.join(", "),
            sim.metrics.fleet_dollar_cost(end),
            sim.metrics.spot_revenue(end),
            sim.metrics.net_fleet_cost(end)
        );
    }
}

fn print_help() {
    println!(
        "sageserve — forecast-aware LLM serving (SageServe reproduction)

USAGE:
  sageserve exp <id|all> [--out DIR] [--scale F] [--pjrt] [--seed N]
      regenerate paper figures/tables ({} ids; see DESIGN.md §5)
  sageserve simulate [--strategy siloed|reactive|lt-i|lt-u|lt-ua|chiron]
      [--days F] [--scale F] [--epoch jul2025|nov2024] [--policy fcfs|edf|pf|dpa]
      [--fleet h100|a100|mi300|mixed|mixed3|h100:W,mi300:W]
      [--routing sku-aware|blind] [--metrics streaming|exact]
      [--pjrt] [--replay trace.csv] [--faults PLAN]
      [--control-faults PLAN] [--guardrails]
      [--chunked] [--chunk-epochs N] [--chunk-workers N]
      [--disagg] [--ttft-target S] [--itl-target S]
      (--fleet picks the GPU fleet; mixed fleets report per-SKU GPU-hours,
       on-demand cost, spot revenue and net cost; --routing toggles
       per-request SKU affinity — see also `exp hetero`; --metrics exact
       keeps the O(requests) per-request outcome log instead of the
       default O(bins) streaming accumulators; --chunked runs the
       epoch-sliced executor — generation pipelined on worker threads,
       peak memory O(chunk), results bit-identical to the default engine;
       --faults injects a deterministic fault schedule, `;`-separated
       clauses: region-dark=centralus@2d-2.5d, degrade=eastus@1d-2d:0.5,
       spot-shock=0.6@3d, crash=1.0, retry=1s/60s/5 — see `exp faults`;
       --control-faults injects a deterministic *control-plane* fault
       schedule (windows, no events), `;`-separated clauses:
       forecast-blackout=2d-3d, forecast-corrupt=0.5@2d-3d:100,
       telemetry-freeze=2d-3d, solver-fail=2d-3d, act-drop=2d-3d,
       act-delay=120s@2d-3d; --guardrails arms the watchdog + residual
       tracker + fallback cascade for forecast-driven strategies — see
       `exp guardrails`;
       --disagg splits each endpoint into prefill/decode pools with an
       explicit KV-cache handoff, sized per control epoch against the
       TTFT/ITL targets — see `exp disagg`)
  sageserve serve [--requests N] [--max-new N] [--artifacts DIR]
      real batched inference on the AOT transformer via PJRT
  sageserve trace --out FILE [--days F] [--scale F] [--epoch E] [--seed N]
      emit a synthetic workload trace (CSV)
  sageserve selftest [--artifacts DIR]
      verify the PJRT artifacts against golden outputs",
        experiments::ALL_EXPERIMENTS.len()
    );
}
