//! The §5 optimization stack, built from scratch:
//!
//! * [`bounded`] — the production LP core: bounded-variable primal/dual
//!   simplex with a persistent, warm-startable [`SimplexState`] (rhs swaps
//!   and bound tightenings reuse the factorized basis).
//! * [`ilp`] — branch-and-bound integer programming: the incremental
//!   bounded path (nodes are bound tightenings over one shared tableau,
//!   warm dual re-solves) plus the original dense path kept as the
//!   equivalence oracle.
//! * [`simplex`] — the dense two-phase primal simplex the oracle runs on.
//! * [`capacity`] — the SageServe instance-allocation problem: builds one
//!   ILP per model (the formulation decouples across models — no
//!   constraint in §5 couples different `i`) and returns the δ_{i,j,k}
//!   instance-count changes.  [`CapacitySolver`] carries per-model warm
//!   state across control epochs.

pub mod bounded;
pub mod capacity;
pub mod ilp;
pub mod simplex;

pub use bounded::{solve_bounded, BoundedLp, BoundedOutcome, SimplexState};
pub use capacity::{
    optimize_capacity, optimize_capacity_dense, optimize_capacity_warm,
    optimize_capacity_warm_faulted, perturb_inputs, synthetic_inputs, CapacityInputs,
    CapacityPlan, CapacitySolver,
};
pub use ilp::{
    solve_ilp, solve_ilp_bounded, solve_ilp_bounded_with, solve_ilp_counted, BoundedIntLinProg,
    IlpLimits, IlpStats, IntLinProg,
};
pub use simplex::{Cmp, LinProg, LpOutcome};
