//! The §5 optimization stack, built from scratch:
//!
//! * [`simplex`] — dense two-phase primal simplex with Bland's rule.
//! * [`ilp`] — branch-and-bound integer programming on top of the LP
//!   relaxation.
//! * [`capacity`] — the SageServe instance-allocation problem: builds one
//!   ILP per model (the formulation decouples across models — no
//!   constraint in §5 couples different `i`) and returns the δ_{i,j,k}
//!   instance-count changes.

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

pub mod capacity;
pub mod ilp;
pub mod simplex;

pub use capacity::{CapacityInputs, CapacityPlan, optimize_capacity};
pub use ilp::{solve_ilp, IlpLimits, IntLinProg};
pub use simplex::{Cmp, LinProg, LpOutcome};
