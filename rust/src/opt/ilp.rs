//! Branch-and-bound integer programming over the simplex LP relaxation.
//!
//! Depth-first with best-incumbent pruning and most-fractional branching.
//! The capacity problems this solves are small and near-integral (network
//! structure), so the tree rarely exceeds a handful of nodes.

use crate::opt::simplex::{solve, Cmp, LinProg, LpOutcome};

/// An LP plus the set of variables required to be integral.
#[derive(Debug, Clone)]
pub struct IntLinProg {
    pub lp: LinProg,
    pub int_vars: Vec<usize>,
}

/// Search limits (defense against pathological instances).
#[derive(Debug, Clone, Copy)]
pub struct IlpLimits {
    pub max_nodes: usize,
    /// Relative optimality gap: a node is pruned when its relaxation
    /// cannot beat the incumbent by more than `gap·|incumbent|` (the same
    /// default class commercial MIP solvers use).
    pub gap: f64,
}

impl Default for IlpLimits {
    fn default() -> Self {
        IlpLimits { max_nodes: 20_000, gap: 1e-4 }
    }
}

const INT_TOL: f64 = 1e-6;

/// Solve the ILP; returns (x, objective) or None if infeasible / node
/// limit exhausted without an incumbent.
pub fn solve_ilp(problem: &IntLinProg, limits: IlpLimits) -> Option<(Vec<f64>, f64)> {
    // Each node = extra bound rows appended to the base LP.
    let mut stack: Vec<Vec<(Vec<f64>, Cmp, f64)>> = vec![vec![]];
    // Seed the incumbent by rounding the root relaxation *up* (covering
    // structure ⇒ usually feasible) and re-solving with the integers
    // pinned — one extra LP that prunes most of the tree.
    let mut incumbent: Option<(Vec<f64>, f64)> = root_rounding_incumbent(problem);
    let mut nodes = 0usize;

    while let Some(extra) = stack.pop() {
        nodes += 1;
        if nodes > limits.max_nodes {
            break;
        }
        let mut lp = problem.lp.clone();
        lp.rows.extend(extra.iter().cloned());
        let (x, obj) = match solve(&lp) {
            LpOutcome::Optimal { x, obj } => (x, obj),
            _ => continue, // infeasible or unbounded branch
        };
        if let Some((_, best)) = &incumbent {
            let tol = (limits.gap * best.abs()).max(1e-9);
            if obj >= *best - tol {
                continue; // bound: can't meaningfully beat the incumbent
            }
        }
        // Most-fractional branching variable.
        let mut branch: Option<(usize, f64, f64)> = None; // (var, value, frac dist)
        for &v in &problem.int_vars {
            let frac = (x[v] - x[v].round()).abs();
            if frac > INT_TOL {
                let dist = (x[v].fract() - 0.5).abs();
                match branch {
                    None => branch = Some((v, x[v], dist)),
                    Some((_, _, bd)) if dist < bd => branch = Some((v, x[v], dist)),
                    _ => {}
                }
            }
        }
        match branch {
            None => {
                // Integral: round cleanly and accept as incumbent.
                let mut xi = x;
                for &v in &problem.int_vars {
                    xi[v] = xi[v].round();
                }
                let obj = problem.lp.c.iter().zip(&xi).map(|(c, v)| c * v).sum();
                match &incumbent {
                    None => incumbent = Some((xi, obj)),
                    Some((_, best)) if obj < *best => incumbent = Some((xi, obj)),
                    _ => {}
                }
            }
            Some((v, val, _)) => {
                let mut unit = vec![0.0; problem.lp.n];
                unit[v] = 1.0;
                // x_v <= floor
                let mut lo = extra.clone();
                lo.push((unit.clone(), Cmp::Le, val.floor()));
                // x_v >= ceil
                let mut hi = extra;
                hi.push((unit, Cmp::Ge, val.ceil()));
                // DFS: push the branch nearer the LP value last (explored
                // first) to find good incumbents early.
                if val.fract() < 0.5 {
                    stack.push(hi);
                    stack.push(lo);
                } else {
                    stack.push(lo);
                    stack.push(hi);
                }
            }
        }
    }
    incumbent
}

/// Solve the root LP, round every integer variable up (ceil), and
/// re-solve with them pinned.  For covering-style problems (all the
/// capacity instances) the rounded point is feasible, giving B&B a strong
/// initial bound at the cost of two LP solves.
fn root_rounding_incumbent(problem: &IntLinProg) -> Option<(Vec<f64>, f64)> {
    let root = match solve(&problem.lp) {
        LpOutcome::Optimal { x, .. } => x,
        _ => return None,
    };
    let mut lp = problem.lp.clone();
    for &v in &problem.int_vars {
        let mut unit = vec![0.0; lp.n];
        unit[v] = 1.0;
        lp.rows.push((unit, Cmp::Eq, root[v].ceil()));
    }
    match solve(&lp) {
        LpOutcome::Optimal { x, obj } => Some((x, obj)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_like() {
        // max 5a + 4b s.t. 6a + 4b <= 24, a + 2b <= 6, integer.
        // LP optimum (3, 1.5) → -21; ILP optimum is a=4, b=0 → -20.
        let p = IntLinProg {
            lp: LinProg {
                n: 2,
                c: vec![-5.0, -4.0],
                rows: vec![
                    (vec![6.0, 4.0], Cmp::Le, 24.0),
                    (vec![1.0, 2.0], Cmp::Le, 6.0),
                ],
            },
            int_vars: vec![0, 1],
        };
        let (x, obj) = solve_ilp(&p, IlpLimits::default()).unwrap();
        assert_eq!((x[0].round() as i64, x[1].round() as i64), (4, 0));
        assert!((obj + 20.0).abs() < 1e-6);
    }

    #[test]
    fn already_integral_lp() {
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![(vec![1.0], Cmp::Ge, 3.0)],
            },
            int_vars: vec![0],
        };
        let (x, obj) = solve_ilp(&p, IlpLimits::default()).unwrap();
        assert_eq!(x[0], 3.0);
        assert!((obj - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_lp_rounds_up_for_covering() {
        // min x s.t. 3x >= 10 → LP 3.33, ILP 4.
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![(vec![3.0], Cmp::Ge, 10.0)],
            },
            int_vars: vec![0],
        };
        let (x, _) = solve_ilp(&p, IlpLimits::default()).unwrap();
        assert_eq!(x[0], 4.0);
    }

    #[test]
    fn infeasible_returns_none() {
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![
                    (vec![1.0], Cmp::Le, 1.0),
                    (vec![1.0], Cmp::Ge, 2.0),
                ],
            },
            int_vars: vec![0],
        };
        assert!(solve_ilp(&p, IlpLimits::default()).is_none());
    }

    #[test]
    fn mixed_integer_keeps_continuous_free() {
        // min x + y s.t. x + y >= 2.5, x integer, y continuous.
        let p = IntLinProg {
            lp: LinProg {
                n: 2,
                c: vec![1.0, 1.0],
                rows: vec![(vec![1.0, 1.0], Cmp::Ge, 2.5)],
            },
            int_vars: vec![0],
        };
        let (x, obj) = solve_ilp(&p, IlpLimits::default()).unwrap();
        assert!((obj - 2.5).abs() < 1e-6);
        assert!((x[0] - x[0].round()).abs() < 1e-9);
    }
}
