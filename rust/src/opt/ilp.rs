//! Branch-and-bound integer programming over the simplex LP relaxation.
//!
//! Depth-first with best-incumbent pruning and most-fractional branching.
//! The capacity problems this solves are small and near-integral (network
//! structure), so the tree rarely exceeds a handful of nodes.
//!
//! Two implementations live here:
//!
//! * [`solve_ilp_bounded_with`] — the production path.  Nodes are
//!   per-variable **bound tightenings** applied to one persistent
//!   [`SimplexState`] tableau; each node re-solves warm via the dual
//!   simplex from whatever basis the previous node left behind (cold
//!   fallback is automatic), and the root-rounding incumbent pins
//!   integers through bounds instead of appending `Eq` rows (which would
//!   force a fresh phase 1).  Nodes whose parent relaxation already
//!   cannot beat the incumbent are discarded *without* an LP solve.
//! * [`solve_ilp`] / [`solve_ilp_counted`] — the original dense path
//!   (clones the whole [`LinProg`] per node, rows for branches), retained
//!   as the independent equivalence oracle the bounded path is tested
//!   against.

use crate::opt::bounded::{BoundedLp, BoundedOutcome, SimplexState};
use crate::opt::simplex::{solve, Cmp, LinProg, LpOutcome};

/// An LP plus the set of variables required to be integral.
#[derive(Debug, Clone)]
pub struct IntLinProg {
    /// The relaxation.
    pub lp: LinProg,
    /// Indices of variables constrained to integer values.
    pub int_vars: Vec<usize>,
}

/// A bounded-form LP plus the set of variables required to be integral.
#[derive(Debug, Clone)]
pub struct BoundedIntLinProg {
    /// The relaxation, with per-variable bounds.
    pub lp: BoundedLp,
    /// Indices of variables constrained to integer values.
    pub int_vars: Vec<usize>,
}

/// Search limits (defense against pathological instances).
#[derive(Debug, Clone, Copy)]
pub struct IlpLimits {
    /// Maximum branch-and-bound nodes whose relaxation is solved.
    pub max_nodes: usize,
    /// Relative optimality gap: a node is pruned when its relaxation
    /// cannot beat the incumbent by more than `gap·|incumbent|` (the same
    /// default class commercial MIP solvers use).
    pub gap: f64,
}

impl Default for IlpLimits {
    fn default() -> Self {
        IlpLimits { max_nodes: 20_000, gap: 1e-4 }
    }
}

/// Work counters from one branch-and-bound run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IlpStats {
    /// Nodes whose LP relaxation was solved during the tree walk — the
    /// same accounting as [`solve_ilp_counted`], so the two are directly
    /// comparable.  The root solve and root-rounding probe are outside
    /// the count on both paths.
    pub nodes: usize,
    /// Nodes discarded on their parent's bound without an LP solve.
    pub pruned_unsolved: usize,
    /// Simplex pivots across all node solves (primal + dual + flips).
    pub pivots: u64,
    /// Node LPs served by the warm dual path.
    pub lp_warm: usize,
    /// Node LPs that fell back to a cold two-phase solve.
    pub lp_cold: usize,
}

const INT_TOL: f64 = 1e-6;

/// Solve the ILP on the bounded stack with a fresh tableau.  Returns
/// `(solution, stats)`; the solution is `None` if infeasible or the node
/// limit exhausted without an incumbent.
pub fn solve_ilp_bounded(
    problem: &BoundedIntLinProg,
    limits: IlpLimits,
) -> (Option<(Vec<f64>, f64)>, IlpStats) {
    let mut state = SimplexState::new(&problem.lp);
    solve_ilp_bounded_with(
        &mut state,
        &problem.int_vars,
        &problem.lp.lo,
        &problem.lp.hi,
        limits,
        None,
    )
}

/// Solve an ILP whose matrix already lives in `state`, branching through
/// per-variable bound tightenings of `[root_lo, root_hi]`.
///
/// `state` may carry the basis of a previous solve over the same matrix
/// (an earlier control epoch after [`SimplexState::set_rhs`]); the root
/// then re-optimizes warm via the dual simplex.  `seed` is an optional
/// incumbent `(x, obj)` the caller has already verified feasible for
/// *this* instance — it prunes the tree from node one.
pub fn solve_ilp_bounded_with(
    state: &mut SimplexState,
    int_vars: &[usize],
    root_lo: &[f64],
    root_hi: &[f64],
    limits: IlpLimits,
    seed: Option<(Vec<f64>, f64)>,
) -> (Option<(Vec<f64>, f64)>, IlpStats) {
    let mut stats = IlpStats::default();
    let pivots0 = state.pivot_count();
    let mut incumbent: Option<(Vec<f64>, f64)> = seed;

    // Root relaxation (warm when the state carries a basis).
    let mut solve_node = |state: &mut SimplexState, stats: &mut IlpStats| {
        let (out, warm) = state.resolve();
        if warm {
            stats.lp_warm += 1;
        } else {
            stats.lp_cold += 1;
        }
        out
    };

    if !state.set_bounds(root_lo, root_hi) {
        stats.pivots = state.pivot_count() - pivots0;
        return (None, stats);
    }
    let root = solve_node(state, &mut stats);
    let root_x = match root {
        BoundedOutcome::Optimal { x, .. } => x,
        // Root infeasible/unbounded ⇒ no integer point either (a seed
        // would certify feasibility, so none can exist here).
        _ => {
            stats.pivots = state.pivot_count() - pivots0;
            return (None, stats);
        }
    };

    // Root-rounding incumbent: pin every integer variable to the ceiling
    // of its relaxation value *through bounds* and re-solve warm.  For
    // covering-style problems (all the capacity instances) the rounded
    // point is feasible, giving B&B a strong initial bound for the cost
    // of one dual re-solve instead of a fresh phase 1.
    {
        let mut lo = root_lo.to_vec();
        let mut hi = root_hi.to_vec();
        let mut pin_ok = true;
        for &v in int_vars {
            let pin = root_x[v].ceil();
            if pin < root_lo[v] - INT_TOL || pin > root_hi[v] + INT_TOL {
                pin_ok = false;
                break;
            }
            lo[v] = pin;
            hi[v] = pin;
        }
        if pin_ok && state.set_bounds(&lo, &hi) {
            if let BoundedOutcome::Optimal { x, obj } = solve_node(state, &mut stats) {
                match &incumbent {
                    Some((_, best)) if obj >= *best => {}
                    _ => incumbent = Some((x, obj)),
                }
            }
        }
    }

    // Each node = (structural lower bounds, upper bounds, parent's
    // relaxation objective — a valid bound on every descendant).
    let mut stack: Vec<(Vec<f64>, Vec<f64>, f64)> =
        vec![(root_lo.to_vec(), root_hi.to_vec(), f64::NEG_INFINITY)];

    while let Some((nlo, nhi, parent_bound)) = stack.pop() {
        // Parent-bound prune: no LP solve, not counted as a node.
        if let Some((_, best)) = &incumbent {
            let tol = (limits.gap * best.abs()).max(1e-9);
            if parent_bound >= *best - tol {
                stats.pruned_unsolved += 1;
                continue;
            }
        }
        stats.nodes += 1;
        if stats.nodes > limits.max_nodes {
            break;
        }
        if !state.set_bounds(&nlo, &nhi) {
            continue; // empty bound interval: infeasible without solving
        }
        let (x, obj) = match solve_node(state, &mut stats) {
            BoundedOutcome::Optimal { x, obj } => (x, obj),
            _ => continue, // infeasible or unbounded branch
        };
        if let Some((_, best)) = &incumbent {
            let tol = (limits.gap * best.abs()).max(1e-9);
            if obj >= *best - tol {
                continue; // bound: can't meaningfully beat the incumbent
            }
        }
        // Most-fractional branching variable.
        let mut branch: Option<(usize, f64, f64)> = None; // (var, value, frac dist)
        for &v in int_vars {
            let frac = (x[v] - x[v].round()).abs();
            if frac > INT_TOL {
                let dist = (x[v].fract() - 0.5).abs();
                match branch {
                    None => branch = Some((v, x[v], dist)),
                    Some((_, _, bd)) if dist < bd => branch = Some((v, x[v], dist)),
                    _ => {}
                }
            }
        }
        match branch {
            None => {
                // Integral: round cleanly and accept as incumbent.
                let mut xi = x;
                for &v in int_vars {
                    xi[v] = xi[v].round();
                }
                let obj = state.objective_of(&xi);
                match &incumbent {
                    None => incumbent = Some((xi, obj)),
                    Some((_, best)) if obj < *best => incumbent = Some((xi, obj)),
                    _ => {}
                }
            }
            Some((v, val, _)) => {
                // x_v ≤ floor
                let mut lo_hi = nhi.clone();
                lo_hi[v] = val.floor();
                let lo_child = (nlo.clone(), lo_hi, obj);
                // x_v ≥ ceil
                let mut hi_lo = nlo;
                hi_lo[v] = val.ceil();
                let hi_child = (hi_lo, nhi, obj);
                // DFS: push the branch nearer the LP value last (explored
                // first) to find good incumbents early.
                if val.fract() < 0.5 {
                    stack.push(hi_child);
                    stack.push(lo_child);
                } else {
                    stack.push(lo_child);
                    stack.push(hi_child);
                }
            }
        }
    }
    stats.pivots = state.pivot_count() - pivots0;
    (incumbent, stats)
}

/// Solve the ILP on the dense oracle path; returns `(x, objective)` or
/// `None` if infeasible / node limit exhausted without an incumbent.
pub fn solve_ilp(problem: &IntLinProg, limits: IlpLimits) -> Option<(Vec<f64>, f64)> {
    solve_ilp_counted(problem, limits).0
}

/// [`solve_ilp`] plus the number of nodes whose relaxation was solved —
/// the baseline the bounded path's node counts are regression-tested
/// against.
pub fn solve_ilp_counted(
    problem: &IntLinProg,
    limits: IlpLimits,
) -> (Option<(Vec<f64>, f64)>, usize) {
    // Each node = extra bound rows appended to the base LP.
    let mut stack: Vec<Vec<(Vec<f64>, Cmp, f64)>> = vec![vec![]];
    // Seed the incumbent by rounding the root relaxation *up* (covering
    // structure ⇒ usually feasible) and re-solving with the integers
    // pinned — one extra LP that prunes most of the tree.
    let mut incumbent: Option<(Vec<f64>, f64)> = root_rounding_incumbent(problem);
    let mut nodes = 0usize;

    while let Some(extra) = stack.pop() {
        nodes += 1;
        if nodes > limits.max_nodes {
            break;
        }
        let mut lp = problem.lp.clone();
        lp.rows.extend(extra.iter().cloned());
        let (x, obj) = match solve(&lp) {
            LpOutcome::Optimal { x, obj } => (x, obj),
            _ => continue, // infeasible or unbounded branch
        };
        if let Some((_, best)) = &incumbent {
            let tol = (limits.gap * best.abs()).max(1e-9);
            if obj >= *best - tol {
                continue; // bound: can't meaningfully beat the incumbent
            }
        }
        // Most-fractional branching variable.
        let mut branch: Option<(usize, f64, f64)> = None; // (var, value, frac dist)
        for &v in &problem.int_vars {
            let frac = (x[v] - x[v].round()).abs();
            if frac > INT_TOL {
                let dist = (x[v].fract() - 0.5).abs();
                match branch {
                    None => branch = Some((v, x[v], dist)),
                    Some((_, _, bd)) if dist < bd => branch = Some((v, x[v], dist)),
                    _ => {}
                }
            }
        }
        match branch {
            None => {
                // Integral: round cleanly and accept as incumbent.
                let mut xi = x;
                for &v in &problem.int_vars {
                    xi[v] = xi[v].round();
                }
                let obj = problem.lp.c.iter().zip(&xi).map(|(c, v)| c * v).sum();
                match &incumbent {
                    None => incumbent = Some((xi, obj)),
                    Some((_, best)) if obj < *best => incumbent = Some((xi, obj)),
                    _ => {}
                }
            }
            Some((v, val, _)) => {
                let mut unit = vec![0.0; problem.lp.n];
                unit[v] = 1.0;
                // x_v <= floor
                let mut lo = extra.clone();
                lo.push((unit.clone(), Cmp::Le, val.floor()));
                // x_v >= ceil
                let mut hi = extra;
                hi.push((unit, Cmp::Ge, val.ceil()));
                // DFS: push the branch nearer the LP value last (explored
                // first) to find good incumbents early.
                if val.fract() < 0.5 {
                    stack.push(hi);
                    stack.push(lo);
                } else {
                    stack.push(lo);
                    stack.push(hi);
                }
            }
        }
    }
    (incumbent, nodes)
}

/// Solve the root LP, round every integer variable up (ceil), and
/// re-solve with them pinned.  For covering-style problems (all the
/// capacity instances) the rounded point is feasible, giving B&B a strong
/// initial bound at the cost of two LP solves.
fn root_rounding_incumbent(problem: &IntLinProg) -> Option<(Vec<f64>, f64)> {
    let root = match solve(&problem.lp) {
        LpOutcome::Optimal { x, .. } => x,
        _ => return None,
    };
    let mut lp = problem.lp.clone();
    for &v in &problem.int_vars {
        let mut unit = vec![0.0; lp.n];
        unit[v] = 1.0;
        lp.rows.push((unit, Cmp::Eq, root[v].ceil()));
    }
    match solve(&lp) {
        LpOutcome::Optimal { x, obj } => Some((x, obj)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run a dense-form problem through both paths and require agreement.
    fn both(p: &IntLinProg) -> Option<(Vec<f64>, f64)> {
        let dense = solve_ilp(p, IlpLimits::default());
        let bp = BoundedIntLinProg {
            lp: BoundedLp::from_linprog(&p.lp),
            int_vars: p.int_vars.clone(),
        };
        let (bounded, _) = solve_ilp_bounded(&bp, IlpLimits::default());
        match (&dense, &bounded) {
            (Some((_, a)), Some((_, b))) => {
                assert!((a - b).abs() < 1e-6, "dense obj {a} vs bounded obj {b}")
            }
            (None, None) => {}
            (d, b) => panic!("paths diverged: dense {d:?} bounded {b:?}"),
        }
        bounded
    }

    #[test]
    fn knapsack_like() {
        // max 5a + 4b s.t. 6a + 4b <= 24, a + 2b <= 6, integer.
        // LP optimum (3, 1.5) → -21; ILP optimum is a=4, b=0 → -20.
        let p = IntLinProg {
            lp: LinProg {
                n: 2,
                c: vec![-5.0, -4.0],
                rows: vec![
                    (vec![6.0, 4.0], Cmp::Le, 24.0),
                    (vec![1.0, 2.0], Cmp::Le, 6.0),
                ],
            },
            int_vars: vec![0, 1],
        };
        let (x, obj) = both(&p).unwrap();
        assert_eq!((x[0].round() as i64, x[1].round() as i64), (4, 0));
        assert!((obj + 20.0).abs() < 1e-6);
    }

    #[test]
    fn already_integral_lp() {
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![(vec![1.0], Cmp::Ge, 3.0)],
            },
            int_vars: vec![0],
        };
        let (x, obj) = both(&p).unwrap();
        assert_eq!(x[0], 3.0);
        assert!((obj - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_lp_rounds_up_for_covering() {
        // min x s.t. 3x >= 10 → LP 3.33, ILP 4.
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![(vec![3.0], Cmp::Ge, 10.0)],
            },
            int_vars: vec![0],
        };
        let (x, _) = both(&p).unwrap();
        assert_eq!(x[0], 4.0);
    }

    #[test]
    fn infeasible_returns_none() {
        let p = IntLinProg {
            lp: LinProg {
                n: 1,
                c: vec![1.0],
                rows: vec![
                    (vec![1.0], Cmp::Le, 1.0),
                    (vec![1.0], Cmp::Ge, 2.0),
                ],
            },
            int_vars: vec![0],
        };
        assert!(both(&p).is_none());
    }

    #[test]
    fn mixed_integer_keeps_continuous_free() {
        // min x + y s.t. x + y >= 2.5, x integer, y continuous.
        let p = IntLinProg {
            lp: LinProg {
                n: 2,
                c: vec![1.0, 1.0],
                rows: vec![(vec![1.0, 1.0], Cmp::Ge, 2.5)],
            },
            int_vars: vec![0],
        };
        let (x, obj) = both(&p).unwrap();
        assert!((obj - 2.5).abs() < 1e-6);
        assert!((x[0] - x[0].round()).abs() < 1e-9);
    }

    #[test]
    fn bounded_nodes_are_branch_tightenings_not_rows() {
        // Integer bounds arrive through the tableau: a bounded knapsack
        // whose branches must respect the original hi bound.
        let p = BoundedIntLinProg {
            lp: BoundedLp {
                n: 2,
                c: vec![-5.0, -4.0],
                rows: vec![(vec![6.0, 4.0], Cmp::Le, 24.0)],
                lo: vec![0.0, 0.0],
                hi: vec![3.0, 10.0],
            },
            int_vars: vec![0, 1],
        };
        let (sol, stats) = solve_ilp_bounded(&p, IlpLimits::default());
        let (x, obj) = sol.unwrap();
        // x0 capped at 3 → 6·3 = 18 used, 4·b ≤ 6 → b = 1: obj −19.
        assert_eq!((x[0].round() as i64, x[1].round() as i64), (3, 1));
        assert!((obj + 19.0).abs() < 1e-6);
        assert!(stats.nodes >= 1);
    }

    #[test]
    fn seed_incumbent_prunes_but_never_worsens() {
        // min x s.t. 3x >= 10, integer → 4.  Seed with the known optimum:
        // the answer must be identical and the tree all but collapse.
        let p = BoundedIntLinProg {
            lp: BoundedLp {
                n: 1,
                c: vec![1.0],
                rows: vec![(vec![3.0], Cmp::Ge, 10.0)],
                lo: vec![0.0],
                hi: vec![f64::INFINITY],
            },
            int_vars: vec![0],
        };
        let mut state = SimplexState::new(&p.lp);
        let seed = Some((vec![4.0], 4.0));
        let (sol, _) = solve_ilp_bounded_with(
            &mut state,
            &p.int_vars,
            &p.lp.lo,
            &p.lp.hi,
            IlpLimits::default(),
            seed,
        );
        let (x, obj) = sol.unwrap();
        assert_eq!(x[0], 4.0);
        assert!((obj - 4.0).abs() < 1e-9);
    }

    #[test]
    fn reused_state_warm_starts_across_rhs_changes() {
        // Same matrix, drifting demand: the second solve must reuse the
        // basis (warm dual) and agree with a from-scratch solve.
        let lp = BoundedLp {
            n: 1,
            c: vec![1.0],
            rows: vec![(vec![3.0], Cmp::Ge, 10.0)],
            lo: vec![0.0],
            hi: vec![f64::INFINITY],
        };
        let mut state = SimplexState::new(&lp);
        let (first, _) =
            solve_ilp_bounded_with(&mut state, &[0], &lp.lo, &lp.hi, IlpLimits::default(), None);
        assert_eq!(first.unwrap().0[0], 4.0);

        state.set_rhs(&[14.0]); // 3x ≥ 14 → LP 4.67 → ILP 5
        let (second, stats) =
            solve_ilp_bounded_with(&mut state, &[0], &lp.lo, &lp.hi, IlpLimits::default(), None);
        assert_eq!(second.unwrap().0[0], 5.0);
        assert!(stats.lp_warm > 0, "expected warm solves, got {stats:?}");
    }
}
