//! The SageServe capacity-allocation problem (§5), built on the ILP.
//!
//! For each model `i` (the formulation decouples across models):
//!
//! ```text
//! vars   x_jk   = new instance count of model i at region j on GPU k  (int)
//!        u_jk   = max(0, x_jk - n_jk)   (scale-out part, continuous)
//! min    Σ_k α_k Σ_j (x_jk - n_jk)  +  Σ_jk σ_ik · u_jk
//! s.t.   Σ_k x_jk·θ_ik ≥ ε · max_w ρ_ij(w)              ∀ j   (local floor)
//!        Σ_jk x_jk·θ_ik ≥ max_w Σ_j ρ_ij(w)                  (global cover)
//!        u_jk ≥ x_jk − n_jk,  u ≥ 0
//!        min_inst ≤ x_jk ≤ max_inst
//! ```
//!
//! δ = x − n is handed to the Scaling Logic (§6.4).  The regional VM
//! budget is enforced downstream by the cluster when executing δ.

use std::time::Instant;

use crate::opt::ilp::{solve_ilp, IlpLimits, IntLinProg};
use crate::opt::simplex::{Cmp, LinProg};

/// Inputs for one model's capacity problem.
#[derive(Debug, Clone)]
pub struct CapacityInputs {
    /// Current instance counts n_{j,k}: `[region][gpu]`.
    pub current: Vec<Vec<f64>>,
    /// Per-instance input TPS θ_{k}: `[gpu]` (model-specific).
    pub tps_per_instance: Vec<f64>,
    /// Forecast input TPS per region per window ρ_j(w): `[region][window]`
    /// (already including the β NIW-headroom buffer of §6.3).
    pub forecast_tps: Vec<Vec<f64>>,
    /// VM acquisition cost α_k: `[gpu]` ($/h).
    pub vm_cost: Vec<f64>,
    /// Instance start cost σ_{k} = α_k × startup hours: `[gpu]`.
    pub start_cost: Vec<f64>,
    /// §5 ε: minimum locally-served fraction of peak.
    pub epsilon: f64,
    pub min_instances: f64,
    pub max_instances: f64,
}

/// Output: instance-count deltas per `[region][gpu]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityPlan {
    pub deltas: Vec<Vec<i64>>,
    pub objective: f64,
    pub solve_time: f64,
}

/// Solve one model's allocation.  Returns None if the ILP is infeasible
/// even at max_instances everywhere (forecast exceeds total capacity) —
/// callers should then clamp to max.
pub fn optimize_capacity(inp: &CapacityInputs) -> Option<CapacityPlan> {
    let started = Instant::now();
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    assert!(inp.forecast_tps.len() == r);
    let nx = r * g; // x vars
    let n = 2 * nx; // x then u
    let idx = |j: usize, k: usize| j * g + k;

    let mut c = vec![0.0; n];
    for j in 0..r {
        for k in 0..g {
            c[idx(j, k)] = inp.vm_cost[k];
            c[nx + idx(j, k)] = inp.start_cost[k];
        }
    }

    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = Vec::new();
    // Local floor per region: Σ_k x_jk θ_k ≥ ε max_w ρ_j(w).
    for j in 0..r {
        let peak = inp.forecast_tps[j].iter().copied().fold(0.0, f64::max);
        let mut row = vec![0.0; n];
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
        rows.push((row, Cmp::Ge, inp.epsilon * peak));
    }
    // Global cover: Σ_jk x_jk θ_k ≥ max_w Σ_j ρ_j(w).
    let windows = inp.forecast_tps.first().map(|f| f.len()).unwrap_or(0);
    let mut global_peak = 0.0f64;
    for w in 0..windows {
        let s: f64 = (0..r).map(|j| inp.forecast_tps[j][w]).sum();
        global_peak = global_peak.max(s);
    }
    let mut row = vec![0.0; n];
    for j in 0..r {
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
    }
    rows.push((row, Cmp::Ge, global_peak));
    // u_jk ≥ x_jk − n_jk  ⇔  x_jk − u_jk ≤ n_jk.
    for j in 0..r {
        for k in 0..g {
            let mut row = vec![0.0; n];
            row[idx(j, k)] = 1.0;
            row[nx + idx(j, k)] = -1.0;
            rows.push((row, Cmp::Le, inp.current[j][k]));
        }
    }
    // Bounds.
    for j in 0..r {
        for k in 0..g {
            let mut lo = vec![0.0; n];
            lo[idx(j, k)] = 1.0;
            rows.push((lo.clone(), Cmp::Ge, inp.min_instances));
            rows.push((lo, Cmp::Le, inp.max_instances));
        }
    }

    let problem = IntLinProg {
        lp: LinProg { n, c, rows },
        int_vars: (0..nx).collect(),
    };
    let (x, obj) = solve_ilp(&problem, IlpLimits::default())?;
    // Report the objective in the paper's δ terms: the ILP minimized
    // Σ α·x + Σ σ·u; subtract the Σ α·n constant so scale-in is negative.
    let alpha_n: f64 = (0..r)
        .map(|j| (0..g).map(|k| inp.vm_cost[k] * inp.current[j][k]).sum::<f64>())
        .sum();
    let obj = obj - alpha_n;

    let mut deltas = vec![vec![0i64; g]; r];
    for j in 0..r {
        for k in 0..g {
            deltas[j][k] = (x[idx(j, k)].round() as i64) - (inp.current[j][k].round() as i64);
        }
    }
    Some(CapacityPlan { deltas, objective: obj, solve_time: started.elapsed().as_secs_f64() })
}

/// Build a random-but-feasible instance of given dimensions (for the §5
/// solver-runtime benchmark: l models are solved independently, so the
/// bench loops this l times).
pub fn synthetic_inputs(regions: usize, gpus: usize, seed: u64) -> CapacityInputs {
    // Splitmix-style deterministic pseudo-randoms (no rand dependency here).
    let mut state = seed.wrapping_add(0x9e3779b97f4a7c15);
    let mut next = move || {
        state ^= state >> 30;
        state = state.wrapping_mul(0xbf58476d1ce4e5b9);
        state ^= state >> 27;
        (state >> 11) as f64 / (1u64 << 53) as f64
    };
    let tps: Vec<f64> = (0..gpus).map(|_| 200.0 + 400.0 * next()).collect();
    let current: Vec<Vec<f64>> =
        (0..regions).map(|_| (0..gpus).map(|_| (2.0 + 10.0 * next()).floor()).collect()).collect();
    let forecast: Vec<Vec<f64>> = (0..regions)
        .map(|_| (0..4).map(|_| 500.0 + 3000.0 * next()).collect())
        .collect();
    CapacityInputs {
        current,
        tps_per_instance: tps,
        forecast_tps: forecast,
        vm_cost: (0..gpus).map(|_| 50.0 + 60.0 * next()).collect(),
        start_cost: (0..gpus).map(|_| 10.0 + 20.0 * next()).collect(),
        epsilon: 0.6,
        min_instances: 2.0,
        max_instances: 40.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single_gpu(current: Vec<f64>, forecast: Vec<Vec<f64>>, theta: f64) -> CapacityInputs {
        CapacityInputs {
            current: current.into_iter().map(|c| vec![c]).collect(),
            tps_per_instance: vec![theta],
            forecast_tps: forecast,
            vm_cost: vec![98.32],
            start_cost: vec![16.4],
            epsilon: 0.6,
            min_instances: 2.0,
            max_instances: 20.0,
        }
    }

    #[test]
    fn scales_out_to_cover_peak() {
        // 3 regions at 2 instances × 500 TPS each; forecast peak 3000 TPS
        // in region 0 ⇒ needs ≥ 6 instances globally and ≥ 0.6·3000/500 =
        // 3.6 → 4 locally.
        let inp = single_gpu(
            vec![2.0, 2.0, 2.0],
            vec![vec![3000.0, 2500.0], vec![400.0, 500.0], vec![100.0, 200.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        let x0 = inp.current[0][0] as i64 + plan.deltas[0][0];
        assert!(x0 >= 4, "local floor: x0 = {x0}");
        let total: i64 = (0..3)
            .map(|j| inp.current[j][0] as i64 + plan.deltas[j][0])
            .sum();
        // Global: max_w Σ_j ρ = 3000+400+100 = 3500? windows: w0 sum =
        // 3500, w1 sum = 3200 ⇒ need ≥ 7 instances.
        assert!(total >= 7, "global cover: total = {total}");
    }

    #[test]
    fn scales_in_when_idle() {
        // Huge allocation, tiny forecast ⇒ δ < 0 down to min_instances.
        let inp = single_gpu(
            vec![10.0, 10.0, 10.0],
            vec![vec![100.0], vec![100.0], vec![100.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        for j in 0..3 {
            let x = inp.current[j][0] as i64 + plan.deltas[j][0];
            assert_eq!(x, 2, "region {j} should sit at min_instances");
        }
    }

    #[test]
    fn never_deallocates_below_zero_or_min() {
        let inp = single_gpu(vec![2.0, 2.0, 2.0], vec![vec![0.0], vec![0.0], vec![0.0]], 500.0);
        let plan = optimize_capacity(&inp).unwrap();
        for j in 0..3 {
            assert_eq!(plan.deltas[j][0], 0);
        }
    }

    #[test]
    fn rerouting_allowed_by_epsilon() {
        // Region 0 peak 2000 but ε=0.6 ⇒ local floor 1200 (3 inst); the
        // remaining 800 can be served by other regions' slack under the
        // global constraint.
        let inp = single_gpu(
            vec![2.0, 2.0, 2.0],
            vec![vec![2000.0], vec![500.0], vec![500.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        let x0 = inp.current[0][0] as i64 + plan.deltas[0][0];
        let total: i64 = (0..3).map(|j| inp.current[j][0] as i64 + plan.deltas[j][0]).sum();
        assert!(x0 >= 3);
        assert!(total >= 6); // 3000 TPS global / 500
    }

    #[test]
    fn prefers_cheaper_gpu() {
        // Two GPU types, same θ, different α ⇒ scale-out lands on cheap k.
        let inp = CapacityInputs {
            current: vec![vec![2.0, 2.0]],
            tps_per_instance: vec![500.0, 500.0],
            forecast_tps: vec![vec![3000.0]],
            vm_cost: vec![98.0, 54.0],
            start_cost: vec![16.0, 9.0],
            epsilon: 1.0,
            min_instances: 2.0,
            max_instances: 20.0,
        };
        let plan = optimize_capacity(&inp).unwrap();
        assert!(plan.deltas[0][1] > 0, "cheap GPU takes the growth");
        assert_eq!(plan.deltas[0][0], 0, "expensive GPU untouched");
    }

    #[test]
    fn infeasible_when_demand_exceeds_max() {
        let inp = single_gpu(vec![2.0], vec![vec![1.0e9]], 500.0);
        assert!(optimize_capacity(&inp).is_none());
    }

    #[test]
    fn objective_counts_start_cost_only_for_scale_out() {
        // Scale-in should not pay σ: objective = α·δ (negative).
        let inp = single_gpu(vec![10.0], vec![vec![500.0]], 500.0);
        let plan = optimize_capacity(&inp).unwrap();
        assert!(plan.deltas[0][0] < 0);
        assert!(plan.objective < 0.0);
    }

    #[test]
    fn synthetic_inputs_are_solvable() {
        for seed in 0..5 {
            let inp = synthetic_inputs(3, 1, seed);
            assert!(optimize_capacity(&inp).is_some(), "seed {seed}");
        }
    }

    #[test]
    fn paper_scale_solves_quickly() {
        // §5: l=20, r=20, g=5 took 33 s with a commercial solver.  Our
        // decomposed exact B&B must stay well under that (see benches).
        let mut total = 0.0;
        for model in 0..20u64 {
            let inp = synthetic_inputs(20, 5, model);
            let plan = optimize_capacity(&inp).expect("solvable");
            total += plan.solve_time;
        }
        assert!(total < 30.0, "20-model solve took {total}s");
    }
}
