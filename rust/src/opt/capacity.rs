//! The SageServe capacity-allocation problem (§5), built on the ILP.
//!
//! For each model `i` (the formulation decouples across models):
//!
//! ```text
//! vars   x_jk   = new instance count of model i at region j on GPU k  (int)
//!        u_jk   = max(0, x_jk - n_jk)   (scale-out part, continuous)
//! min    Σ_k α_k Σ_j (x_jk - n_jk)  +  Σ_jk σ_ik · u_jk
//! s.t.   Σ_k x_jk·θ_ik ≥ ε · max_w ρ_ij(w)              ∀ j   (local floor)
//!        Σ_jk x_jk·θ_ik ≥ max_w Σ_j ρ_ij(w)                  (global cover)
//!        u_jk ≥ x_jk − n_jk,  u ≥ 0
//!        min_inst ≤ x_jk ≤ max_inst
//! ```
//!
//! δ = x − n is handed to the Scaling Logic (§6.4).  The regional VM
//! budget is enforced downstream by the cluster when executing δ.
//!
//! The production path ([`optimize_capacity`] / [`optimize_capacity_warm`])
//! runs on the bounded-variable stack: `min ≤ x ≤ max` and `u ≥ 0` live in
//! the tableau, so an (r, g) instance has `r + 1 + r·g` rows instead of the
//! `r + 1 + 3·r·g` the dense encoding needs.  [`CapacitySolver`] keeps the
//! factorized tableau, basis and last integer solution per model across
//! control epochs: demand drift only changes the right-hand side, so epoch
//! N+1 re-solves warm via the dual simplex from epoch N's basis, seeded
//! with epoch N's plan as the initial incumbent.  The original dense
//! encoding is retained as [`optimize_capacity_dense`], the equivalence
//! oracle for tests and the `exp ilp` old-vs-new comparison.

use std::time::Instant;

use crate::opt::bounded::{BoundedLp, SimplexState};
use crate::opt::ilp::{solve_ilp_bounded_with, solve_ilp_counted, IlpLimits, IntLinProg};
use crate::opt::simplex::{Cmp, LinProg};

/// Inputs for one model's capacity problem.
#[derive(Debug, Clone)]
pub struct CapacityInputs {
    /// Current instance counts n_{j,k}: `[region][gpu]`.
    pub current: Vec<Vec<f64>>,
    /// Per-instance input TPS θ_{k}: `[gpu]` (model-specific).
    pub tps_per_instance: Vec<f64>,
    /// Forecast input TPS per region per window ρ_j(w): `[region][window]`
    /// (already including the β NIW-headroom buffer of §6.3).
    pub forecast_tps: Vec<Vec<f64>>,
    /// VM acquisition cost α_k: `[gpu]` ($/h).
    pub vm_cost: Vec<f64>,
    /// Instance start cost σ_{k} = α_k × startup hours: `[gpu]`.
    pub start_cost: Vec<f64>,
    /// §5 ε: minimum locally-served fraction of peak.
    pub epsilon: f64,
    /// Lower bound on every x_{j,k}.
    pub min_instances: f64,
    /// Upper bound on every x_{j,k}.
    pub max_instances: f64,
}

/// Output: instance-count deltas per `[region][gpu]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityPlan {
    /// δ_{j,k} = x_{j,k} − n_{j,k}.
    pub deltas: Vec<Vec<i64>>,
    /// Plan cost in the paper's δ terms (scale-in is negative).
    pub objective: f64,
    /// Wall-clock seconds spent in the solver.
    pub solve_time: f64,
    /// Simplex pivots across all branch-and-bound node solves
    /// (0 on the dense oracle path, which has no pivot counter).
    pub pivots: u64,
    /// Branch-and-bound nodes whose relaxation was solved.
    pub nodes: usize,
    /// Whether a previous epoch's tableau/basis was reused (warm start).
    pub warm: bool,
}

/// Per-model state carried across control epochs: the factorized tableau
/// plus the last integer solution.  The matrix is keyed on everything
/// that shapes rows or costs (dims, θ, α, σ); a key change rebuilds cold,
/// a key hit re-solves warm from the previous basis after an O(m²) rhs
/// swap.
#[derive(Debug, Clone, Default)]
pub struct CapacitySolver {
    state: Option<SimplexState>,
    key: Vec<f64>,
    last_x: Option<Vec<f64>>,
}

impl CapacitySolver {
    /// Fresh state: the first solve through it runs cold.
    pub fn new() -> CapacitySolver {
        CapacitySolver::default()
    }

    /// Whether a previous solve left a reusable tableau behind.
    pub fn has_state(&self) -> bool {
        self.state.is_some()
    }
}

/// Everything that shapes the constraint matrix or costs; rhs (forecast,
/// current counts) and bounds (min/max) are excluded — those change per
/// epoch and are handled by warm re-solves.
fn matrix_key(inp: &CapacityInputs) -> Vec<f64> {
    let mut key = vec![inp.current.len() as f64, inp.tps_per_instance.len() as f64];
    key.extend_from_slice(&inp.tps_per_instance);
    key.extend_from_slice(&inp.vm_cost);
    key.extend_from_slice(&inp.start_cost);
    key
}

/// The bounded-form rows (floors, global cover, linking) and the rhs in
/// original row orientation, for one model instance.
fn bounded_problem(inp: &CapacityInputs) -> (BoundedLp, Vec<f64>) {
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    assert!(inp.forecast_tps.len() == r);
    let nx = r * g;
    let n = 2 * nx;
    let idx = |j: usize, k: usize| j * g + k;

    let mut c = vec![0.0; n];
    let mut lo = vec![0.0; n];
    let mut hi = vec![f64::INFINITY; n];
    for j in 0..r {
        for k in 0..g {
            c[idx(j, k)] = inp.vm_cost[k];
            c[nx + idx(j, k)] = inp.start_cost[k];
            lo[idx(j, k)] = inp.min_instances;
            hi[idx(j, k)] = inp.max_instances;
        }
    }

    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = Vec::with_capacity(r + 1 + nx);
    let mut rhs = Vec::with_capacity(r + 1 + nx);
    // Local floor per region: Σ_k x_jk θ_k ≥ ε max_w ρ_j(w).
    for j in 0..r {
        let peak = inp.forecast_tps[j].iter().copied().fold(0.0, f64::max);
        let mut row = vec![0.0; n];
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
        let b = inp.epsilon * peak;
        rows.push((row, Cmp::Ge, b));
        rhs.push(b);
    }
    // Global cover: Σ_jk x_jk θ_k ≥ max_w Σ_j ρ_j(w).
    let windows = inp.forecast_tps.first().map(|f| f.len()).unwrap_or(0);
    let mut global_peak = 0.0f64;
    for w in 0..windows {
        let s: f64 = (0..r).map(|j| inp.forecast_tps[j][w]).sum();
        global_peak = global_peak.max(s);
    }
    let mut row = vec![0.0; n];
    for j in 0..r {
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
    }
    rows.push((row, Cmp::Ge, global_peak));
    rhs.push(global_peak);
    // u_jk ≥ x_jk − n_jk  ⇔  x_jk − u_jk ≤ n_jk.  (The u ≥ 0 and
    // min/max x bounds are variable bounds, not rows.)
    for j in 0..r {
        for k in 0..g {
            let mut row = vec![0.0; n];
            row[idx(j, k)] = 1.0;
            row[nx + idx(j, k)] = -1.0;
            rows.push((row, Cmp::Le, inp.current[j][k]));
            rhs.push(inp.current[j][k]);
        }
    }

    (BoundedLp { n, c, rows, lo, hi }, rhs)
}

/// Validate a candidate x-part against this epoch's instance: recompute
/// `u = max(0, x − n)`, check floors / cover / bounds, and return the
/// full `(x·u, raw objective)` seed if feasible.
fn seed_from_previous(inp: &CapacityInputs, lp: &BoundedLp, prev_x: &[f64]) -> Option<(Vec<f64>, f64)> {
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    let nx = r * g;
    if prev_x.len() != lp.n {
        return None;
    }
    let mut cand = vec![0.0; lp.n];
    for i in 0..nx {
        let x = prev_x[i];
        if x < inp.min_instances - 1e-9 || x > inp.max_instances + 1e-9 {
            return None;
        }
        cand[i] = x;
        cand[nx + i] = (x - inp.current[i / g][i % g]).max(0.0);
    }
    for (row, cmp, b) in &lp.rows {
        let lhs: f64 = row.iter().zip(&cand).map(|(a, v)| a * v).sum();
        let ok = match cmp {
            Cmp::Ge => lhs >= b - 1e-6,
            Cmp::Le => lhs <= b + 1e-6,
            Cmp::Eq => (lhs - b).abs() <= 1e-6,
        };
        if !ok {
            return None;
        }
    }
    let obj = lp.c.iter().zip(&cand).map(|(c, v)| c * v).sum();
    Some((cand, obj))
}

/// Solve one model's allocation cold (no carried state).  Returns None if
/// the ILP is infeasible even at max_instances everywhere (forecast
/// exceeds total capacity) — callers should then clamp to max.
pub fn optimize_capacity(inp: &CapacityInputs) -> Option<CapacityPlan> {
    optimize_capacity_warm(inp, &mut CapacitySolver::new())
}

/// Solve one model's allocation, reusing `solver`'s tableau, basis and
/// last solution when the matrix is unchanged since the previous call
/// (the per-epoch controller path).  Semantics match
/// [`optimize_capacity`]; only the work differs.
pub fn optimize_capacity_warm(
    inp: &CapacityInputs,
    solver: &mut CapacitySolver,
) -> Option<CapacityPlan> {
    let started = Instant::now();
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    let nx = r * g;
    let (lp, rhs) = bounded_problem(inp);
    let key = matrix_key(inp);
    let reused = solver.state.is_some() && solver.key == key;
    if reused {
        let state = solver.state.as_mut().expect("checked above");
        state.set_rhs(&rhs);
    } else {
        solver.state = Some(SimplexState::new(&lp));
        solver.key = key;
        solver.last_x = None;
    }
    let seed = solver
        .last_x
        .as_ref()
        .and_then(|prev| seed_from_previous(inp, &lp, prev));
    let state = solver.state.as_mut().expect("just set");
    let int_vars: Vec<usize> = (0..nx).collect();
    let (sol, stats) =
        solve_ilp_bounded_with(state, &int_vars, &lp.lo, &lp.hi, IlpLimits::default(), seed);
    let (x, obj) = sol?;
    solver.last_x = Some(x.clone());

    // Report the objective in the paper's δ terms: the ILP minimized
    // Σ α·x + Σ σ·u; subtract the Σ α·n constant so scale-in is negative.
    let alpha_n: f64 = (0..r)
        .map(|j| (0..g).map(|k| inp.vm_cost[k] * inp.current[j][k]).sum::<f64>())
        .sum();
    let obj = obj - alpha_n;

    let idx = |j: usize, k: usize| j * g + k;
    let mut deltas = vec![vec![0i64; g]; r];
    for j in 0..r {
        for k in 0..g {
            deltas[j][k] = (x[idx(j, k)].round() as i64) - (inp.current[j][k].round() as i64);
        }
    }
    Some(CapacityPlan {
        deltas,
        objective: obj,
        solve_time: started.elapsed().as_secs_f64(),
        pivots: stats.pivots,
        nodes: stats.nodes,
        warm: reused,
    })
}

/// [`optimize_capacity_warm`] behind the control-plane fault plane's
/// solver-failure injection: when `fault` is set, the solve reports the
/// infeasible/iteration-cap outcome (`None`) **without touching the
/// carried tableau, basis or last solution**, so the first post-fault
/// epoch still re-solves warm exactly as if the faulted epochs had
/// never happened.  (Crippling `IlpLimits` would not work here: the
/// root relaxation and root-rounding incumbent are computed before the
/// node cap is consulted, so a capped search still returns a plan.)
pub fn optimize_capacity_warm_faulted(
    inp: &CapacityInputs,
    solver: &mut CapacitySolver,
    fault: bool,
) -> Option<CapacityPlan> {
    if fault {
        return None;
    }
    optimize_capacity_warm(inp, solver)
}

/// The original dense-encoding path (bounds as rows, per-node LP clones)
/// — kept as the equivalence oracle for tests and the `exp ilp`
/// old-vs-new comparison.  Same semantics as [`optimize_capacity`].
pub fn optimize_capacity_dense(inp: &CapacityInputs) -> Option<CapacityPlan> {
    let started = Instant::now();
    let r = inp.current.len();
    let g = inp.tps_per_instance.len();
    assert!(inp.forecast_tps.len() == r);
    let nx = r * g; // x vars
    let n = 2 * nx; // x then u
    let idx = |j: usize, k: usize| j * g + k;

    let mut c = vec![0.0; n];
    for j in 0..r {
        for k in 0..g {
            c[idx(j, k)] = inp.vm_cost[k];
            c[nx + idx(j, k)] = inp.start_cost[k];
        }
    }

    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = Vec::new();
    // Local floor per region: Σ_k x_jk θ_k ≥ ε max_w ρ_j(w).
    for j in 0..r {
        let peak = inp.forecast_tps[j].iter().copied().fold(0.0, f64::max);
        let mut row = vec![0.0; n];
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
        rows.push((row, Cmp::Ge, inp.epsilon * peak));
    }
    // Global cover: Σ_jk x_jk θ_k ≥ max_w Σ_j ρ_j(w).
    let windows = inp.forecast_tps.first().map(|f| f.len()).unwrap_or(0);
    let mut global_peak = 0.0f64;
    for w in 0..windows {
        let s: f64 = (0..r).map(|j| inp.forecast_tps[j][w]).sum();
        global_peak = global_peak.max(s);
    }
    let mut row = vec![0.0; n];
    for j in 0..r {
        for k in 0..g {
            row[idx(j, k)] = inp.tps_per_instance[k];
        }
    }
    rows.push((row, Cmp::Ge, global_peak));
    // u_jk ≥ x_jk − n_jk  ⇔  x_jk − u_jk ≤ n_jk.
    for j in 0..r {
        for k in 0..g {
            let mut row = vec![0.0; n];
            row[idx(j, k)] = 1.0;
            row[nx + idx(j, k)] = -1.0;
            rows.push((row, Cmp::Le, inp.current[j][k]));
        }
    }
    // Bounds as explicit rows (what the bounded path eliminates).
    for j in 0..r {
        for k in 0..g {
            let mut lo = vec![0.0; n];
            lo[idx(j, k)] = 1.0;
            rows.push((lo.clone(), Cmp::Ge, inp.min_instances));
            rows.push((lo, Cmp::Le, inp.max_instances));
        }
    }

    let problem = IntLinProg {
        lp: LinProg { n, c, rows },
        int_vars: (0..nx).collect(),
    };
    let (sol, nodes) = solve_ilp_counted(&problem, IlpLimits::default());
    let (x, obj) = sol?;
    // Report the objective in the paper's δ terms: the ILP minimized
    // Σ α·x + Σ σ·u; subtract the Σ α·n constant so scale-in is negative.
    let alpha_n: f64 = (0..r)
        .map(|j| (0..g).map(|k| inp.vm_cost[k] * inp.current[j][k]).sum::<f64>())
        .sum();
    let obj = obj - alpha_n;

    let mut deltas = vec![vec![0i64; g]; r];
    for j in 0..r {
        for k in 0..g {
            deltas[j][k] = (x[idx(j, k)].round() as i64) - (inp.current[j][k].round() as i64);
        }
    }
    Some(CapacityPlan {
        deltas,
        objective: obj,
        solve_time: started.elapsed().as_secs_f64(),
        pivots: 0,
        nodes,
        warm: false,
    })
}

/// Build a random-but-feasible instance of given dimensions (for the §5
/// solver-runtime benchmark: l models are solved independently, so the
/// bench loops this l times).
pub fn synthetic_inputs(regions: usize, gpus: usize, seed: u64) -> CapacityInputs {
    // Splitmix-style deterministic pseudo-randoms (no rand dependency here).
    let mut state = seed.wrapping_add(0x9e3779b97f4a7c15);
    let mut next = move || {
        state ^= state >> 30;
        state = state.wrapping_mul(0xbf58476d1ce4e5b9);
        state ^= state >> 27;
        (state >> 11) as f64 / (1u64 << 53) as f64
    };
    let tps: Vec<f64> = (0..gpus).map(|_| 200.0 + 400.0 * next()).collect();
    let current: Vec<Vec<f64>> =
        (0..regions).map(|_| (0..gpus).map(|_| (2.0 + 10.0 * next()).floor()).collect()).collect();
    let forecast: Vec<Vec<f64>> = (0..regions)
        .map(|_| (0..4).map(|_| 500.0 + 3000.0 * next()).collect())
        .collect();
    CapacityInputs {
        current,
        tps_per_instance: tps,
        forecast_tps: forecast,
        vm_cost: (0..gpus).map(|_| 50.0 + 60.0 * next()).collect(),
        start_cost: (0..gpus).map(|_| 10.0 + 20.0 * next()).collect(),
        epsilon: 0.6,
        min_instances: 2.0,
        max_instances: 40.0,
    }
}

/// Drift an instance the way one control epoch does: demand moves a few
/// percent and the fleet now sits at the plan the previous epoch chose.
/// Used by the warm-start tests, benches and `exp ilp`.
pub fn perturb_inputs(inp: &CapacityInputs, plan: &CapacityPlan, drift: f64) -> CapacityInputs {
    let mut next = inp.clone();
    for row in &mut next.forecast_tps {
        for v in row.iter_mut() {
            *v *= 1.0 + drift;
        }
    }
    for (j, row) in next.current.iter_mut().enumerate() {
        for (k, v) in row.iter_mut().enumerate() {
            *v += plan.deltas[j][k] as f64;
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single_gpu(current: Vec<f64>, forecast: Vec<Vec<f64>>, theta: f64) -> CapacityInputs {
        CapacityInputs {
            current: current.into_iter().map(|c| vec![c]).collect(),
            tps_per_instance: vec![theta],
            forecast_tps: forecast,
            vm_cost: vec![98.32],
            start_cost: vec![16.4],
            epsilon: 0.6,
            min_instances: 2.0,
            max_instances: 20.0,
        }
    }

    #[test]
    fn scales_out_to_cover_peak() {
        // 3 regions at 2 instances × 500 TPS each; forecast peak 3000 TPS
        // in region 0 ⇒ needs ≥ 6 instances globally and ≥ 0.6·3000/500 =
        // 3.6 → 4 locally.
        let inp = single_gpu(
            vec![2.0, 2.0, 2.0],
            vec![vec![3000.0, 2500.0], vec![400.0, 500.0], vec![100.0, 200.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        let x0 = inp.current[0][0] as i64 + plan.deltas[0][0];
        assert!(x0 >= 4, "local floor: x0 = {x0}");
        let total: i64 = (0..3)
            .map(|j| inp.current[j][0] as i64 + plan.deltas[j][0])
            .sum();
        // Global: max_w Σ_j ρ = 3000+400+100 = 3500? windows: w0 sum =
        // 3500, w1 sum = 3200 ⇒ need ≥ 7 instances.
        assert!(total >= 7, "global cover: total = {total}");
    }

    #[test]
    fn scales_in_when_idle() {
        // Huge allocation, tiny forecast ⇒ δ < 0 down to min_instances.
        let inp = single_gpu(
            vec![10.0, 10.0, 10.0],
            vec![vec![100.0], vec![100.0], vec![100.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        for j in 0..3 {
            let x = inp.current[j][0] as i64 + plan.deltas[j][0];
            assert_eq!(x, 2, "region {j} should sit at min_instances");
        }
    }

    #[test]
    fn never_deallocates_below_zero_or_min() {
        let inp = single_gpu(vec![2.0, 2.0, 2.0], vec![vec![0.0], vec![0.0], vec![0.0]], 500.0);
        let plan = optimize_capacity(&inp).unwrap();
        for j in 0..3 {
            assert_eq!(plan.deltas[j][0], 0);
        }
    }

    #[test]
    fn rerouting_allowed_by_epsilon() {
        // Region 0 peak 2000 but ε=0.6 ⇒ local floor 1200 (3 inst); the
        // remaining 800 can be served by other regions' slack under the
        // global constraint.
        let inp = single_gpu(
            vec![2.0, 2.0, 2.0],
            vec![vec![2000.0], vec![500.0], vec![500.0]],
            500.0,
        );
        let plan = optimize_capacity(&inp).unwrap();
        let x0 = inp.current[0][0] as i64 + plan.deltas[0][0];
        let total: i64 = (0..3).map(|j| inp.current[j][0] as i64 + plan.deltas[j][0]).sum();
        assert!(x0 >= 3);
        assert!(total >= 6); // 3000 TPS global / 500
    }

    #[test]
    fn prefers_cheaper_gpu() {
        // Two GPU types, same θ, different α ⇒ scale-out lands on cheap k.
        let inp = CapacityInputs {
            current: vec![vec![2.0, 2.0]],
            tps_per_instance: vec![500.0, 500.0],
            forecast_tps: vec![vec![3000.0]],
            vm_cost: vec![98.0, 54.0],
            start_cost: vec![16.0, 9.0],
            epsilon: 1.0,
            min_instances: 2.0,
            max_instances: 20.0,
        };
        let plan = optimize_capacity(&inp).unwrap();
        assert!(plan.deltas[0][1] > 0, "cheap GPU takes the growth");
        assert_eq!(plan.deltas[0][0], 0, "expensive GPU untouched");
    }

    #[test]
    fn infeasible_when_demand_exceeds_max() {
        let inp = single_gpu(vec![2.0], vec![vec![1.0e9]], 500.0);
        assert!(optimize_capacity(&inp).is_none());
    }

    #[test]
    fn objective_counts_start_cost_only_for_scale_out() {
        // Scale-in should not pay σ: objective = α·δ (negative).
        let inp = single_gpu(vec![10.0], vec![vec![500.0]], 500.0);
        let plan = optimize_capacity(&inp).unwrap();
        assert!(plan.deltas[0][0] < 0);
        assert!(plan.objective < 0.0);
    }

    #[test]
    fn synthetic_inputs_are_solvable() {
        for seed in 0..5 {
            let inp = synthetic_inputs(3, 1, seed);
            assert!(optimize_capacity(&inp).is_some(), "seed {seed}");
        }
    }

    #[test]
    fn dense_oracle_agrees() {
        // Old encoding (bounds as rows) and new encoding (bounds in the
        // tableau) must land on equal-cost plans; the gap-pruned B&B
        // bounds each within 1e-4·|opt| of the true optimum.
        for seed in 0..6 {
            let inp = synthetic_inputs(3, 2, seed);
            let dense = optimize_capacity_dense(&inp).expect("dense solvable");
            let bounded = optimize_capacity(&inp).expect("bounded solvable");
            let tol = 3e-4 * dense.objective.abs() + 1e-6;
            assert!(
                (dense.objective - bounded.objective).abs() <= tol,
                "seed {seed}: dense {} vs bounded {}",
                dense.objective,
                bounded.objective
            );
        }
    }

    #[test]
    fn warm_restart_uses_fraction_of_cold_pivots() {
        // Epoch N+1 = epoch N with a few percent of demand drift and the
        // fleet sitting at epoch N's plan: the dual re-solve from the
        // carried basis must cost a small fraction of the cold pivots.
        let inp = synthetic_inputs(20, 5, 7);
        let mut solver = CapacitySolver::new();
        let cold = optimize_capacity_warm(&inp, &mut solver).expect("solvable");
        assert!(!cold.warm);
        assert!(cold.pivots > 0);

        let drifted = perturb_inputs(&inp, &cold, 0.03);
        let warm = optimize_capacity_warm(&drifted, &mut solver).expect("solvable");
        assert!(warm.warm, "matrix unchanged ⇒ warm path");
        assert!(
            warm.pivots * 4 <= cold.pivots,
            "warm re-solve took {} pivots vs {} cold",
            warm.pivots,
            cold.pivots
        );

        // And it must agree with a from-scratch solve of the same epoch.
        let fresh = optimize_capacity(&drifted).expect("solvable");
        let tol = 3e-4 * fresh.objective.abs() + 1e-6;
        assert!(
            (fresh.objective - warm.objective).abs() <= tol,
            "warm {} vs fresh {}",
            warm.objective,
            fresh.objective
        );
    }

    #[test]
    fn faulted_solve_fails_without_corrupting_warm_state() {
        let inp = synthetic_inputs(20, 5, 7);
        let mut solver = CapacitySolver::new();
        let cold = optimize_capacity_warm(&inp, &mut solver).expect("solvable");

        // Forced failure: None, and the carried basis is untouched.
        assert!(optimize_capacity_warm_faulted(&inp, &mut solver, true).is_none());
        assert!(solver.has_state(), "fault must not evict the carried tableau");

        // The first post-fault epoch still re-solves warm.
        let drifted = perturb_inputs(&inp, &cold, 0.03);
        let warm = optimize_capacity_warm_faulted(&drifted, &mut solver, false)
            .expect("solvable");
        assert!(warm.warm, "post-fault solve must reuse the pre-fault basis");

        // And without a fault the entry point is a plain delegate.
        let mut fresh = CapacitySolver::new();
        let plain = optimize_capacity_warm_faulted(&inp, &mut fresh, false).expect("solvable");
        assert_eq!(plain.deltas, cold.deltas);
    }

    #[test]
    fn solver_state_rebuilds_on_matrix_change() {
        let mut solver = CapacitySolver::new();
        let a = synthetic_inputs(4, 2, 1);
        optimize_capacity_warm(&a, &mut solver).expect("solvable");
        // Different dims ⇒ different matrix ⇒ cold rebuild, not a crash.
        let b = synthetic_inputs(6, 3, 2);
        let plan = optimize_capacity_warm(&b, &mut solver).expect("solvable");
        assert!(!plan.warm);
    }

    #[test]
    fn paper_scale_solves_within_pivot_budget() {
        // §5: l=20, r=20, g=5 took 33 s with a commercial solver.  The
        // old assertion here bounded summed wall-clock (< 3 s), which
        // flaked on loaded CI machines; pivots and B&B nodes measure the
        // same algorithmic work deterministically, so budget those
        // instead.  Wall-clock lives in benches/ilp_solver.rs and
        // PERF.md, where variance is expected and tracked, not asserted.
        let (mut pivots, mut nodes) = (0u64, 0usize);
        for model in 0..20u64 {
            let inp = synthetic_inputs(20, 5, model);
            let plan = optimize_capacity(&inp).expect("solvable");
            assert!(plan.pivots < 50_000, "model {model}: {} pivots", plan.pivots);
            assert!(plan.nodes < 2_000, "model {model}: {} B&B nodes", plan.nodes);
            pivots += plan.pivots;
            nodes += plan.nodes;
        }
        assert!(pivots < 400_000, "20-model batch took {pivots} pivots");
        assert!(nodes < 16_000, "20-model batch explored {nodes} nodes");
    }
}
