//! Dense two-phase primal simplex.
//!
//! Minimizes `c·x` subject to rows `a·x {≤,≥,=} b`, `x ≥ 0`.  Phase 1
//! drives artificial variables to zero (infeasibility detection); phase 2
//! optimizes the real objective.  Bland's rule guarantees termination.
//!
//! Problem sizes here are small (the capacity ILP decouples per model —
//! ≤ a few hundred rows), so a dense tableau is simpler and faster than a
//! revised implementation.
//!
//! The production capacity path now runs on the bounded-variable stack in
//! [`crate::opt::bounded`] (bounds in the tableau, warm starts); this
//! solver is retained as the independent equivalence oracle it is tested
//! against — keep the two implementations decoupled.

/// Row comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// `a·x ≤ b`.
    Le,
    /// `a·x ≥ b`.
    Ge,
    /// `a·x = b`.
    Eq,
}

/// A linear program in natural form (minimization).
#[derive(Debug, Clone)]
pub struct LinProg {
    /// Number of decision variables.
    pub n: usize,
    /// Objective coefficients (length n).
    pub c: Vec<f64>,
    /// Constraint rows: (coefficients length n, cmp, rhs).
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    /// An optimal vertex was found.
    Optimal {
        /// Variable values (length `n`).
        x: Vec<f64>,
        /// Objective value `c·x`.
        obj: f64,
    },
    /// No point satisfies the rows (with `x ≥ 0`).
    Infeasible,
    /// The objective decreases without bound along a feasible ray.
    Unbounded,
}

const EPS: f64 = 1e-9;

struct Tableau {
    /// m rows × width; the last column is the RHS.
    t: Vec<f64>,
    m: usize,
    width: usize,
    /// Basis variable per row.
    basis: Vec<usize>,
}

impl Tableau {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.t[r * self.width + c]
    }

    #[inline]
    fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.t[r * self.width + c]
    }

    /// Gaussian pivot on (row, col).
    fn pivot(&mut self, row: usize, col: usize) {
        let w = self.width;
        let d = self.at(row, col);
        debug_assert!(d.abs() > EPS);
        for c in 0..w {
            *self.at_mut(row, c) /= d;
        }
        for r in 0..self.m {
            if r != row {
                let f = self.at(r, col);
                if f.abs() > EPS {
                    for c in 0..w {
                        let v = self.at(row, c);
                        *self.at_mut(r, c) -= f * v;
                    }
                }
            }
        }
        self.basis[row] = col;
    }

    /// One simplex phase minimizing `obj` (a row of reduced costs over
    /// `ncols` structural columns).  Returns false on unboundedness.
    fn run(&mut self, obj: &mut [f64], mut obj_val: f64, ncols: usize) -> Option<f64> {
        loop {
            // Bland: entering = smallest index with negative reduced cost.
            let mut enter = None;
            for c in 0..ncols {
                if obj[c] < -EPS {
                    enter = Some(c);
                    break;
                }
            }
            let Some(col) = enter else {
                return Some(obj_val);
            };
            // Ratio test, Bland ties by smallest basis index.
            let mut leave: Option<(usize, f64)> = None;
            for r in 0..self.m {
                let a = self.at(r, col);
                if a > EPS {
                    let ratio = self.at(r, self.width - 1) / a;
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((lr, lratio)) => {
                            if ratio < lratio - EPS
                                || (ratio < lratio + EPS && self.basis[r] < self.basis[lr])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((row, _)) = leave else {
                return None; // unbounded
            };
            // Update the objective row alongside the tableau.
            let f = obj[col];
            self.pivot(row, col);
            if f.abs() > EPS {
                for c in 0..ncols {
                    obj[c] -= f * self.at(row, c);
                }
                obj_val -= f * self.at(row, self.width - 1);
            }
            // Keep the entering column's reduced cost exactly zero.
            obj[col] = 0.0;
        }
    }
}

/// Solve the LP.  See module docs.
pub fn solve(lp: &LinProg) -> LpOutcome {
    let n = lp.n;
    let m = lp.rows.len();
    debug_assert!(lp.c.len() == n);

    // Count auxiliary columns.
    let mut n_slack = 0;
    let mut n_art = 0;
    for (_, cmp, rhs) in &lp.rows {
        // After normalizing rhs >= 0:
        let cmp = if *rhs < 0.0 { flip(*cmp) } else { *cmp };
        match cmp {
            Cmp::Le => n_slack += 1,
            Cmp::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Cmp::Eq => n_art += 1,
        }
    }
    let ncols = n + n_slack + n_art;
    let width = ncols + 1;
    let mut tab = Tableau { t: vec![0.0; m * width], m, width, basis: vec![usize::MAX; m] };

    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    // Boolean column mask: O(1) artificial tests instead of scanning a
    // Vec per row per phase.
    let mut is_art = vec![false; ncols];
    for (r, (coeffs, cmp, rhs)) in lp.rows.iter().enumerate() {
        debug_assert!(coeffs.len() == n);
        let (sign, cmp, rhs) = if *rhs < 0.0 { (-1.0, flip(*cmp), -*rhs) } else { (1.0, *cmp, *rhs) };
        for (j, &a) in coeffs.iter().enumerate() {
            *tab.at_mut(r, j) = sign * a;
        }
        *tab.at_mut(r, ncols) = rhs;
        match cmp {
            Cmp::Le => {
                *tab.at_mut(r, s_idx) = 1.0;
                tab.basis[r] = s_idx;
                s_idx += 1;
            }
            Cmp::Ge => {
                *tab.at_mut(r, s_idx) = -1.0;
                s_idx += 1;
                *tab.at_mut(r, a_idx) = 1.0;
                tab.basis[r] = a_idx;
                is_art[a_idx] = true;
                a_idx += 1;
            }
            Cmp::Eq => {
                *tab.at_mut(r, a_idx) = 1.0;
                tab.basis[r] = a_idx;
                is_art[a_idx] = true;
                a_idx += 1;
            }
        }
    }

    // Phase 1: minimize the sum of artificials.
    if n_art > 0 {
        let mut obj = vec![0.0; ncols];
        for (c, &art) in is_art.iter().enumerate() {
            if art {
                obj[c] = 1.0;
            }
        }
        let mut obj_val = 0.0;
        // Price out initial basis (artificials start basic).
        for r in 0..m {
            if is_art[tab.basis[r]] {
                for c in 0..ncols {
                    obj[c] -= tab.at(r, c);
                }
                obj_val -= tab.at(r, ncols);
            }
        }
        match tab.run(&mut obj, obj_val, ncols) {
            Some(v) => {
                // `run` maintains obj_val = −(phase-1 objective), so −v is
                // the artificial mass left at the phase-1 optimum: any
                // residual means no feasible point exists.
                if -v > 1e-6 {
                    return LpOutcome::Infeasible;
                }
            }
            None => return LpOutcome::Infeasible,
        }
        // Belt-and-braces: the basic artificial values must agree with
        // the reduced objective (guards drift in the maintained obj_val).
        let art_sum: f64 = (0..m)
            .filter(|&r| is_art[tab.basis[r]])
            .map(|r| tab.at(r, ncols))
            .sum();
        if art_sum > 1e-6 {
            return LpOutcome::Infeasible;
        }
        // Drive remaining artificials out of the basis when possible.
        for r in 0..m {
            if is_art[tab.basis[r]] {
                if let Some(c) = (0..n + n_slack).find(|&c| tab.at(r, c).abs() > EPS) {
                    tab.pivot(r, c);
                }
            }
        }
    }

    // Phase 2: minimize the real objective over structural + slack columns
    // (artificial columns are frozen by giving them +inf cost — simply
    // exclude them from pricing).
    let ncols2 = n + n_slack;
    let mut obj = vec![0.0; ncols2];
    obj[..n].copy_from_slice(&lp.c);
    let mut obj_val = 0.0;
    for r in 0..m {
        let b = tab.basis[r];
        if b < n && lp.c[b].abs() > EPS {
            let f = lp.c[b];
            for c in 0..ncols2 {
                obj[c] -= f * tab.at(r, c);
            }
            obj_val -= f * tab.at(r, ncols);
        }
    }
    if tab.run(&mut obj, obj_val, ncols2).is_none() {
        return LpOutcome::Unbounded;
    }

    let mut x = vec![0.0; n];
    for r in 0..m {
        if tab.basis[r] < n {
            x[tab.basis[r]] = tab.at(r, ncols);
        }
    }
    let obj = lp.c.iter().zip(&x).map(|(c, v)| c * v).sum();
    LpOutcome::Optimal { x, obj }
}

fn flip(c: Cmp) -> Cmp {
    match c {
        Cmp::Le => Cmp::Ge,
        Cmp::Ge => Cmp::Le,
        Cmp::Eq => Cmp::Eq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(lp: &LinProg) -> (Vec<f64>, f64) {
        match solve(lp) {
            LpOutcome::Optimal { x, obj } => (x, obj),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_maximization() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 → x=2,y=6, obj=36.
        let lp = LinProg {
            n: 2,
            c: vec![-3.0, -5.0],
            rows: vec![
                (vec![1.0, 0.0], Cmp::Le, 4.0),
                (vec![0.0, 2.0], Cmp::Le, 12.0),
                (vec![3.0, 2.0], Cmp::Le, 18.0),
            ],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
        assert!((obj + 36.0).abs() < 1e-6);
    }

    #[test]
    fn ge_constraints_need_phase1() {
        // min x + y s.t. x + y >= 10, x >= 3 → obj 10.
        let lp = LinProg {
            n: 2,
            c: vec![1.0, 1.0],
            rows: vec![
                (vec![1.0, 1.0], Cmp::Ge, 10.0),
                (vec![1.0, 0.0], Cmp::Ge, 3.0),
            ],
        };
        let (x, obj) = optimal(&lp);
        assert!((obj - 10.0).abs() < 1e-6);
        assert!(x[0] >= 3.0 - 1e-9);
    }

    #[test]
    fn equality_constraints() {
        // min 2x + 3y s.t. x + y = 5, x - y = 1 → x=3, y=2, obj 12.
        let lp = LinProg {
            n: 2,
            c: vec![2.0, 3.0],
            rows: vec![
                (vec![1.0, 1.0], Cmp::Eq, 5.0),
                (vec![1.0, -1.0], Cmp::Eq, 1.0),
            ],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.0).abs() < 1e-6 && (x[1] - 2.0).abs() < 1e-6);
        assert!((obj - 12.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let lp = LinProg {
            n: 1,
            c: vec![1.0],
            rows: vec![
                (vec![1.0], Cmp::Le, 1.0),
                (vec![1.0], Cmp::Ge, 2.0),
            ],
        };
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        // min -x, x >= 0 unbounded below.
        let lp = LinProg { n: 1, c: vec![-1.0], rows: vec![(vec![1.0], Cmp::Ge, 0.0)] };
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // x <= 5 written as -x >= -5.
        let lp = LinProg {
            n: 1,
            c: vec![-1.0],
            rows: vec![(vec![-1.0], Cmp::Ge, -5.0)],
        };
        let (x, _) = optimal(&lp);
        assert!((x[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degeneracy stressor; Bland must terminate.
        let lp = LinProg {
            n: 4,
            c: vec![-0.75, 150.0, -0.02, 6.0],
            rows: vec![
                (vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0),
                (vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0),
                (vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0),
            ],
        };
        let (_, obj) = optimal(&lp);
        assert!((obj + 0.05).abs() < 1e-6);
    }
}
