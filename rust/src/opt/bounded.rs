//! Bounded-variable simplex with warm starts.
//!
//! The dense solver in [`crate::opt::simplex`] treats every variable as
//! `x ≥ 0` and therefore needs an explicit row (plus slack, plus possibly
//! an artificial) for each `x ≤ max`, `x ≥ min` and `u ≥ x − n` bound in
//! the capacity formulation — roughly `3·r·g` extra rows at (r regions,
//! g SKUs).  This module keeps bounds *in the tableau* instead: every
//! variable carries `[lo, hi]` and a nonbasic variable rests at one of its
//! finite bounds (a flag, not a row).  The row count for a capacity
//! instance drops from `~3rg + r + 1` to `r + 1 + rg`, shrinking the
//! dense tableau by roughly an order of magnitude at r=20, g=10.
//!
//! Beyond the smaller tableau, the state object is **warm-startable**:
//!
//! * [`SimplexState::set_rhs`] swaps the right-hand side in O(m²) using
//!   the identity that slack column `r` of the tableau is column `r` of
//!   the basis inverse — no refactorization, no rebuild.
//! * [`SimplexState::set_bounds`] tightens or relaxes variable bounds in
//!   O(n) — branch-and-bound nodes become bound edits, not row appends.
//! * [`SimplexState::solve_warm`] re-optimizes from the current basis
//!   with the **dual simplex** (the basis stays dual-feasible under rhs
//!   and bound changes), falling back to a cold two-phase primal solve
//!   when the basis is not reusable.
//!
//! Termination: the primal uses Bland's rule extended to bounds (entering
//! = smallest eligible index; ratio ties broken by smallest variable
//! index, with the entering variable's own bound flip competing under its
//! own index).  The dual uses a max-violation leaving rule under a hard
//! iteration cap — on cap the caller falls back to a cold solve, so the
//! warm path is an optimization, never a correctness risk.  After the
//! dual reaches primal feasibility a primal cleanup pass runs, so warm
//! results are optimal to the same tolerance as cold ones.

use crate::opt::simplex::Cmp;

/// Reduced-cost pricing threshold.
const EPS_D: f64 = 1e-7;
/// Pivot-element magnitude floor for ratio-test candidacy.
const EPS_A: f64 = 1e-8;
/// Primal bound-violation tolerance (dual leaving test, feasibility checks).
const EPS_X: f64 = 1e-6;
/// Tie tolerance in ratio tests.
const EPS_TIE: f64 = 1e-9;
/// Reduced costs are refreshed from the cost row every this many pivots to
/// bound drift from the incremental updates.
const D_REFRESH: u64 = 64;

/// A linear program with per-variable bounds (minimization).
///
/// Minimizes `c·x` subject to `rows` and `lo ≤ x ≤ hi`.  Lower bounds must
/// be finite; upper bounds may be `f64::INFINITY`.  Unlike
/// [`crate::opt::simplex::LinProg`] there is no implicit `x ≥ 0` — bounds
/// are explicit and live in the tableau, not in rows.
#[derive(Debug, Clone)]
pub struct BoundedLp {
    /// Number of structural variables.
    pub n: usize,
    /// Objective coefficients (length `n`).
    pub c: Vec<f64>,
    /// Constraint rows: (coefficients length `n`, cmp, rhs).
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
    /// Per-variable lower bounds (finite, length `n`).
    pub lo: Vec<f64>,
    /// Per-variable upper bounds (may be `INFINITY`, length `n`).
    pub hi: Vec<f64>,
}

impl BoundedLp {
    /// Lift a nonnegative-variable [`crate::opt::simplex::LinProg`] into
    /// the bounded form (`lo = 0`, `hi = ∞`).
    pub fn from_linprog(lp: &crate::opt::simplex::LinProg) -> BoundedLp {
        BoundedLp {
            n: lp.n,
            c: lp.c.clone(),
            rows: lp.rows.clone(),
            lo: vec![0.0; lp.n],
            hi: vec![f64::INFINITY; lp.n],
        }
    }
}

/// Solver outcome for the bounded simplex.
#[derive(Debug, Clone, PartialEq)]
pub enum BoundedOutcome {
    /// An optimal vertex: structural values and objective `c·x`.
    Optimal {
        /// Structural variable values (length `n`), clamped into bounds.
        x: Vec<f64>,
        /// Objective value `c·x`.
        obj: f64,
    },
    /// No point satisfies the rows and bounds.
    Infeasible,
    /// The objective decreases without bound along a feasible ray.
    Unbounded,
}

enum PrimalEnd {
    Optimal,
    Unbounded,
}

enum DualEnd {
    /// Primal feasibility restored; basis is optimal modulo a primal
    /// cleanup pass.
    Feasible,
    /// A violated row admits no entering column — primal infeasible
    /// (a Farkas certificate, independent of reduced-cost accuracy).
    Infeasible,
    /// The current basis is not dual-feasible; cold solve required.
    NotDualFeasible,
    /// Iteration cap hit; cold solve required.
    IterLimit,
}

/// Persistent tableau + basis for one bounded LP, reusable across
/// right-hand-side changes (control epochs) and bound tightenings
/// (branch-and-bound nodes).
///
/// The matrix (rows and costs) is fixed at construction; callers mutate
/// the rhs via [`set_rhs`](SimplexState::set_rhs) and structural bounds
/// via [`set_bounds`](SimplexState::set_bounds), then call
/// [`resolve`](SimplexState::resolve) which tries the warm dual path and
/// falls back to a cold two-phase primal solve.
#[derive(Debug, Clone)]
pub struct SimplexState {
    // --- immutable problem data (set at construction) ---
    m: usize,
    n: usize,
    /// Sign-normalized structural matrix, m×n row-major (`Ge` rows are
    /// stored negated so every slack has coefficient +1).
    a0: Vec<f64>,
    /// Sign-normalized right-hand side (updated by `set_rhs`).
    b0: Vec<f64>,
    /// +1 / −1 applied to each original row at build time.
    row_sign: Vec<f64>,
    /// Structural costs.
    c: Vec<f64>,

    // --- live solver state ---
    /// Active column count: n structurals + m slacks + live artificials.
    ncols: usize,
    /// Artificial columns currently appended (`ncols - n - m`).
    n_art: usize,
    /// Row-major tableau, m × width with the rhs at column `n + 2m`.
    /// Columns `[ncols, n + 2m)` are reserved (zero) artificial slots.
    t: Vec<f64>,
    width: usize,
    /// Basic column per row.
    basis: Vec<usize>,
    is_basic: Vec<bool>,
    /// Nonbasic variables rest at `lo` unless this flag says `hi`
    /// (only ever set for finite upper bounds).
    at_hi: Vec<bool>,
    /// Per-column bounds (structurals first, then slacks, then artificials).
    lo: Vec<f64>,
    hi: Vec<f64>,
    /// Reduced costs (maintained incrementally, refreshed periodically).
    d: Vec<f64>,
    /// Values of the basic variables per row.
    beta: Vec<f64>,
    /// Scratch copy of the pivot row.
    prow: Vec<f64>,
    /// Whether the tableau currently holds a factorized basis (a cold
    /// solve has run since construction).
    built: bool,
    /// Total pivots performed over the lifetime of this state (primal +
    /// dual + bound flips); snapshot around solves for per-solve counts.
    pivots: u64,
}

impl SimplexState {
    /// Build a state for `lp`.  No solve happens here; the first
    /// [`resolve`](SimplexState::resolve) runs cold.
    pub fn new(lp: &BoundedLp) -> SimplexState {
        let n = lp.n;
        let m = lp.rows.len();
        assert_eq!(lp.c.len(), n);
        assert_eq!(lp.lo.len(), n);
        assert_eq!(lp.hi.len(), n);
        let width = n + 2 * m + 1;
        let mut a0 = vec![0.0; m * n];
        let mut b0 = vec![0.0; m];
        let mut row_sign = vec![1.0; m];
        // Bounds over the full column space: structurals, slacks (Le/Ge
        // → [0, ∞), Eq → fixed [0, 0]), reserved artificial slots.
        let mut lo = vec![0.0; n + 2 * m];
        let mut hi = vec![f64::INFINITY; n + 2 * m];
        lo[..n].copy_from_slice(&lp.lo);
        hi[..n].copy_from_slice(&lp.hi);
        for (j, (&l, &h)) in lp.lo.iter().zip(&lp.hi).enumerate() {
            assert!(l.is_finite(), "lower bound of x{j} must be finite");
            assert!(l <= h + EPS_TIE, "empty bound interval on x{j}");
        }
        for (r, (coeffs, cmp, rhs)) in lp.rows.iter().enumerate() {
            assert_eq!(coeffs.len(), n);
            let sign = if *cmp == Cmp::Ge { -1.0 } else { 1.0 };
            row_sign[r] = sign;
            for (j, &a) in coeffs.iter().enumerate() {
                a0[r * n + j] = sign * a;
            }
            b0[r] = sign * rhs;
            if *cmp == Cmp::Eq {
                hi[n + r] = 0.0; // fixed slack
            }
        }
        SimplexState {
            m,
            n,
            a0,
            b0,
            row_sign,
            c: lp.c.clone(),
            ncols: n + m,
            n_art: 0,
            t: vec![0.0; m * width],
            width,
            basis: (n..n + m).collect(),
            is_basic: vec![false; n + 2 * m],
            at_hi: vec![false; n + 2 * m],
            lo,
            hi,
            d: vec![0.0; n + 2 * m],
            beta: vec![0.0; m],
            prow: vec![0.0; width],
            built: false,
            pivots: 0,
        }
    }

    /// Total pivots performed so far (primal + dual + bound flips).
    pub fn pivot_count(&self) -> u64 {
        self.pivots
    }

    /// Objective `c·x` of a structural point under this problem's costs.
    pub fn objective_of(&self, x: &[f64]) -> f64 {
        self.c.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Replace the right-hand side with the *original-form* values `b`
    /// (the same orientation the rows were given in; `Ge` rows are
    /// re-normalized internally).  O(m²): the rhs column is recomputed
    /// through the basis inverse read off the slack columns.
    pub fn set_rhs(&mut self, b: &[f64]) {
        assert_eq!(b.len(), self.m);
        for r in 0..self.m {
            self.b0[r] = self.row_sign[r] * b[r];
        }
        if !self.built {
            return;
        }
        // Slack column r of the tableau is column r of B⁻¹, so the new
        // rhs column is Σ_r b'_r · t[:, slack(r)].
        let w = self.width;
        let rhs = self.n + 2 * self.m;
        for rr in 0..self.m {
            let mut s = 0.0;
            for r in 0..self.m {
                let br = self.b0[r];
                if br != 0.0 {
                    s += br * self.t[rr * w + self.n + r];
                }
            }
            self.prow[rr] = s;
        }
        for rr in 0..self.m {
            self.t[rr * w + rhs] = self.prow[rr];
        }
    }

    /// Replace the structural bounds.  Returns `false` when some interval
    /// is empty (`lo > hi`) — the caller should treat the node as
    /// infeasible without solving.
    pub fn set_bounds(&mut self, lo: &[f64], hi: &[f64]) -> bool {
        assert_eq!(lo.len(), self.n);
        assert_eq!(hi.len(), self.n);
        let mut ok = true;
        for j in 0..self.n {
            self.lo[j] = lo[j];
            self.hi[j] = hi[j];
            if lo[j] > hi[j] + EPS_TIE {
                ok = false;
            }
            // A nonbasic variable parked at an upper bound that just
            // became infinite has nowhere to rest; move it to lo.
            if self.at_hi[j] && !hi[j].is_finite() {
                self.at_hi[j] = false;
            }
        }
        ok
    }

    /// Warm re-optimize from the current basis via the dual simplex.
    /// Returns `None` when the basis is not reusable (never built, not
    /// dual-feasible, or the iteration cap tripped) — fall back to
    /// [`solve_cold`](SimplexState::solve_cold).
    pub fn solve_warm(&mut self) -> Option<BoundedOutcome> {
        if !self.built {
            return None;
        }
        match self.dual() {
            DualEnd::Infeasible => Some(BoundedOutcome::Infeasible),
            DualEnd::NotDualFeasible | DualEnd::IterLimit => None,
            DualEnd::Feasible => match self.primal(false) {
                PrimalEnd::Unbounded => Some(BoundedOutcome::Unbounded),
                PrimalEnd::Optimal => Some(self.extract()),
            },
        }
    }

    /// Cold solve: rebuild the tableau from the stored matrix and run the
    /// two-phase primal simplex under the current rhs and bounds.
    pub fn solve_cold(&mut self) -> BoundedOutcome {
        self.rebuild();
        if self.n_art > 0 {
            match self.primal(true) {
                // Phase 1 minimizes a sum of bounded-below variables; it
                // cannot be unbounded, but fail closed if it reports so.
                PrimalEnd::Unbounded => return BoundedOutcome::Infeasible,
                PrimalEnd::Optimal => {}
            }
            let art_sum: f64 = (0..self.m)
                .filter(|&r| self.basis[r] >= self.n + self.m)
                .map(|r| self.beta[r].max(0.0))
                .sum();
            if art_sum > 1e-6 {
                return BoundedOutcome::Infeasible;
            }
            // Freeze the artificials at zero.  Ones still basic (at ~0)
            // stay: their [0, 0] bounds pin them through every later
            // ratio test, which is exactly the original row — no
            // drive-out pivots needed (and none through tiny elements).
            for a in self.n + self.m..self.ncols {
                self.lo[a] = 0.0;
                self.hi[a] = 0.0;
            }
            self.recompute_beta();
        }
        match self.primal(false) {
            PrimalEnd::Unbounded => BoundedOutcome::Unbounded,
            PrimalEnd::Optimal => self.extract(),
        }
    }

    /// Warm solve with automatic cold fallback.  Returns the outcome and
    /// whether the warm path succeeded.
    pub fn resolve(&mut self) -> (BoundedOutcome, bool) {
        if let Some(out) = self.solve_warm() {
            return (out, true);
        }
        (self.solve_cold(), false)
    }

    // ----- internals -------------------------------------------------

    /// Nonbasic resting value of column `j`.
    #[inline]
    fn val(&self, j: usize) -> f64 {
        if self.at_hi[j] {
            self.hi[j]
        } else {
            self.lo[j]
        }
    }

    /// Reset the tableau to the all-slack basis (structurals nonbasic at
    /// their lower bounds) and install artificial columns for rows whose
    /// slack value would violate its bounds.
    fn rebuild(&mut self) {
        let (n, m, w) = (self.n, self.m, self.width);
        let rhs = n + 2 * m;
        self.t.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..m {
            let row = &mut self.t[r * w..r * w + w];
            row[..n].copy_from_slice(&self.a0[r * n..r * n + n]);
            row[n + r] = 1.0;
            row[rhs] = self.b0[r];
            self.basis[r] = n + r;
        }
        self.ncols = n + m;
        self.n_art = 0;
        for j in 0..n + 2 * m {
            self.is_basic[j] = false;
            self.at_hi[j] = false;
        }
        for r in 0..m {
            self.is_basic[n + r] = true;
            // Reset artificial slots to a harmless default.
            self.lo[n + m + r] = 0.0;
            self.hi[n + m + r] = f64::INFINITY;
        }
        self.built = true;
        self.recompute_beta();
        // Install artificials where the initial slack value is outside
        // its bounds: below zero, or above zero on a fixed (Eq) slack.
        for r in 0..m {
            let s = n + r;
            if !self.is_basic[s] || self.basis[r] != s {
                continue;
            }
            let b = self.beta[r];
            let sign = if b < -EPS_X {
                -1.0
            } else if b > self.hi[s] + EPS_X {
                1.0
            } else {
                continue;
            };
            let col = self.ncols;
            self.ncols += 1;
            self.n_art += 1;
            self.lo[col] = 0.0;
            self.hi[col] = f64::INFINITY;
            self.t[r * w + col] = sign;
            self.is_basic[s] = false;
            self.at_hi[s] = false; // rests at lo = 0
            self.pivot(r, col);
            self.basis[r] = col;
            self.is_basic[col] = true;
        }
        if self.n_art > 0 {
            self.recompute_beta();
        }
    }

    /// Gaussian pivot on (row, col); updates the tableau only — basis
    /// bookkeeping and reduced costs are the caller's job.
    fn pivot(&mut self, row: usize, col: usize) {
        let w = self.width;
        let rhs = self.n + 2 * self.m;
        let piv = self.t[row * w + col];
        debug_assert!(piv.abs() > EPS_A);
        let inv = 1.0 / piv;
        for c in 0..self.ncols {
            self.t[row * w + c] *= inv;
        }
        self.t[row * w + rhs] *= inv;
        self.t[row * w + col] = 1.0;
        self.prow[..self.ncols].copy_from_slice(&self.t[row * w..row * w + self.ncols]);
        self.prow[rhs] = self.t[row * w + rhs];
        for r in 0..self.m {
            if r == row {
                continue;
            }
            let f = self.t[r * w + col];
            if f.abs() > 1e-12 {
                for c in 0..self.ncols {
                    self.t[r * w + c] -= f * self.prow[c];
                }
                self.t[r * w + rhs] -= f * self.prow[rhs];
                self.t[r * w + col] = 0.0;
            }
        }
    }

    /// Recompute basic values from the tableau and the nonbasic resting
    /// points: `β = B⁻¹b − Σ_{nonbasic j} (B⁻¹A)_j · val(j)`.
    fn recompute_beta(&mut self) {
        let w = self.width;
        let rhs = self.n + 2 * self.m;
        for r in 0..self.m {
            self.beta[r] = self.t[r * w + rhs];
        }
        for j in 0..self.ncols {
            if self.is_basic[j] {
                continue;
            }
            let v = self.val(j);
            if v != 0.0 {
                for r in 0..self.m {
                    self.beta[r] -= self.t[r * w + j] * v;
                }
            }
        }
    }

    /// Phase-aware cost of column `j`: phase 1 prices artificials at 1,
    /// phase 2 prices structurals at `c`.
    #[inline]
    fn cost(&self, j: usize, phase1: bool) -> f64 {
        if phase1 {
            if j >= self.n + self.m {
                1.0
            } else {
                0.0
            }
        } else if j < self.n {
            self.c[j]
        } else {
            0.0
        }
    }

    /// Recompute reduced costs from scratch for the given phase.
    fn recompute_d(&mut self, phase1: bool) {
        let w = self.width;
        for j in 0..self.ncols {
            self.d[j] = self.cost(j, phase1);
        }
        for r in 0..self.m {
            let cb = self.cost(self.basis[r], phase1);
            if cb != 0.0 {
                for c in 0..self.ncols {
                    self.d[c] -= cb * self.t[r * w + c];
                }
            }
        }
        for r in 0..self.m {
            self.d[self.basis[r]] = 0.0;
        }
    }

    /// Bounded primal simplex (Bland's rule with bound flips).  Assumes
    /// `beta` is current and the basis is primal-feasible on entry.
    fn primal(&mut self, phase1: bool) -> PrimalEnd {
        let w = self.width;
        self.recompute_d(phase1);
        let mut since_refresh = 0u64;
        loop {
            if since_refresh >= D_REFRESH {
                // Incremental updates drift; refresh from scratch.
                self.recompute_d(phase1);
                self.recompute_beta();
                since_refresh = 0;
            }
            // Entering: smallest-index nonbasic, non-fixed column whose
            // reduced cost improves in the feasible direction.
            let mut enter = None;
            for j in 0..self.ncols {
                if self.is_basic[j] || !(self.hi[j] - self.lo[j] > EPS_TIE) {
                    continue;
                }
                let dj = self.d[j];
                if (!self.at_hi[j] && dj < -EPS_D) || (self.at_hi[j] && dj > EPS_D) {
                    enter = Some(j);
                    break;
                }
            }
            let Some(j) = enter else {
                return PrimalEnd::Optimal;
            };
            let dir = if self.at_hi[j] { -1.0 } else { 1.0 };
            // Ratio test: the entering variable's own bound span competes
            // with every basic variable's slack to its nearer bound.
            // Bland ties go to the smallest variable index.
            let mut best_t = self.hi[j] - self.lo[j]; // may be ∞
            let mut best_idx = j;
            let mut leave: Option<usize> = None;
            for r in 0..self.m {
                let a = self.t[r * w + j];
                let rate = dir * a;
                let bi = self.basis[r];
                let lim = if rate > EPS_A {
                    (self.beta[r] - self.lo[bi]).max(0.0) / rate
                } else if rate < -EPS_A {
                    let hb = self.hi[bi];
                    if !hb.is_finite() {
                        continue;
                    }
                    (hb - self.beta[r]).max(0.0) / (-rate)
                } else {
                    continue;
                };
                if lim < best_t - EPS_TIE || (lim < best_t + EPS_TIE && bi < best_idx) {
                    best_t = lim.min(best_t);
                    best_idx = bi;
                    leave = Some(r);
                }
            }
            if !best_t.is_finite() {
                return PrimalEnd::Unbounded;
            }
            self.pivots += 1;
            since_refresh += 1;
            // Incremental basic-value update: moving the entering
            // variable by θ changes β_r at rate −dir·a_rj.
            let theta = best_t;
            match leave {
                None => {
                    // Bound flip: the entering variable crosses its whole
                    // interval; the basis is unchanged.
                    for r in 0..self.m {
                        self.beta[r] -= dir * self.t[r * w + j] * theta;
                    }
                    self.at_hi[j] = !self.at_hi[j];
                }
                Some(row) => {
                    let new_val = self.val(j) + dir * theta;
                    for r in 0..self.m {
                        if r != row {
                            self.beta[r] -= dir * self.t[r * w + j] * theta;
                        }
                    }
                    let a = self.t[row * w + j];
                    let rate = dir * a;
                    let leaving = self.basis[row];
                    // Increasing β means the leaving variable hit hi.
                    self.at_hi[leaving] = rate < 0.0;
                    self.is_basic[leaving] = false;
                    let f = self.d[j];
                    self.pivot(row, j);
                    self.basis[row] = j;
                    self.is_basic[j] = true;
                    self.at_hi[j] = false;
                    self.beta[row] = new_val;
                    if f != 0.0 {
                        for c in 0..self.ncols {
                            self.d[c] -= f * self.t[row * w + c];
                        }
                    }
                    self.d[j] = 0.0;
                }
            }
        }
    }

    /// Bounded dual simplex from the current basis.  Repairs primal
    /// feasibility while keeping reduced-cost signs; used for warm
    /// re-solves after rhs or bound changes.
    fn dual(&mut self) -> DualEnd {
        let w = self.width;
        self.recompute_d(false);
        // The basis must be dual-feasible for the dual method to apply;
        // tolerate small drift — the primal cleanup in `solve_warm`
        // restores exact optimality.
        for j in 0..self.ncols {
            if self.is_basic[j] || !(self.hi[j] - self.lo[j] > EPS_TIE) {
                continue;
            }
            let dj = self.d[j];
            if (!self.at_hi[j] && dj < -EPS_X) || (self.at_hi[j] && dj > EPS_X) {
                return DualEnd::NotDualFeasible;
            }
        }
        self.recompute_beta();
        let cap = 10 * (self.m + self.ncols) as u64 + 500;
        let mut iters = 0u64;
        let mut since_refresh = 0u64;
        loop {
            if since_refresh >= D_REFRESH {
                // Incremental updates drift; refresh from scratch.
                self.recompute_d(false);
                self.recompute_beta();
                since_refresh = 0;
            }
            // Leaving: the basic variable with the largest bound
            // violation (ties → smallest basis index).
            let mut sel: Option<(usize, f64, bool)> = None; // (row, viol, above)
            for r in 0..self.m {
                let bi = self.basis[r];
                let b = self.beta[r];
                let (viol, above) = if b < self.lo[bi] - EPS_X {
                    (self.lo[bi] - b, false)
                } else if self.hi[bi].is_finite() && b > self.hi[bi] + EPS_X {
                    (b - self.hi[bi], true)
                } else {
                    continue;
                };
                match sel {
                    None => sel = Some((r, viol, above)),
                    Some((sr, sv, _)) => {
                        if viol > sv + EPS_TIE
                            || (viol > sv - EPS_TIE && self.basis[r] < self.basis[sr])
                        {
                            sel = Some((r, viol, above));
                        }
                    }
                }
            }
            let Some((row, _, above)) = sel else {
                return DualEnd::Feasible;
            };
            iters += 1;
            if iters > cap {
                return DualEnd::IterLimit;
            }
            // Entering: dual ratio test over eligible nonbasic columns.
            // Eligibility: moving the entering variable off its bound in
            // its feasible direction must push the leaving variable back
            // toward the violated bound.
            let mut enter: Option<(usize, f64)> = None;
            for j in 0..self.ncols {
                if self.is_basic[j] || !(self.hi[j] - self.lo[j] > EPS_TIE) {
                    continue;
                }
                let a = self.t[row * w + j];
                if a.abs() <= EPS_A {
                    continue;
                }
                // Feasible move direction of nonbasic j: up from lo,
                // down from hi.  β_row changes at rate −dir·a.
                let dir = if self.at_hi[j] { -1.0 } else { 1.0 };
                let pushes_up = dir * a < 0.0;
                if pushes_up != !above {
                    // `above` needs β to decrease; `below` needs increase.
                    continue;
                }
                let ratio = self.d[j].abs() / a.abs();
                match enter {
                    None => enter = Some((j, ratio)),
                    Some((ej, er)) => {
                        if ratio < er - EPS_TIE || (ratio < er + EPS_TIE && j < ej) {
                            enter = Some((j, ratio));
                        }
                    }
                }
            }
            let Some((j, _)) = enter else {
                // The violated row cannot be repaired under the bounds —
                // a primal infeasibility certificate.
                return DualEnd::Infeasible;
            };
            self.pivots += 1;
            since_refresh += 1;
            let leaving = self.basis[row];
            // The entering variable moves exactly far enough to land the
            // leaving variable on its violated bound.
            let a = self.t[row * w + j];
            let dir = if self.at_hi[j] { -1.0 } else { 1.0 };
            let target = if above { self.hi[leaving] } else { self.lo[leaving] };
            let theta = ((self.beta[row] - target) / (dir * a)).max(0.0);
            let new_val = self.val(j) + dir * theta;
            for r in 0..self.m {
                if r != row {
                    self.beta[r] -= dir * self.t[r * w + j] * theta;
                }
            }
            self.at_hi[leaving] = above; // rests at the bound it violated
            self.is_basic[leaving] = false;
            let f = self.d[j];
            self.pivot(row, j);
            self.basis[row] = j;
            self.is_basic[j] = true;
            self.at_hi[j] = false;
            self.beta[row] = new_val;
            if f != 0.0 {
                for c in 0..self.ncols {
                    self.d[c] -= f * self.t[row * w + c];
                }
            }
            self.d[j] = 0.0;
        }
    }

    /// Read the optimal structural point out of the state.
    fn extract(&mut self) -> BoundedOutcome {
        // One exact refresh so incremental drift never reaches callers.
        self.recompute_beta();
        let mut x = vec![0.0; self.n];
        for j in 0..self.n {
            if !self.is_basic[j] {
                x[j] = self.val(j);
            }
        }
        for r in 0..self.m {
            if self.basis[r] < self.n {
                x[self.basis[r]] = self.beta[r];
            }
        }
        for j in 0..self.n {
            if x[j] < self.lo[j] {
                x[j] = self.lo[j];
            }
            if x[j] > self.hi[j] {
                x[j] = self.hi[j];
            }
        }
        let obj = self.objective_of(&x);
        BoundedOutcome::Optimal { x, obj }
    }
}

/// Solve a [`BoundedLp`] cold (fresh state, two-phase primal).
pub fn solve_bounded(lp: &BoundedLp) -> BoundedOutcome {
    SimplexState::new(lp).solve_cold()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::simplex::{Cmp, LinProg};

    fn optimal(lp: &BoundedLp) -> (Vec<f64>, f64) {
        match solve_bounded(lp) {
            BoundedOutcome::Optimal { x, obj } => (x, obj),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    fn lift(n: usize, c: Vec<f64>, rows: Vec<(Vec<f64>, Cmp, f64)>) -> BoundedLp {
        BoundedLp::from_linprog(&LinProg { n, c, rows })
    }

    #[test]
    fn textbook_maximization() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 → x=2,y=6, obj=36.
        let lp = lift(
            2,
            vec![-3.0, -5.0],
            vec![
                (vec![1.0, 0.0], Cmp::Le, 4.0),
                (vec![0.0, 2.0], Cmp::Le, 12.0),
                (vec![3.0, 2.0], Cmp::Le, 18.0),
            ],
        );
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
        assert!((obj + 36.0).abs() < 1e-6);
    }

    #[test]
    fn ge_rows_need_artificials() {
        // min x + y s.t. x + y >= 10, x >= 3 → obj 10.
        let lp = lift(
            2,
            vec![1.0, 1.0],
            vec![(vec![1.0, 1.0], Cmp::Ge, 10.0), (vec![1.0, 0.0], Cmp::Ge, 3.0)],
        );
        let (x, obj) = optimal(&lp);
        assert!((obj - 10.0).abs() < 1e-6);
        assert!(x[0] >= 3.0 - 1e-9);
    }

    #[test]
    fn equality_rows() {
        // min 2x + 3y s.t. x + y = 5, x - y = 1 → x=3, y=2, obj 12.
        let lp = lift(
            2,
            vec![2.0, 3.0],
            vec![(vec![1.0, 1.0], Cmp::Eq, 5.0), (vec![1.0, -1.0], Cmp::Eq, 1.0)],
        );
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.0).abs() < 1e-6 && (x[1] - 2.0).abs() < 1e-6);
        assert!((obj - 12.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let lp = lift(
            1,
            vec![1.0],
            vec![(vec![1.0], Cmp::Le, 1.0), (vec![1.0], Cmp::Ge, 2.0)],
        );
        assert_eq!(solve_bounded(&lp), BoundedOutcome::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let lp = lift(1, vec![-1.0], vec![(vec![1.0], Cmp::Ge, 0.0)]);
        assert_eq!(solve_bounded(&lp), BoundedOutcome::Unbounded);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degeneracy stressor; Bland-with-bounds must terminate.
        let lp = lift(
            4,
            vec![-0.75, 150.0, -0.02, 6.0],
            vec![
                (vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0),
                (vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0),
                (vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0),
            ],
        );
        let (_, obj) = optimal(&lp);
        assert!((obj + 0.05).abs() < 1e-6);
    }

    #[test]
    fn upper_bound_replaces_row() {
        // max x with x ∈ [0, 4] and no rows at all: a single bound flip.
        let lp = BoundedLp {
            n: 1,
            c: vec![-1.0],
            rows: vec![],
            lo: vec![0.0],
            hi: vec![4.0],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 4.0).abs() < 1e-9);
        assert!((obj + 4.0).abs() < 1e-9);
    }

    #[test]
    fn lower_bound_holds_without_rows() {
        // min 3x with x ∈ [2, 40] → x = 2.
        let lp = BoundedLp {
            n: 1,
            c: vec![3.0],
            rows: vec![],
            lo: vec![2.0],
            hi: vec![40.0],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((obj - 6.0).abs() < 1e-9);
    }

    #[test]
    fn fixed_variable_is_respected() {
        // min x + y, x fixed at 3, x + y >= 5 → y = 2.
        let lp = BoundedLp {
            n: 2,
            c: vec![1.0, 1.0],
            rows: vec![(vec![1.0, 1.0], Cmp::Ge, 5.0)],
            lo: vec![3.0, 0.0],
            hi: vec![3.0, f64::INFINITY],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.0).abs() < 1e-9);
        assert!((x[1] - 2.0).abs() < 1e-6);
        assert!((obj - 5.0).abs() < 1e-6);
    }

    #[test]
    fn capacity_shaped_instance() {
        // One region, one SKU: min 98x + 16u s.t. 500x ≥ 1800,
        // x − u ≤ 10, x ∈ [2, 20], u ≥ 0 → x = 3.6, u = 0.
        let lp = BoundedLp {
            n: 2,
            c: vec![98.0, 16.0],
            rows: vec![
                (vec![500.0, 0.0], Cmp::Ge, 1800.0),
                (vec![1.0, -1.0], Cmp::Le, 10.0),
            ],
            lo: vec![2.0, 0.0],
            hi: vec![20.0, f64::INFINITY],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.6).abs() < 1e-6, "x = {:?}", x);
        assert!(x[1].abs() < 1e-6);
        assert!((obj - 352.8).abs() < 1e-4);
    }

    #[test]
    fn warm_bound_tightening_matches_cold() {
        // The branch-and-bound motion: solve the relaxation, tighten the
        // integer bound, dual-resolve — identical to a cold solve.
        let lp = BoundedLp {
            n: 2,
            c: vec![98.0, 16.0],
            rows: vec![
                (vec![500.0, 0.0], Cmp::Ge, 1800.0),
                (vec![1.0, -1.0], Cmp::Le, 10.0),
            ],
            lo: vec![2.0, 0.0],
            hi: vec![20.0, f64::INFINITY],
        };
        let mut st = SimplexState::new(&lp);
        let root = st.solve_cold();
        assert!(matches!(root, BoundedOutcome::Optimal { .. }));

        // Up-branch x ≥ 4.
        assert!(st.set_bounds(&[4.0, 0.0], &[20.0, f64::INFINITY]));
        let (up, warm) = st.resolve();
        assert!(warm, "bound tightening should stay on the dual path");
        match up {
            BoundedOutcome::Optimal { x, obj } => {
                assert!((x[0] - 4.0).abs() < 1e-6);
                assert!((obj - 392.0).abs() < 1e-4);
            }
            other => panic!("expected optimal, got {other:?}"),
        }

        // Down-branch x ≤ 3 is infeasible (needs x ≥ 3.6).
        assert!(st.set_bounds(&[2.0, 0.0], &[3.0, f64::INFINITY]));
        let (down, _) = st.resolve();
        assert_eq!(down, BoundedOutcome::Infeasible);
    }

    #[test]
    fn warm_rhs_change_matches_cold() {
        let mk = |demand: f64| BoundedLp {
            n: 2,
            c: vec![98.0, 16.0],
            rows: vec![
                (vec![500.0, 0.0], Cmp::Ge, demand),
                (vec![1.0, -1.0], Cmp::Le, 10.0),
            ],
            lo: vec![2.0, 0.0],
            hi: vec![20.0, f64::INFINITY],
        };
        let mut st = SimplexState::new(&mk(1800.0));
        assert!(matches!(st.solve_cold(), BoundedOutcome::Optimal { .. }));
        let before = st.pivot_count();
        // Demand moves between epochs; only the rhs changes.
        st.set_rhs(&[2600.0, 10.0]);
        let (out, warm) = st.resolve();
        assert!(warm, "rhs swap should stay on the dual path");
        let warm_pivots = st.pivot_count() - before;
        let cold = solve_bounded(&mk(2600.0));
        match (out, cold) {
            (
                BoundedOutcome::Optimal { obj: a, .. },
                BoundedOutcome::Optimal { obj: b, .. },
            ) => assert!((a - b).abs() < 1e-6, "warm {a} vs cold {b}"),
            (a, b) => panic!("outcomes diverged: warm {a:?} cold {b:?}"),
        }
        assert!(warm_pivots <= 4, "rhs nudge took {warm_pivots} pivots");
    }

    #[test]
    fn negative_cost_with_infinite_upper_bound_is_caught() {
        // min -u with u free above and no binding row: unbounded.
        let lp = BoundedLp {
            n: 1,
            c: vec![-1.0],
            rows: vec![(vec![1.0], Cmp::Ge, 0.0)],
            lo: vec![0.0],
            hi: vec![f64::INFINITY],
        };
        assert_eq!(solve_bounded(&lp), BoundedOutcome::Unbounded);
    }

    #[test]
    fn empty_bound_interval_reports_infeasible_via_set_bounds() {
        let lp = BoundedLp {
            n: 1,
            c: vec![1.0],
            rows: vec![],
            lo: vec![0.0],
            hi: vec![5.0],
        };
        let mut st = SimplexState::new(&lp);
        st.solve_cold();
        assert!(!st.set_bounds(&[4.0], &[3.0]));
    }

    #[test]
    fn matches_dense_solver_on_shared_forms() {
        // Cross-check against the dense oracle on its own test problems.
        let problems = vec![
            LinProg {
                n: 2,
                c: vec![-3.0, -5.0],
                rows: vec![
                    (vec![1.0, 0.0], Cmp::Le, 4.0),
                    (vec![0.0, 2.0], Cmp::Le, 12.0),
                    (vec![3.0, 2.0], Cmp::Le, 18.0),
                ],
            },
            LinProg {
                n: 2,
                c: vec![2.0, 3.0],
                rows: vec![
                    (vec![1.0, 1.0], Cmp::Eq, 5.0),
                    (vec![1.0, -1.0], Cmp::Eq, 1.0),
                ],
            },
            LinProg {
                n: 1,
                c: vec![-1.0],
                rows: vec![(vec![-1.0], Cmp::Ge, -5.0)],
            },
        ];
        for lp in &problems {
            let dense = crate::opt::simplex::solve(lp);
            let bounded = solve_bounded(&BoundedLp::from_linprog(lp));
            match (dense, bounded) {
                (
                    crate::opt::simplex::LpOutcome::Optimal { obj: a, .. },
                    BoundedOutcome::Optimal { obj: b, .. },
                ) => assert!((a - b).abs() < 1e-6, "dense {a} vs bounded {b}"),
                (d, b) => panic!("outcomes diverged: dense {d:?} bounded {b:?}"),
            }
        }
    }
}
