//! Routing logic (§6.1): global region selection by effective memory
//! utilization, then within-region instance selection by
//! join-the-shortest-queue on remaining tokens.
//!
//! ## SKU affinity (heterogeneous fleets)
//!
//! On a multi-SKU fleet the short-timescale layer cooperates with the
//! pool-level scaler (the Chiron/OServe observation: hierarchical
//! autoscaling wins only when request placement works *with* capacity
//! placement).  With [`RoutingParams::sku_affinity`] on:
//!
//! * **long-context** requests (prompt+decode tokens ≥
//!   [`RoutingParams::long_ctx_tokens`]) prefer the fleet's highest-HBM
//!   SKU — their KV reservations crowd small-HBM instances out.  The
//!   preference only engages when the fleet actually spans HBM sizes
//!   ([`Cluster::hbm_diverse`]); on an HBM-uniform fleet it would just
//!   chase the tie-break SKU, so long-context requests follow the
//!   short-request policy there;
//! * **short interactive** requests prefer the *cheapest* SKU with
//!   headroom, keeping dear silicon free for the work that needs it;
//! * a **fallback cascade** walks the remaining SKUs in affinity order
//!   when the preferred SKU has no instance with headroom, and finally
//!   degenerates to plain JSQ over every eligible instance — so
//!   SKU-aware routing can never serve *fewer* requests than blind JSQ.
//!
//! Single-SKU fleets short-circuit to the blind path before any of this
//! runs, keeping every homogeneous paper experiment bit-identical.

use crate::config::{GpuKind, ModelKind, Region, RoutingParams, Tier};
use crate::sim::cluster::{Cluster, InstanceId};
use crate::sim::instance::InstState;

/// Fixed region-preference order: origin first, then the others in index
/// order — a stack array, no per-request allocation.
#[inline]
fn preference_order(origin: Region) -> [Region; 3] {
    let mut order = [origin; 3];
    let mut k = 1;
    for r in Region::ALL {
        if r != origin {
            order[k] = r;
            k += 1;
        }
    }
    order
}

/// Global routing for interactive requests (§6.1): first preferred region
/// (origin, then the others in index order) whose effective memory
/// utilization is under the threshold; otherwise the least-utilized one.
/// One pass over three O(1) aggregate reads — allocation-free.
///
/// Regions dark under the fault plane's availability mask are skipped
/// entirely (the mask is all-clear in fault-free runs, so this costs one
/// always-false branch per region).  If *every* region is dark the origin
/// is returned as a degenerate fallback — dispatch will find no instance
/// there and the request re-enters the retry path.
pub fn route_region(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    origin: Region,
) -> Region {
    let mut best = origin;
    let mut best_util = f64::INFINITY;
    for r in preference_order(origin) {
        if !cluster.region_available(r) {
            continue;
        }
        let util = cluster.effective_util(model, r);
        if util < params.region_util_threshold {
            return r;
        }
        // All saturated: least utilized wins.  Strict `<` keeps the
        // *first* minimal region in preference order, matching the
        // `min_by` this replaced (std returns the first equal minimum).
        if util < best_util {
            best = r;
            best_util = util;
        }
    }
    best
}

/// Instance selection within a region: JSQ over admitting instances whose
/// pool can serve the tier (minimum pending tokens, §6.1).  Falls back to
/// provisioning instances (they queue until ready) when nothing is active.
///
/// One pass over the endpoint's cached tier-eligible roster, tracking the
/// active and provisioning minima simultaneously; `pending_tokens` is an
/// O(1) counter read, so the whole decision is allocation-free.
pub fn route_instance(
    cluster: &Cluster,
    model: ModelKind,
    region: Region,
    tier: Tier,
) -> Option<InstanceId> {
    let ep = cluster.endpoints.get(&(model, region))?;
    let eligible = if tier.is_interactive() {
        &ep.iw_instances
    } else {
        &ep.niw_instances
    };
    // Strict `<` keeps the *first* minimal instance, matching the
    // `min_by_key` this replaced.
    let mut best_active: Option<(u64, InstanceId)> = None;
    let mut best_prov: Option<(u64, InstanceId)> = None;
    for &i in eligible {
        let inst = &cluster.instances[i];
        let slot = match inst.state {
            InstState::Active => &mut best_active,
            InstState::Provisioning { .. } => &mut best_prov,
            _ => continue,
        };
        let key = inst.pending_tokens();
        match slot {
            Some((bk, _)) if *bk <= key => {}
            _ => *slot = Some((key, i)),
        }
    }
    best_active.or(best_prov).map(|(_, i)| i)
}

/// Is this request long-context under the configured HBM threshold —
/// *and* does the fleet actually span HBM sizes?  On an HBM-uniform
/// fleet (e.g. 50/50 H100+A100, both 640 GiB) "prefer the high-HBM SKU"
/// would just chase the tie-break SKU for no memory benefit, so
/// long-context requests follow the same cheapest-with-headroom policy
/// as short ones there.
#[inline]
fn wants_high_hbm(cluster: &Cluster, params: &RoutingParams, total_tokens: u64) -> bool {
    cluster.hbm_diverse && total_tokens >= params.long_ctx_tokens
}

/// The request's SKU-affinity order over the fleet: highest-HBM-first
/// for long-context requests on an HBM-diverse fleet, cheapest-first
/// otherwise.  Copied into a stack array — allocation-free on the
/// per-request path.
#[inline]
fn sku_preference(
    cluster: &Cluster,
    params: &RoutingParams,
    total_tokens: u64,
) -> ([GpuKind; GpuKind::COUNT], usize) {
    let src = if wants_high_hbm(cluster, params, total_tokens) {
        &cluster.gpus_hbm_desc
    } else {
        &cluster.gpus_cost_asc
    };
    let mut out = [GpuKind::H100x8; GpuKind::COUNT];
    out[..src.len()].copy_from_slice(src);
    (out, src.len())
}

/// SKU-aware global routing: like [`route_region`], but a long-context
/// request first looks for a preferred (under-threshold) region where
/// the fleet's highest-HBM SKU still has KV headroom
/// ([`Cluster::sku_has_headroom`] — O(1) per-SKU aggregate reads), so a
/// cross-region spill is only paid when the target can actually serve
/// on the preferred SKU.  Short requests, HBM-uniform fleets and
/// single-SKU fleets fall through to the blind policy unchanged.
pub fn route_region_sku_aware(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    origin: Region,
    total_tokens: u64,
) -> Region {
    if !params.sku_affinity
        || cluster.gpus.len() == 1
        || !wants_high_hbm(cluster, params, total_tokens)
    {
        return route_region(cluster, params, model, origin);
    }
    let top_hbm = cluster.gpus_hbm_desc[0];
    for r in preference_order(origin) {
        if cluster.region_available(r)
            && cluster.effective_util(model, r) < params.region_util_threshold
            && cluster.sku_has_headroom(model, r, top_hbm, params.sku_headroom_util)
        {
            return r;
        }
    }
    // No under-threshold region has headroom on the preferred SKU: the
    // blind rule (first under-threshold region, else least-utilized)
    // decides.
    route_region(cluster, params, model, origin)
}

/// Region choice for NIW work released by the queue manager's capacity
/// signal (§6.2).  The signal means "this region has spare capacity",
/// so the default destination stays the signalling region — but on an
/// HBM-diverse fleet a *long-context* release deserves the same SKU
/// awareness as a live arrival: if the signalling region's top-HBM SKU
/// has no KV headroom, spill to the first preference-order region that
/// is under the utilization threshold *and* can actually serve on that
/// SKU.  Short releases, single-SKU and HBM-uniform fleets keep the
/// signalling region unconditionally, so homogeneous paper experiments
/// are bit-identical to the pre-fix behavior.
pub fn route_released_niw(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    signal_region: Region,
    total_tokens: u64,
) -> Region {
    if !params.sku_affinity
        || cluster.gpus.len() == 1
        || !wants_high_hbm(cluster, params, total_tokens)
    {
        return signal_region;
    }
    let top_hbm = cluster.gpus_hbm_desc[0];
    if cluster.sku_has_headroom(model, signal_region, top_hbm, params.sku_headroom_util) {
        return signal_region;
    }
    for r in preference_order(signal_region) {
        if cluster.region_available(r)
            && cluster.effective_util(model, r) < params.region_util_threshold
            && cluster.sku_has_headroom(model, r, top_hbm, params.sku_headroom_util)
        {
            return r;
        }
    }
    // Nowhere better: the capacity signal still stands, serve locally on
    // whatever SKU the instance cascade picks.
    signal_region
}

/// SKU-aware instance selection: JSQ *within* the request's preferred
/// SKU, cascading across the fleet in affinity order, with plain JSQ as
/// the terminal fallback.
///
/// One pass over the endpoint's cached tier-eligible roster tracks, per
/// SKU, the shortest-queue active instance that still has headroom
/// ((reserved KV + queued tokens) under
/// [`RoutingParams::sku_headroom_util`] of its KV capacity), alongside
/// the blind JSQ winners.  The cascade then takes the first affinity
/// SKU with a headroom instance; if every SKU is saturated the blind
/// active/provisioning pick is returned — exactly what
/// [`route_instance`] would have chosen.  Allocation-free; single-SKU
/// fleets and a disabled [`RoutingParams::sku_affinity`] short-circuit
/// to [`route_instance`].
pub fn route_instance_sku_aware(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    region: Region,
    tier: Tier,
    total_tokens: u64,
) -> Option<InstanceId> {
    if !params.sku_affinity || cluster.gpus.len() == 1 {
        return route_instance(cluster, model, region, tier);
    }
    let ep = cluster.endpoints.get(&(model, region))?;
    let eligible = if tier.is_interactive() {
        &ep.iw_instances
    } else {
        &ep.niw_instances
    };
    // Strict `<` keeps the *first* minimal instance per bucket, matching
    // the JSQ tie-break of the blind path.
    let mut best_by_sku: [Option<(u64, InstanceId)>; GpuKind::COUNT] = [None; GpuKind::COUNT];
    let mut best_active: Option<(u64, InstanceId)> = None;
    let mut best_prov: Option<(u64, InstanceId)> = None;
    for &i in eligible {
        let inst = &cluster.instances[i];
        let key = inst.pending_tokens();
        match inst.state {
            InstState::Active => {
                match best_active {
                    Some((bk, _)) if bk <= key => {}
                    _ => best_active = Some((key, i)),
                }
                let occupied = inst.kv_used + inst.waiting_tokens();
                if (occupied as f64) < params.sku_headroom_util * inst.kv_capacity as f64 {
                    let slot = &mut best_by_sku[inst.gpu.index()];
                    match slot {
                        Some((bk, _)) if *bk <= key => {}
                        _ => *slot = Some((key, i)),
                    }
                }
            }
            InstState::Provisioning { .. } => match best_prov {
                Some((bk, _)) if bk <= key => {}
                _ => best_prov = Some((key, i)),
            },
            _ => {}
        }
    }
    let (order, n) = sku_preference(cluster, params, total_tokens);
    for &gpu in &order[..n] {
        if let Some((_, id)) = best_by_sku[gpu.index()] {
            return Some(id);
        }
    }
    best_active.or(best_prov).map(|(_, i)| i)
}

/// Prefill-queue JSQ: instance selection for *admissions* on a
/// disaggregated fleet.  Arrivals must land on prefill instances (the
/// pool sized against the TTFT target), so this walks the endpoint's
/// prefill roster — JSQ on pending tokens over tier-eligible active
/// instances, provisioning ones as the fallback, exactly mirroring
/// [`route_instance`]'s tie-breaks.  If the prefill roster has no
/// eligible instance at all (e.g. every prefill VM crashed), the blind
/// unified path decides so the request is not stranded; the engine
/// records such degenerate completions without a handoff.
///
/// Never called when disaggregation is off — unified runs keep the
/// existing code path untouched.
pub fn route_instance_prefill(
    cluster: &Cluster,
    model: ModelKind,
    region: Region,
    tier: Tier,
) -> Option<InstanceId> {
    let ep = cluster.endpoints.get(&(model, region))?;
    let mut best_active: Option<(u64, InstanceId)> = None;
    let mut best_prov: Option<(u64, InstanceId)> = None;
    for &i in &ep.prefill_instances {
        let inst = &cluster.instances[i];
        let eligible = if tier.is_interactive() {
            inst.pool.serves_iw()
        } else {
            inst.pool.serves_niw()
        };
        if !eligible {
            continue;
        }
        let slot = match inst.state {
            InstState::Active => &mut best_active,
            InstState::Provisioning { .. } => &mut best_prov,
            _ => continue,
        };
        let key = inst.pending_tokens();
        match slot {
            Some((bk, _)) if *bk <= key => {}
            _ => *slot = Some((key, i)),
        }
    }
    best_active
        .or(best_prov)
        .map(|(_, i)| i)
        .or_else(|| route_instance(cluster, model, region, tier))
}

/// Decode placement for a completed prefill: prefer the KV-transfer
/// cheapest live decode instance.  Transfer cost is
/// `tokens × kv_bytes_per_token / per-SKU transfer rate`, so within a
/// region the fastest-transfer SKU wins (ties broken by JSQ on pending
/// tokens); regions are tried in preference order from the prefill
/// region — an intra-region transfer always beats paying the
/// inter-region hop.  Headroom-free instances are skipped on the first
/// pass; if no live decode instance anywhere has headroom the prefill
/// region's blind decode JSQ decides, and `None` is returned only when
/// no live region holds any admitting decode instance (the engine then
/// re-arms the handoff and retries after a backoff).
pub fn route_instance_decode(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    from_region: Region,
    tier: Tier,
    input_tokens: u64,
) -> Option<InstanceId> {
    let eligible = |inst: &crate::sim::instance::InstanceSim| {
        if tier.is_interactive() {
            inst.pool.serves_iw()
        } else {
            inst.pool.serves_niw()
        }
    };
    // Pass 1: cheapest transfer among headroom instances, nearest region
    // first.
    for r in preference_order(from_region) {
        if !cluster.region_available(r) {
            continue;
        }
        let Some(ep) = cluster.endpoints.get(&(model, r)) else {
            continue;
        };
        // (transfer time, pending tokens) lexicographic minimum; strict
        // `<` keeps the first minimum, matching the JSQ tie-break.
        let mut best: Option<(f64, u64, InstanceId)> = None;
        for &i in &ep.decode_instances {
            let inst = &cluster.instances[i];
            if inst.state != InstState::Active || !eligible(inst) {
                continue;
            }
            let occupied = inst.kv_used + inst.waiting_tokens();
            if (occupied as f64) >= params.sku_headroom_util * inst.kv_capacity as f64 {
                continue;
            }
            let cost = cluster.perf.profile(model, inst.gpu).kv_transfer_time(input_tokens);
            let pending = inst.pending_tokens();
            let better = match best {
                Some((bc, bp, _)) => cost < bc || (cost == bc && pending < bp),
                None => true,
            };
            if better {
                best = Some((cost, pending, i));
            }
        }
        if let Some((_, _, i)) = best {
            return Some(i);
        }
    }
    // Pass 2: every decode instance is past the headroom fraction —
    // blind JSQ over live decode rosters, nearest region first, active
    // before provisioning (work queues until capacity frees up).
    for r in preference_order(from_region) {
        if !cluster.region_available(r) {
            continue;
        }
        let Some(ep) = cluster.endpoints.get(&(model, r)) else {
            continue;
        };
        let mut best_active: Option<(u64, InstanceId)> = None;
        let mut best_prov: Option<(u64, InstanceId)> = None;
        for &i in &ep.decode_instances {
            let inst = &cluster.instances[i];
            if !eligible(inst) {
                continue;
            }
            let slot = match inst.state {
                InstState::Active => &mut best_active,
                InstState::Provisioning { .. } => &mut best_prov,
                _ => continue,
            };
            let key = inst.pending_tokens();
            match slot {
                Some((bk, _)) if *bk <= key => {}
                _ => *slot = Some((key, i)),
            }
        }
        if let Some((_, i)) = best_active.or(best_prov) {
            return Some(i);
        }
    }
    None
}

/// Failover routing for a retried (killed) request.  Like
/// [`route_region_sku_aware`], but with the fault plane in view:
///
/// 1. a region that is neither dark nor latency-degraded *and* under the
///    utilization threshold wins first, in preference order — a retry
///    should not land on a wobbling region when a clean one has room;
/// 2. otherwise the normal SKU-aware rule decides among live regions
///    (a degraded region beats losing the request);
/// 3. `None` only when *every* region is dark — the caller re-arms the
///    backoff timer or declares the request lost.
pub fn route_retry(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    origin: Region,
    total_tokens: u64,
) -> Option<Region> {
    if Region::ALL.iter().all(|&r| !cluster.region_available(r)) {
        return None;
    }
    for r in preference_order(origin) {
        if cluster.region_available(r)
            && !cluster.region_degraded(r)
            && cluster.effective_util(model, r) < params.region_util_threshold
        {
            return Some(r);
        }
    }
    Some(route_region_sku_aware(cluster, params, model, origin, total_tokens))
}

/// Extra latency charged when a request is served outside its origin
/// region (§2.1: ~50 ms inter-region).
pub fn routing_latency(params: &RoutingParams, origin: Region, served: Region) -> f64 {
    if origin == served {
        0.0
    } else {
        params.inter_region_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuKind, ScalingParams};
    use crate::perf::PerfTable;
    use crate::sim::cluster::PoolTag;

    fn cluster() -> Cluster {
        Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 2)],
            4,
        )
    }

    fn saturate(c: &mut Cluster, region: Region) {
        for id in c.endpoints[&(ModelKind::Llama2_70B, region)].instances.clone() {
            c.mutate(id, |inst| {
                inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
            });
        }
    }

    #[test]
    fn prefers_origin_when_under_threshold() {
        let c = cluster();
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::WestUs);
        assert_eq!(r, Region::WestUs);
    }

    #[test]
    fn spills_to_next_region_when_origin_hot() {
        let mut c = cluster();
        saturate(&mut c, Region::EastUs);
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_ne!(r, Region::EastUs);
    }

    #[test]
    fn all_hot_picks_least_utilized() {
        let mut c = cluster();
        for region in Region::ALL {
            saturate(&mut c, region);
        }
        // Make Central slightly cooler.
        let id = c.endpoints[&(ModelKind::Llama2_70B, Region::CentralUs)].instances[0];
        c.mutate(id, |inst| inst.kv_used = 0);
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_eq!(r, Region::CentralUs);
    }

    #[test]
    fn all_hot_tie_prefers_origin() {
        // Equal utilization everywhere: the first minimal region in
        // preference order (the origin) must win, matching `min_by`.
        let mut c = cluster();
        for region in Region::ALL {
            saturate(&mut c, region);
        }
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::WestUs);
        assert_eq!(r, Region::WestUs);
    }

    #[test]
    fn jsq_picks_emptiest_instance() {
        let mut c = cluster();
        let ids = c.active_instances(ModelKind::Llama2_70B, Region::EastUs);
        c.mutate(ids[0], |inst| inst.kv_used = 1000);
        c.push_waiting(ids[0], crate::trace::types::Request {
            id: 9,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: crate::trace::types::AppKind::Chat,
            input_tokens: 5000,
            output_tokens: 100,
        });
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(pick, ids[1]);
    }

    #[test]
    fn pool_filter_respected() {
        let mut c = Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::SiloIw, 2), (PoolTag::SiloNiw, 1)],
            0,
        );
        let _ = &mut c;
        let iw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(c.instances[iw].pool, PoolTag::SiloIw);
        let niw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::Niw).unwrap();
        assert_eq!(c.instances[niw].pool, PoolTag::SiloNiw);
    }

    #[test]
    fn falls_back_to_provisioning_instances() {
        let mut c = cluster();
        for id in c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)].instances.clone() {
            c.mutate(id, |inst| inst.state = InstState::Provisioning { until: 100.0 });
        }
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        assert!(pick.is_some());
    }

    // ------------------------------------------------------------------
    // Fault plane: dark-region exclusion and retry failover
    // ------------------------------------------------------------------

    #[test]
    fn routing_never_picks_a_dark_region() {
        let mut c = cluster();
        let p = RoutingParams::default();
        let m = ModelKind::Llama2_70B;
        // Dark origin: even though it is the preferred region, routing
        // must skip it.
        c.set_region_dark(Region::EastUs, true);
        assert_ne!(route_region(&c, &p, m, Region::EastUs), Region::EastUs);
        assert_ne!(route_region_sku_aware(&c, &p, m, Region::EastUs, 50_000), Region::EastUs);
        // Saturate the live regions: least-utilized still excludes dark.
        saturate(&mut c, Region::CentralUs);
        saturate(&mut c, Region::WestUs);
        assert_ne!(route_region(&c, &p, m, Region::EastUs), Region::EastUs);
    }

    #[test]
    fn retry_prefers_clean_regions_over_degraded() {
        let mut c = cluster();
        let p = RoutingParams::default();
        let m = ModelKind::Llama2_70B;
        c.set_region_dark(Region::EastUs, true);
        c.set_region_degraded(Region::CentralUs, 0.5);
        // The only clean live region wins even though Central precedes
        // West in preference order from East.
        assert_eq!(route_retry(&c, &p, m, Region::EastUs, 1_000), Some(Region::WestUs));
        // Saturating the clean region falls back to SKU-aware routing,
        // which may pick the degraded (but live) region — never the dark
        // one.
        saturate(&mut c, Region::WestUs);
        let r = route_retry(&c, &p, m, Region::EastUs, 1_000).unwrap();
        assert_ne!(r, Region::EastUs);
    }

    #[test]
    fn retry_returns_none_when_every_region_is_dark() {
        let mut c = cluster();
        let p = RoutingParams::default();
        for r in Region::ALL {
            c.set_region_dark(r, true);
        }
        assert_eq!(route_retry(&c, &p, ModelKind::Llama2_70B, Region::EastUs, 1_000), None);
    }

    #[test]
    fn latency_charged_cross_region_only() {
        let p = RoutingParams::default();
        assert_eq!(routing_latency(&p, Region::EastUs, Region::EastUs), 0.0);
        assert!(routing_latency(&p, Region::EastUs, Region::WestUs) > 0.0);
    }

    // ------------------------------------------------------------------
    // SKU-aware routing
    // ------------------------------------------------------------------

    fn three_way_cluster() -> Cluster {
        use crate::config::FleetSpec;
        Cluster::new_fleet(
            &[ModelKind::Llama2_70B],
            PerfTable::for_fleet(&GpuKind::ALL, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 6)],
            0,
            &FleetSpec::mixed_3way(),
        )
    }

    const LONG: u64 = 50_000; // ≥ default long_ctx_tokens
    const SHORT: u64 = 1_000;

    #[test]
    fn long_context_prefers_high_hbm_sku() {
        let c = three_way_cluster();
        let p = RoutingParams::default();
        let pick = route_instance_sku_aware(
            &c, &p, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, LONG,
        )
        .unwrap();
        assert_eq!(c.instances[pick].gpu, GpuKind::Mi300x8);
    }

    #[test]
    fn short_interactive_prefers_cheapest_sku() {
        let c = three_way_cluster();
        let p = RoutingParams::default();
        let pick = route_instance_sku_aware(
            &c, &p, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, SHORT,
        )
        .unwrap();
        assert_eq!(c.instances[pick].gpu, GpuKind::A100x8);
    }

    #[test]
    fn cascade_falls_through_saturated_skus() {
        let mut c = three_way_cluster();
        let p = RoutingParams::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        // Saturate every MI300 past the headroom threshold: a long
        // request must cascade to the next-HBM SKU (the 640 GiB tie
        // keeps fleet order ⇒ H100).
        let ids = c.endpoints[&(m, r)].instances.clone();
        for id in &ids {
            if c.instances[*id].gpu == GpuKind::Mi300x8 {
                c.mutate(*id, |inst| {
                    inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
                });
            }
        }
        let pick = route_instance_sku_aware(&c, &p, m, r, Tier::IwF, LONG).unwrap();
        assert_eq!(c.instances[pick].gpu, GpuKind::H100x8);
        // Saturate everything: the terminal fallback must equal blind JSQ.
        for id in &ids {
            c.mutate(*id, |inst| {
                inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
            });
        }
        let aware = route_instance_sku_aware(&c, &p, m, r, Tier::IwF, LONG).unwrap();
        let blind = route_instance(&c, m, r, Tier::IwF).unwrap();
        assert_eq!(aware, blind);
    }

    #[test]
    fn single_sku_fleet_short_circuits_to_blind_jsq() {
        let c = cluster(); // homogeneous H100
        let p = RoutingParams::default();
        for tokens in [SHORT, LONG] {
            let aware = route_instance_sku_aware(
                &c, &p, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, tokens,
            );
            let blind = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
            assert_eq!(aware, blind);
            assert_eq!(
                route_region_sku_aware(
                    &c, &p, ModelKind::Llama2_70B, Region::WestUs, tokens
                ),
                route_region(&c, &p, ModelKind::Llama2_70B, Region::WestUs)
            );
        }
    }

    #[test]
    fn region_routing_follows_high_hbm_capacity() {
        let mut c = three_way_cluster();
        let p = RoutingParams::default();
        let (m, origin) = (ModelKind::Llama2_70B, Region::EastUs);
        // Drain every MI300 in the origin region: a long-context request
        // should spill to the next preference region that still serves
        // the high-HBM SKU, even though the origin is under threshold.
        let ids = c.endpoints[&(m, origin)].instances.clone();
        for id in ids {
            if c.instances[id].gpu == GpuKind::Mi300x8 {
                c.mutate(id, |inst| inst.state = InstState::Draining);
            }
        }
        let r = route_region_sku_aware(&c, &p, m, origin, LONG);
        assert_ne!(r, origin);
        assert!(c.active_count_by_gpu(m, r, GpuKind::Mi300x8) > 0);
        // Short requests keep the blind region choice (origin is fine).
        assert_eq!(route_region_sku_aware(&c, &p, m, origin, SHORT), origin);
        // Saturate the remote MI300s past the headroom fraction too: an
        // active-but-full preferred SKU must not attract the spill — the
        // blind rule decides (origin, which is under threshold).
        for region in Region::ALL {
            let ids = c.endpoints[&(m, region)].instances.clone();
            for id in ids {
                if c.instances[id].gpu == GpuKind::Mi300x8 {
                    c.mutate(id, |inst| {
                        inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
                    });
                }
            }
        }
        assert_eq!(route_region_sku_aware(&c, &p, m, origin, LONG), origin);
    }

    #[test]
    fn released_niw_stays_in_signal_region_by_default() {
        let p = RoutingParams::default();
        // Homogeneous fleet: always the signalling region, long or short.
        let h = cluster();
        for tokens in [SHORT, LONG] {
            assert_eq!(
                route_released_niw(&h, &p, ModelKind::Llama2_70B, Region::WestUs, tokens),
                Region::WestUs
            );
        }
        // Mixed fleet with headroom everywhere: short releases stay, and
        // long releases stay too because the signal region's MI300s have
        // room.
        let c = three_way_cluster();
        for tokens in [SHORT, LONG] {
            assert_eq!(
                route_released_niw(&c, &p, ModelKind::Llama2_70B, Region::EastUs, tokens),
                Region::EastUs
            );
        }
    }

    #[test]
    fn released_long_niw_spills_when_signal_region_lacks_hbm_headroom() {
        let mut c = three_way_cluster();
        let p = RoutingParams::default();
        let (m, signal) = (ModelKind::Llama2_70B, Region::EastUs);
        // Saturate the signalling region's MI300s past the headroom
        // fraction: a long-context release must move to a region whose
        // top-HBM SKU can still take it.
        let ids = c.endpoints[&(m, signal)].instances.clone();
        for id in ids {
            if c.instances[id].gpu == GpuKind::Mi300x8 {
                c.mutate(id, |inst| {
                    inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
                });
            }
        }
        let dest = route_released_niw(&c, &p, m, signal, LONG);
        assert_ne!(dest, signal);
        assert!(c.sku_has_headroom(m, dest, GpuKind::Mi300x8, p.sku_headroom_util));
        // Short releases are unaffected by the saturation.
        assert_eq!(route_released_niw(&c, &p, m, signal, SHORT), signal);
        // Saturate every region's MI300s: fall back to the signalling
        // region (the capacity signal still stands).
        for region in Region::ALL {
            let ids = c.endpoints[&(m, region)].instances.clone();
            for id in ids {
                if c.instances[id].gpu == GpuKind::Mi300x8 {
                    c.mutate(id, |inst| {
                        inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
                    });
                }
            }
        }
        assert_eq!(route_released_niw(&c, &p, m, signal, LONG), signal);
    }

    #[test]
    fn hbm_uniform_fleet_disables_hbm_affinity() {
        use crate::config::FleetSpec;
        // 50/50 H100+A100: both 640 GiB, so "prefer high HBM" would just
        // chase the tie-break SKU.  Long-context requests must follow
        // the short-request policy (cheapest SKU with headroom) and the
        // region pass must stay blind.
        let c = Cluster::new_fleet(
            &[ModelKind::Llama2_70B],
            PerfTable::for_fleet(
                &[GpuKind::H100x8, GpuKind::A100x8],
                &[ModelKind::Llama2_70B],
            ),
            ScalingParams::default(),
            &[(PoolTag::Unified, 4)],
            0,
            &FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]),
        );
        assert!(!c.hbm_diverse);
        let p = RoutingParams::default();
        let pick =
            route_instance_sku_aware(&c, &p, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, LONG)
                .unwrap();
        assert_eq!(c.instances[pick].gpu, GpuKind::A100x8);
        assert_eq!(
            route_region_sku_aware(&c, &p, ModelKind::Llama2_70B, Region::WestUs, LONG),
            route_region(&c, &p, ModelKind::Llama2_70B, Region::WestUs)
        );
    }
}
