//! Routing logic (§6.1): global region selection by effective memory
//! utilization, then within-region instance selection by
//! join-the-shortest-queue on remaining tokens.

use crate::config::{ModelKind, Region, RoutingParams, Tier};
use crate::sim::cluster::{Cluster, InstanceId};
use crate::sim::instance::InstState;

/// Fixed region-preference order: origin first, then the others in index
/// order — a stack array, no per-request allocation.
#[inline]
fn preference_order(origin: Region) -> [Region; 3] {
    let mut order = [origin; 3];
    let mut k = 1;
    for r in Region::ALL {
        if r != origin {
            order[k] = r;
            k += 1;
        }
    }
    order
}

/// Global routing for interactive requests (§6.1): first preferred region
/// (origin, then the others in index order) whose effective memory
/// utilization is under the threshold; otherwise the least-utilized one.
/// One pass over three O(1) aggregate reads — allocation-free.
pub fn route_region(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    origin: Region,
) -> Region {
    let mut best = origin;
    let mut best_util = f64::INFINITY;
    for r in preference_order(origin) {
        let util = cluster.effective_util(model, r);
        if util < params.region_util_threshold {
            return r;
        }
        // All saturated: least utilized wins.  Strict `<` keeps the
        // *first* minimal region in preference order, matching the
        // `min_by` this replaced (std returns the first equal minimum).
        if util < best_util {
            best = r;
            best_util = util;
        }
    }
    best
}

/// Instance selection within a region: JSQ over admitting instances whose
/// pool can serve the tier (minimum pending tokens, §6.1).  Falls back to
/// provisioning instances (they queue until ready) when nothing is active.
///
/// One pass over the endpoint's cached tier-eligible roster, tracking the
/// active and provisioning minima simultaneously; `pending_tokens` is an
/// O(1) counter read, so the whole decision is allocation-free.
pub fn route_instance(
    cluster: &Cluster,
    model: ModelKind,
    region: Region,
    tier: Tier,
) -> Option<InstanceId> {
    let ep = cluster.endpoints.get(&(model, region))?;
    let eligible = if tier.is_interactive() {
        &ep.iw_instances
    } else {
        &ep.niw_instances
    };
    // Strict `<` keeps the *first* minimal instance, matching the
    // `min_by_key` this replaced.
    let mut best_active: Option<(u64, InstanceId)> = None;
    let mut best_prov: Option<(u64, InstanceId)> = None;
    for &i in eligible {
        let inst = &cluster.instances[i];
        let slot = match inst.state {
            InstState::Active => &mut best_active,
            InstState::Provisioning { .. } => &mut best_prov,
            _ => continue,
        };
        let key = inst.pending_tokens();
        match slot {
            Some((bk, _)) if *bk <= key => {}
            _ => *slot = Some((key, i)),
        }
    }
    best_active.or(best_prov).map(|(_, i)| i)
}

/// Extra latency charged when a request is served outside its origin
/// region (§2.1: ~50 ms inter-region).
pub fn routing_latency(params: &RoutingParams, origin: Region, served: Region) -> f64 {
    if origin == served {
        0.0
    } else {
        params.inter_region_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuKind, ScalingParams};
    use crate::perf::PerfTable;
    use crate::sim::cluster::PoolTag;

    fn cluster() -> Cluster {
        Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 2)],
            4,
        )
    }

    fn saturate(c: &mut Cluster, region: Region) {
        for id in c.endpoints[&(ModelKind::Llama2_70B, region)].instances.clone() {
            c.mutate(id, |inst| {
                inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
            });
        }
    }

    #[test]
    fn prefers_origin_when_under_threshold() {
        let c = cluster();
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::WestUs);
        assert_eq!(r, Region::WestUs);
    }

    #[test]
    fn spills_to_next_region_when_origin_hot() {
        let mut c = cluster();
        saturate(&mut c, Region::EastUs);
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_ne!(r, Region::EastUs);
    }

    #[test]
    fn all_hot_picks_least_utilized() {
        let mut c = cluster();
        for region in Region::ALL {
            saturate(&mut c, region);
        }
        // Make Central slightly cooler.
        let id = c.endpoints[&(ModelKind::Llama2_70B, Region::CentralUs)].instances[0];
        c.mutate(id, |inst| inst.kv_used = 0);
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_eq!(r, Region::CentralUs);
    }

    #[test]
    fn all_hot_tie_prefers_origin() {
        // Equal utilization everywhere: the first minimal region in
        // preference order (the origin) must win, matching `min_by`.
        let mut c = cluster();
        for region in Region::ALL {
            saturate(&mut c, region);
        }
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::WestUs);
        assert_eq!(r, Region::WestUs);
    }

    #[test]
    fn jsq_picks_emptiest_instance() {
        let mut c = cluster();
        let ids = c.active_instances(ModelKind::Llama2_70B, Region::EastUs);
        c.mutate(ids[0], |inst| inst.kv_used = 1000);
        c.push_waiting(ids[0], crate::trace::types::Request {
            id: 9,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: crate::trace::types::AppKind::Chat,
            input_tokens: 5000,
            output_tokens: 100,
        });
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(pick, ids[1]);
    }

    #[test]
    fn pool_filter_respected() {
        let mut c = Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::SiloIw, 2), (PoolTag::SiloNiw, 1)],
            0,
        );
        let _ = &mut c;
        let iw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(c.instances[iw].pool, PoolTag::SiloIw);
        let niw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::Niw).unwrap();
        assert_eq!(c.instances[niw].pool, PoolTag::SiloNiw);
    }

    #[test]
    fn falls_back_to_provisioning_instances() {
        let mut c = cluster();
        for id in c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)].instances.clone() {
            c.mutate(id, |inst| inst.state = InstState::Provisioning { until: 100.0 });
        }
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        assert!(pick.is_some());
    }

    #[test]
    fn latency_charged_cross_region_only() {
        let p = RoutingParams::default();
        assert_eq!(routing_latency(&p, Region::EastUs, Region::EastUs), 0.0);
        assert!(routing_latency(&p, Region::EastUs, Region::WestUs) > 0.0);
    }
}
