//! Routing logic (§6.1): global region selection by effective memory
//! utilization, then within-region instance selection by
//! join-the-shortest-queue on remaining tokens.

use crate::config::{ModelKind, Region, RoutingParams, Tier};
use crate::sim::cluster::{Cluster, InstanceId};
use crate::sim::instance::InstState;

/// Global routing for interactive requests (§6.1): first preferred region
/// (origin, then the others in index order) whose effective memory
/// utilization is under the threshold; otherwise the least-utilized one.
pub fn route_region(
    cluster: &Cluster,
    params: &RoutingParams,
    model: ModelKind,
    origin: Region,
) -> Region {
    let mut preference: Vec<Region> = vec![origin];
    for r in Region::ALL {
        if r != origin {
            preference.push(r);
        }
    }
    for &r in &preference {
        if cluster.effective_util(model, r) < params.region_util_threshold {
            return r;
        }
    }
    // All saturated: least utilized wins.
    preference
        .into_iter()
        .min_by(|&a, &b| {
            cluster
                .effective_util(model, a)
                .partial_cmp(&cluster.effective_util(model, b))
                .unwrap()
        })
        .unwrap()
}

/// Instance selection within a region: JSQ over admitting instances whose
/// pool can serve the tier (minimum pending tokens, §6.1).  Falls back to
/// provisioning instances (they queue until ready) when nothing is active.
pub fn route_instance(
    cluster: &Cluster,
    model: ModelKind,
    region: Region,
    tier: Tier,
) -> Option<InstanceId> {
    let ep = cluster.endpoints.get(&(model, region))?;
    let eligible = |state_ok: fn(&InstState) -> bool| {
        ep.instances
            .iter()
            .copied()
            .filter(|&i| {
                let inst = &cluster.instances[i];
                state_ok(&inst.state)
                    && if tier.is_interactive() {
                        inst.pool.serves_iw()
                    } else {
                        inst.pool.serves_niw()
                    }
            })
            .min_by_key(|&i| cluster.instances[i].pending_tokens())
    };
    eligible(|s| matches!(s, InstState::Active))
        .or_else(|| eligible(|s| matches!(s, InstState::Provisioning { .. })))
}

/// Extra latency charged when a request is served outside its origin
/// region (§2.1: ~50 ms inter-region).
pub fn routing_latency(params: &RoutingParams, origin: Region, served: Region) -> f64 {
    if origin == served {
        0.0
    } else {
        params.inter_region_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuKind, ScalingParams};
    use crate::perf::PerfTable;
    use crate::sim::cluster::PoolTag;

    fn cluster() -> Cluster {
        Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 2)],
            4,
        )
    }

    fn saturate(c: &mut Cluster, region: Region) {
        for &id in c.endpoints[&(ModelKind::Llama2_70B, region)].instances.clone().iter() {
            let cap = c.instances[id].kv_capacity;
            c.instances[id].kv_used = (cap as f64 * 0.9) as u64;
        }
    }

    #[test]
    fn prefers_origin_when_under_threshold() {
        let c = cluster();
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::WestUs);
        assert_eq!(r, Region::WestUs);
    }

    #[test]
    fn spills_to_next_region_when_origin_hot() {
        let mut c = cluster();
        saturate(&mut c, Region::EastUs);
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_ne!(r, Region::EastUs);
    }

    #[test]
    fn all_hot_picks_least_utilized() {
        let mut c = cluster();
        for region in Region::ALL {
            saturate(&mut c, region);
        }
        // Make Central slightly cooler.
        let id = c.endpoints[&(ModelKind::Llama2_70B, Region::CentralUs)].instances[0];
        c.instances[id].kv_used = 0;
        let r = route_region(&c, &RoutingParams::default(), ModelKind::Llama2_70B, Region::EastUs);
        assert_eq!(r, Region::CentralUs);
    }

    #[test]
    fn jsq_picks_emptiest_instance() {
        let mut c = cluster();
        let ids = c.active_instances(ModelKind::Llama2_70B, Region::EastUs);
        c.instances[ids[0]].kv_used = 1000;
        c.instances[ids[0]].push_waiting(crate::trace::types::Request {
            id: 9,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: crate::trace::types::AppKind::Chat,
            input_tokens: 5000,
            output_tokens: 100,
        });
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(pick, ids[1]);
    }

    #[test]
    fn pool_filter_respected() {
        let mut c = Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::SiloIw, 2), (PoolTag::SiloNiw, 1)],
            0,
        );
        let _ = &mut c;
        let iw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF).unwrap();
        assert_eq!(c.instances[iw].pool, PoolTag::SiloIw);
        let niw = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::Niw).unwrap();
        assert_eq!(c.instances[niw].pool, PoolTag::SiloNiw);
    }

    #[test]
    fn falls_back_to_provisioning_instances() {
        let mut c = cluster();
        for &id in c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)].instances.clone().iter() {
            c.instances[id].state = InstState::Provisioning { until: 100.0 };
        }
        let pick = route_instance(&c, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        assert!(pick.is_some());
    }

    #[test]
    fn latency_charged_cross_region_only() {
        let p = RoutingParams::default();
        assert_eq!(routing_latency(&p, Region::EastUs, Region::EastUs), 0.0);
        assert!(routing_latency(&p, Region::EastUs, Region::WestUs) > 0.0);
    }
}
