//! Layer-3 coordination: SageServe's system contribution.
//!
//! * [`scheduler`] — instance-level request ordering: FCFS / EDF / PF /
//!   DPA (§6.5).
//! * [`router`] — global region routing and within-region JSQ instance
//!   routing (§6.1).
//! * [`queue_manager`] — asynchronous NIW admission with deadline aging
//!   (§6.2).
//! * [`autoscaler`] — Siloed and Unified-Reactive baselines, the LT-I /
//!   LT-U / LT-UA predictive strategies (§6.4), and the Chiron SOTA
//!   baseline [34].
//! * [`controller`] — the hourly forecast + ILP loop (§6.3).

pub mod autoscaler;
pub mod controller;
pub mod queue_manager;
pub mod router;
pub mod scheduler;

pub use scheduler::SchedPolicy;
