//! The NIW Queue Manager (§6.2).
//!
//! NIW requests park here instead of hitting instances directly.  Each
//! model endpoint signals its effective utilization; below 60% the manager
//! releases one queued request to that (model, region), below 50% two.
//! Requests aging past 10 h are upgraded to priority 0 and routed
//! immediately like interactive traffic (deadline protection, 24 h SLA).
//!
//! The manager itself is SKU-blind: a release names the *signalling*
//! region, and the engine then runs it through
//! [`router::route_released_niw`](crate::coordinator::router::route_released_niw)
//! so long-context releases get the same HBM-affinity cascade as live
//! arrivals.

use std::collections::{BTreeMap, VecDeque};

use crate::config::{ModelKind, Region, ScalingParams, Time};
use crate::trace::types::Request;

/// Per-model NIW queues (region is chosen at release time).
#[derive(Debug, Default)]
pub struct QueueManager {
    queues: BTreeMap<ModelKind, VecDeque<Request>>,
    /// Requests currently parked across all queues (kept incrementally —
    /// the engine polls total depth every event-loop iteration).
    depth_total: usize,
    /// Lifetime count of NIW requests parked here.
    pub total_enqueued: u64,
    /// Lifetime count leaving the queues (released, aged or drained).
    pub total_released: u64,
    /// Lifetime count shed under graceful degradation (NOT counted in
    /// `total_released` — shed requests never reach an instance).
    pub total_shed: u64,
}

impl QueueManager {
    /// An empty manager (no queues until the first enqueue).
    pub fn new() -> Self {
        Self::default()
    }

    /// Park an NIW request in its model's FIFO.
    pub fn enqueue(&mut self, req: Request) {
        debug_assert!(!req.tier.is_interactive());
        self.queues.entry(req.model).or_default().push_back(req);
        self.depth_total += 1;
        self.total_enqueued += 1;
    }

    /// Parked requests for one model.
    pub fn depth(&self, model: ModelKind) -> usize {
        self.queues.get(&model).map(|q| q.len()).unwrap_or(0)
    }

    /// Total parked requests — O(1) counter read.
    pub fn total_depth(&self) -> usize {
        self.depth_total
    }

    /// How many requests a utilization signal releases (§6.2 thresholds).
    pub fn release_count(params: &ScalingParams, util: f64) -> usize {
        if util < params.niw_release_util_2 {
            2
        } else if util < params.niw_release_util_1 {
            1
        } else {
            0
        }
    }

    /// Handle a capacity signal from a (model, region) endpoint: pop up to
    /// `release_count(util)` requests for that model, paired with the
    /// signalling region.  That region is the *default* destination — the
    /// engine passes each release through the SKU-aware cascade
    /// (`router::route_released_niw`), which may redirect long-context
    /// work on HBM-diverse fleets.
    pub fn on_capacity_signal(
        &mut self,
        params: &ScalingParams,
        model: ModelKind,
        region: Region,
        util: f64,
    ) -> Vec<(Request, Region)> {
        let n = Self::release_count(params, util);
        let mut out = Vec::new();
        if let Some(q) = self.queues.get_mut(&model) {
            for _ in 0..n {
                match q.pop_front() {
                    Some(r) => out.push((r, region)),
                    None => break,
                }
            }
        }
        self.depth_total -= out.len();
        self.total_released += out.len() as u64;
        out
    }

    /// Aging scan (§6.2): requests older than the aging threshold are
    /// upgraded to priority 0 and must be routed immediately (the caller
    /// routes them like IW traffic).
    pub fn pop_aged(&mut self, params: &ScalingParams, now: Time) -> Vec<Request> {
        let mut out = Vec::new();
        for q in self.queues.values_mut() {
            while let Some(front) = q.front() {
                if now - front.arrival > params.niw_aging_secs {
                    out.push(q.pop_front().unwrap());
                } else {
                    break; // FIFO queues: the front is the oldest
                }
            }
        }
        self.depth_total -= out.len();
        self.total_released += out.len() as u64;
        out
    }

    /// Graceful degradation under sustained capacity loss (fault plane):
    /// shed the *newest* parked requests of one model until the queue
    /// depth fits under `cap` (what the surviving fleet can plausibly
    /// absorb).  Shedding newest-first preserves the FIFO head — the
    /// requests closest to their 24 h deadline keep their place.  Shed
    /// requests leave the system for good (counted once in `total_shed`,
    /// never in `total_released`); interactive traffic is untouched by
    /// construction because only NIW work ever parks here.
    pub fn shed_over_depth(&mut self, model: ModelKind, cap: usize) -> Vec<Request> {
        let mut out = Vec::new();
        if let Some(q) = self.queues.get_mut(&model) {
            while q.len() > cap {
                out.push(q.pop_back().unwrap());
            }
        }
        self.depth_total -= out.len();
        self.total_shed += out.len() as u64;
        out
    }

    /// Drain everything (end-of-run flush so no request is lost).
    pub fn drain_all(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        for q in self.queues.values_mut() {
            out.extend(q.drain(..));
        }
        self.depth_total = 0;
        self.total_released += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Tier;
    use crate::trace::types::AppKind;

    fn niw(id: u64, arrival: Time, model: ModelKind) -> Request {
        Request {
            id,
            arrival,
            model,
            origin: Region::EastUs,
            tier: Tier::Niw,
            app: AppKind::DocSummary,
            input_tokens: 1000,
            output_tokens: 500,
        }
    }

    #[test]
    fn thresholds_release_counts() {
        let p = ScalingParams::default();
        assert_eq!(QueueManager::release_count(&p, 0.70), 0);
        assert_eq!(QueueManager::release_count(&p, 0.59), 1);
        assert_eq!(QueueManager::release_count(&p, 0.49), 2);
    }

    #[test]
    fn capacity_signal_pops_fifo_for_model() {
        let p = ScalingParams::default();
        let mut qm = QueueManager::new();
        qm.enqueue(niw(1, 0.0, ModelKind::Bloom176B));
        qm.enqueue(niw(2, 1.0, ModelKind::Bloom176B));
        qm.enqueue(niw(3, 2.0, ModelKind::Llama2_70B));
        let rel = qm.on_capacity_signal(&p, ModelKind::Bloom176B, Region::WestUs, 0.45);
        assert_eq!(rel.len(), 2);
        assert_eq!(rel[0].0.id, 1);
        assert_eq!(rel[0].1, Region::WestUs);
        assert_eq!(qm.depth(ModelKind::Bloom176B), 0);
        assert_eq!(qm.depth(ModelKind::Llama2_70B), 1);
    }

    #[test]
    fn no_release_when_util_high() {
        let p = ScalingParams::default();
        let mut qm = QueueManager::new();
        qm.enqueue(niw(1, 0.0, ModelKind::Bloom176B));
        let rel = qm.on_capacity_signal(&p, ModelKind::Bloom176B, Region::EastUs, 0.8);
        assert!(rel.is_empty());
        assert_eq!(qm.depth(ModelKind::Bloom176B), 1);
    }

    #[test]
    fn aging_pops_only_old_requests() {
        let p = ScalingParams::default();
        let mut qm = QueueManager::new();
        qm.enqueue(niw(1, 0.0, ModelKind::Bloom176B));
        qm.enqueue(niw(2, 30_000.0, ModelKind::Bloom176B));
        // now = 10h + 1s after the first arrival.
        let aged = qm.pop_aged(&p, 36_001.0);
        assert_eq!(aged.len(), 1);
        assert_eq!(aged[0].id, 1);
        assert_eq!(qm.depth(ModelKind::Bloom176B), 1);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut qm = QueueManager::new();
        qm.enqueue(niw(1, 0.0, ModelKind::Bloom176B));
        qm.enqueue(niw(2, 0.0, ModelKind::Llama31_8B));
        assert_eq!(qm.drain_all().len(), 2);
        assert_eq!(qm.total_depth(), 0);
    }

    #[test]
    fn shed_removes_newest_first_and_counts_exactly_once() {
        let p = ScalingParams::default();
        let mut qm = QueueManager::new();
        for i in 0..5 {
            qm.enqueue(niw(i, i as f64, ModelKind::Bloom176B));
        }
        let shed = qm.shed_over_depth(ModelKind::Bloom176B, 2);
        // Newest-first: ids 4, 3, 2 go; the FIFO head (oldest) survives.
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 3, 2]);
        assert_eq!(qm.depth(ModelKind::Bloom176B), 2);
        assert_eq!(qm.total_shed, 3);
        assert_eq!(qm.total_released, 0, "shed is not a release");
        // Already under cap: a second sweep sheds nothing — exactly-once.
        assert!(qm.shed_over_depth(ModelKind::Bloom176B, 2).is_empty());
        assert_eq!(qm.total_shed, 3);
        // The survivors drain normally at end of run.
        assert_eq!(qm.drain_all().len(), 2);
        assert_eq!(qm.total_enqueued, 5);
        assert_eq!(qm.total_released + qm.total_shed, 5);
    }

    #[test]
    fn counters_track_flow() {
        let p = ScalingParams::default();
        let mut qm = QueueManager::new();
        qm.enqueue(niw(1, 0.0, ModelKind::Bloom176B));
        qm.enqueue(niw(2, 0.0, ModelKind::Bloom176B));
        qm.on_capacity_signal(&p, ModelKind::Bloom176B, Region::EastUs, 0.55);
        assert_eq!(qm.total_enqueued, 2);
        assert_eq!(qm.total_released, 1);
        assert_eq!(qm.total_depth(), 1, "O(1) depth counter stays coherent");
    }
}
