//! Instance-level scheduling policies (§6.5).
//!
//! The scheduler orders an instance's waiting queue; the batcher then
//! admits in that order until GPU memory is exhausted.  `d_r` is the
//! remaining time to the request's TTFT deadline (negative = expired).

use crate::config::{Tier, Time};
use crate::trace::types::Request;

/// The four policies evaluated in Fig 15.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedPolicy {
    /// First-come-first-served (baseline).
    Fcfs,
    /// Earliest deadline first; expired deadlines jump the queue.
    Edf,
    /// All IW-F (FCFS among themselves) before any IW-N.
    Pf,
    /// Deadline-and-priority aware with thresholds `tau_n` (severe
    /// expiry) and `tau_p` (urgency window).
    Dpa { tau_n: Time, tau_p: Time },
}

impl SchedPolicy {
    /// Default DPA thresholds used in the evaluation.
    pub fn dpa_default() -> SchedPolicy {
        SchedPolicy::Dpa { tau_n: 30.0, tau_p: 2.0 }
    }

    /// Full sort key: (§6.1 NIW priority, policy class, policy primary,
    /// arrival, id).  Arrival + id make the order total and deterministic.
    fn key(&self, r: &Request, now: Time) -> (u8, u8, f64, f64, u64) {
        let prio = niw_priority(r, now);
        let (class, primary) = match self {
            SchedPolicy::Fcfs => (0u8, r.arrival),
            SchedPolicy::Edf => (0u8, r.ttft_slack(now)),
            SchedPolicy::Pf => ((r.tier != Tier::IwF) as u8, r.arrival),
            SchedPolicy::Dpa { tau_n, tau_p } => {
                (dpa_class(r, now, *tau_n, *tau_p), r.arrival)
            }
        };
        (prio, class, primary, r.arrival, r.id)
    }

    fn cmp(&self, a: &Request, b: &Request, now: Time) -> std::cmp::Ordering {
        let ka = self.key(a, now);
        let kb = self.key(b, now);
        ka.0.cmp(&kb.0)
            .then(ka.1.cmp(&kb.1))
            .then(ka.2.partial_cmp(&kb.2).unwrap_or(std::cmp::Ordering::Equal))
            .then(ka.3.partial_cmp(&kb.3).unwrap_or(std::cmp::Ordering::Equal))
            .then(ka.4.cmp(&kb.4))
    }

    /// Order `queue` in-place so that position 0 is served first.
    ///
    /// Regardless of policy, the §6.1 priority rule applies first:
    /// priority-0 requests (all IW, plus NIW whose age exceeds the 10 h
    /// aging threshold) come before priority-1 (fresh NIW).
    pub fn order(&self, queue: &mut [Request], now: Time) {
        queue.sort_by(|a, b| self.cmp(a, b, now));
    }

    /// Order only the serving head: the `k` highest-priority requests end
    /// up sorted at the front (O(n + k log k) — the admission path only
    /// consumes the head, so deep overload queues stay cheap to manage).
    pub fn order_head(&self, queue: &mut Vec<Request>, now: Time, k: usize) {
        if queue.len() <= k {
            self.order(queue, now);
            return;
        }
        queue.select_nth_unstable_by(k, |a, b| self.cmp(a, b, now));
        self.order(&mut queue[..k], now);
    }
}

/// §6.1 priority: 0 for interactive and aged NIW, 1 for fresh NIW.
fn niw_priority(r: &Request, now: Time) -> u8 {
    if r.tier.is_interactive() || now - r.arrival > 10.0 * 3600.0 {
        0
    } else {
        1
    }
}

/// DPA ordering classes (§6.5): (1) severely expired, (2) urgent IW-F,
/// (3) urgent IW-N, (4) non-urgent IW-F, (5) non-urgent IW-N,
/// (6) recently expired.  NIW requests (priority-1 until aged) sort after
/// interactive traffic within their class by mapping to class 7 unless
/// severely expired.
fn dpa_class(r: &Request, now: Time, tau_n: Time, tau_p: Time) -> u8 {
    let d = r.ttft_slack(now);
    if d < -tau_n {
        return 1; // severely expired: starvation guard
    }
    if !r.tier.is_interactive() {
        return 7; // default-priority NIW rides behind IW classes
    }
    if d < 0.0 {
        6 // recently expired
    } else if d <= tau_p {
        if r.tier == Tier::IwF {
            2
        } else {
            3
        }
    } else if r.tier == Tier::IwF {
        4
    } else {
        5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, Region};
    use crate::trace::types::AppKind;

    fn req(id: u64, arrival: Time, tier: Tier) -> Request {
        Request {
            id,
            arrival,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier,
            app: AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        }
    }

    fn ids(q: &[Request]) -> Vec<u64> {
        q.iter().map(|r| r.id).collect()
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let mut q = vec![req(2, 5.0, Tier::IwN), req(1, 1.0, Tier::IwF), req(3, 9.0, Tier::IwF)];
        SchedPolicy::Fcfs.order(&mut q, 10.0);
        assert_eq!(ids(&q), vec![1, 2, 3]);
    }

    #[test]
    fn edf_puts_tightest_deadline_first() {
        // At now=10: IW-F arrived t=9.5 has slack 0.5; IW-N arrived t=0 has
        // slack 50; expired IW-F arrived t=5 has slack -4.
        let mut q = vec![req(1, 0.0, Tier::IwN), req(2, 9.5, Tier::IwF), req(3, 5.0, Tier::IwF)];
        SchedPolicy::Edf.order(&mut q, 10.0);
        assert_eq!(ids(&q), vec![3, 2, 1]);
    }

    #[test]
    fn edf_breaks_simultaneous_arrivals_by_tier() {
        // Same arrival: IW-F has the stricter TTFT ⇒ first (§6.5).
        let mut q = vec![req(1, 0.0, Tier::IwN), req(2, 0.0, Tier::IwF)];
        SchedPolicy::Edf.order(&mut q, 0.1);
        assert_eq!(ids(&q), vec![2, 1]);
    }

    #[test]
    fn pf_is_absolute_tier_priority() {
        let mut q = vec![req(1, 0.0, Tier::IwN), req(2, 100.0, Tier::IwF), req(3, 50.0, Tier::IwN)];
        SchedPolicy::Pf.order(&mut q, 100.0);
        assert_eq!(ids(&q), vec![2, 1, 3]);
    }

    #[test]
    fn dpa_severely_expired_first() {
        let tau_n = 30.0;
        // now=100: id1 IW-N arrived 0 → slack -40+60.. compute: slack = 0+60-100 = -40 < -30 severe.
        // id2 IW-F arrived 99.5 → slack 0.5 urgent. id3 IW-F arrived 90 → slack -9 recent-expired.
        let mut q = vec![
            req(3, 90.0, Tier::IwF),
            req(1, 0.0, Tier::IwN),
            req(2, 99.5, Tier::IwF),
        ];
        SchedPolicy::Dpa { tau_n, tau_p: 2.0 }.order(&mut q, 100.0);
        assert_eq!(ids(&q), vec![1, 2, 3]);
    }

    #[test]
    fn dpa_urgent_iwf_before_urgent_iwn() {
        // now=0: IW-F slack 1.0 (≤ tau_p=2), IW-N slack 60 (> tau_p ⇒ class 5).
        // Craft an urgent IW-N: arrival -59 ⇒ slack 1.
        let mut q = vec![req(1, -59.0, Tier::IwN), req(2, 0.0, Tier::IwF)];
        SchedPolicy::Dpa { tau_n: 30.0, tau_p: 2.0 }.order(&mut q, 0.0);
        assert_eq!(ids(&q), vec![2, 1]);
    }

    #[test]
    fn dpa_niw_rides_behind_iw() {
        let mut q = vec![req(1, 0.0, Tier::Niw), req(2, 5.0, Tier::IwN)];
        SchedPolicy::Dpa { tau_n: 30.0, tau_p: 2.0 }.order(&mut q, 6.0);
        assert_eq!(ids(&q), vec![2, 1]);
    }

    #[test]
    fn ordering_is_stable_for_equal_keys() {
        let mut q = vec![req(1, 1.0, Tier::IwF), req(2, 1.0, Tier::IwF)];
        SchedPolicy::Pf.order(&mut q, 2.0);
        assert_eq!(ids(&q), vec![1, 2]);
    }
}
