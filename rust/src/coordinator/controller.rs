//! The hourly forecast + optimization loop (§6.3), plus the telemetry
//! store it reads from.
//!
//! Every control epoch: take the trailing 15-minute input-TPS history per
//! (model, region), forecast the next hour with the [`Forecaster`]
//! (PJRT-compiled seasonal-AR in production), add the β NIW-headroom
//! buffer (10% of last hour's NIW load), and solve the §5 capacity ILP
//! per model.  The resulting δ plans feed the Scaling Logic (§6.4).

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

use std::collections::BTreeMap;

use crate::config::{GpuKind, ModelKind, Region, ScalingParams, Time};
use crate::forecast::Forecaster;
use crate::opt::capacity::{optimize_capacity, CapacityInputs};
use crate::perf::PerfTable;

/// 15-minute-bucketed input-TPS telemetry per (model, region), split into
/// IW (the forecast target) and NIW (the buffer input).
pub struct Telemetry {
    pub bucket_secs: Time,
    keys: Vec<(ModelKind, Region)>,
    iw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    niw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    /// History buckets prepended before t=0 (forecaster warm-up).
    pub warmup_len: usize,
}

impl Telemetry {
    pub fn new(models: &[ModelKind], bucket_secs: Time) -> Self {
        let mut keys = Vec::new();
        for &m in models {
            for r in Region::ALL {
                keys.push((m, r));
            }
        }
        let zero: BTreeMap<_, _> = keys.iter().map(|&k| (k, Vec::new())).collect();
        Telemetry {
            bucket_secs,
            keys,
            iw_tokens: zero.clone(),
            niw_tokens: zero,
            warmup_len: 0,
        }
    }

    /// Seed pre-trace history (expected TPS per bucket, newest last).
    /// `warmup[k][b]` is TPS for key `k` at bucket `b` (oldest first).
    pub fn warmup(&mut self, iw_tps: &BTreeMap<(ModelKind, Region), Vec<f64>>) {
        let mut len = 0;
        for (k, series) in iw_tps {
            let tokens: Vec<f64> = series.iter().map(|tps| tps * self.bucket_secs).collect();
            len = tokens.len();
            self.iw_tokens.insert(*k, tokens.clone());
            self.niw_tokens.insert(*k, vec![0.0; tokens.len()]);
        }
        self.warmup_len = len;
    }

    fn bucket_index(&self, now: Time) -> usize {
        self.warmup_len + (now / self.bucket_secs) as usize
    }

    /// Record one request's input tokens at its arrival time.
    pub fn record(&mut self, now: Time, model: ModelKind, region: Region, input_tokens: u32, interactive: bool) {
        let idx = self.bucket_index(now);
        let map = if interactive { &mut self.iw_tokens } else { &mut self.niw_tokens };
        let v = map.entry((model, region)).or_default();
        if v.len() <= idx {
            v.resize(idx + 1, 0.0);
        }
        v[idx] += input_tokens as f64;
    }

    /// IW input-TPS history for one key, up to (excluding) bucket at `now`.
    pub fn history_tps(&self, key: (ModelKind, Region), now: Time) -> Vec<f64> {
        let end = self.bucket_index(now);
        let v = self.iw_tokens.get(&key).map(|v| v.as_slice()).unwrap_or(&[]);
        (0..end)
            .map(|i| v.get(i).copied().unwrap_or(0.0) / self.bucket_secs)
            .collect()
    }

    /// Observed IW input TPS over the most recent complete bucket.
    pub fn recent_tps(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let idx = self.bucket_index(now);
        let v = match self.iw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        // Use the previous full bucket; fall back to the live one.
        let i = idx.saturating_sub(1);
        v.get(i).copied().unwrap_or(0.0) / self.bucket_secs
    }

    /// Observed TPS for all keys (LT-UA's gap check).
    pub fn recent_tps_all(&self, now: Time) -> BTreeMap<(ModelKind, Region), f64> {
        self.keys.iter().map(|&k| (k, self.recent_tps(k, now))).collect()
    }

    /// NIW input tokens over the trailing hour (β buffer input).
    pub fn niw_tokens_last_hour(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let end = self.bucket_index(now);
        let per_hour = (3600.0 / self.bucket_secs) as usize;
        let start = end.saturating_sub(per_hour);
        let v = match self.niw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        (start..end).map(|i| v.get(i).copied().unwrap_or(0.0)).sum()
    }

    pub fn keys(&self) -> &[(ModelKind, Region)] {
        &self.keys
    }
}

/// One epoch's scaling plan entry: per-SKU instance-count deltas for one
/// (model, region), aligned with the GPU axis `run_epoch` was given.
#[derive(Debug, Clone)]
pub struct EpochPlanEntry {
    pub model: ModelKind,
    pub region: Region,
    /// δ_{j,k} per GPU SKU, fleet order.
    pub deltas: Vec<i64>,
    /// Forecast peak input TPS for the hour (LT-UA gap checks).
    pub forecast_tps: f64,
}

impl EpochPlanEntry {
    /// Net instance-count delta across SKUs.
    pub fn delta_total(&self) -> i64 {
        self.deltas.iter().sum()
    }
}

pub type EpochPlan = Vec<EpochPlanEntry>;

/// Run one forecast + ILP epoch (§6.3) over the full `[region][gpu]`
/// capacity formulation of §5.
///
/// `gpus` is the fleet's SKU axis; `current_counts` are the allocated
/// instance counts as a dense array — one row per `telemetry.keys()`
/// entry, indexed by [`GpuKind::index`] (the engine fills a reused
/// buffer straight off the `EndpointMap` aggregates; no per-epoch map
/// allocation).  θ_{i,k} (per-instance input TPS) comes from the perf
/// table, α_k/σ_k from the SKU price sheet.  Returns the per-SKU δ plan.
pub fn run_epoch(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    now: Time,
) -> EpochPlan {
    let keys = telemetry.keys().to_vec();
    assert_eq!(
        current_counts.len(),
        keys.len(),
        "current_counts rows must align with telemetry keys"
    );
    let history: Vec<Vec<f64>> = keys.iter().map(|&k| telemetry.history_tps(k, now)).collect();
    let forecasts = forecaster.forecast(&history);
    let g = gpus.len();

    // Group per model (the ILP decouples across models).
    let mut plan = EpochPlan::new();
    let models: Vec<ModelKind> = {
        let mut ms: Vec<ModelKind> = keys.iter().map(|&(m, _)| m).collect();
        ms.dedup();
        ms.sort();
        ms.dedup();
        ms
    };
    for model in models {
        let mut current = Vec::new();
        let mut forecast_tps = Vec::new();
        // (telemetry-key row, region) pairs for this model.
        let mut region_order: Vec<(usize, Region)> = Vec::new();
        for (i, &(m, r)) in keys.iter().enumerate() {
            if m != model {
                continue;
            }
            region_order.push((i, r));
            current.push(
                gpus.iter().map(|&k| current_counts[i][k.index()] as f64).collect::<Vec<f64>>(),
            );
            // β buffer: 10% of last hour's NIW load as TPS headroom (§6.3).
            let beta = params.niw_buffer_frac * telemetry.niw_tokens_last_hour((m, r), now) / 3600.0;
            forecast_tps.push(forecasts[i].iter().map(|&f| f + beta).collect::<Vec<f64>>());
        }
        let inputs = CapacityInputs {
            current,
            tps_per_instance: gpus.iter().map(|&k| perf.profile(model, k).input_tps_capacity()).collect(),
            forecast_tps: forecast_tps.clone(),
            vm_cost: gpus.iter().map(|&k| k.dollars_per_hour()).collect(),
            start_cost: gpus
                .iter()
                .map(|&k| k.dollars_per_hour() * (params.local_redeploy_secs / 3600.0))
                .collect(),
            epsilon: params.epsilon,
            // The ILP's lower bound applies per x_{j,k}; for a
            // heterogeneous fleet that would force min_instances of
            // *every* SKU in every region, so multi-SKU epochs bound at
            // zero and rely on the executing layer's per-endpoint floor.
            min_instances: if g == 1 { params.min_instances as f64 } else { 0.0 },
            max_instances: params.max_instances as f64,
        };
        match optimize_capacity(&inputs) {
            Some(cap_plan) => {
                for (j, &(_, r)) in region_order.iter().enumerate() {
                    let peak = forecast_tps[j].iter().copied().fold(0.0, f64::max);
                    plan.push(EpochPlanEntry {
                        model,
                        region: r,
                        deltas: cap_plan.deltas[j].clone(),
                        forecast_tps: peak,
                    });
                }
            }
            None => {
                // Demand beyond max capacity: clamp every region to max,
                // growing on the cheapest SKU (the executing layer caps
                // the endpoint total anyway).
                let cheapest = (0..g)
                    .min_by(|&a, &b| {
                        gpus[a]
                            .dollars_per_hour()
                            .partial_cmp(&gpus[b].dollars_per_hour())
                            .unwrap()
                    })
                    .unwrap_or(0);
                for (j, &(ki, r)) in region_order.iter().enumerate() {
                    let cur: i64 =
                        gpus.iter().map(|&k| current_counts[ki][k.index()] as i64).sum();
                    let peak = forecast_tps[j].iter().copied().fold(0.0, f64::max);
                    let mut deltas = vec![0i64; g];
                    deltas[cheapest] = params.max_instances as i64 - cur;
                    plan.push(EpochPlanEntry { model, region: r, deltas, forecast_tps: peak });
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::forecast::SeasonalNaive;

    #[test]
    fn telemetry_buckets_and_tps() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(10.0, key.0, key.1, 900, true);
        t.record(20.0, key.0, key.1, 900, true);
        t.record(901.0, key.0, key.1, 1800, true);
        let hist = t.history_tps(key, 1800.0);
        assert_eq!(hist.len(), 2);
        assert!((hist[0] - 2.0).abs() < 1e-9);
        assert!((hist[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn warmup_prepends_history() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        warm.insert(key, vec![5.0; 96]);
        t.warmup(&warm);
        t.record(100.0, key.0, key.1, 4500, true);
        let hist = t.history_tps(key, 900.0);
        // 96 warm-up buckets plus the just-completed live bucket.
        assert_eq!(hist.len(), 97);
        assert!((hist[0] - 5.0).abs() < 1e-9);
        assert!((hist[96] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn niw_last_hour_window() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(100.0, key.0, key.1, 1000, false);   // bucket 0
        t.record(4000.0, key.0, key.1, 2000, false);  // bucket 4
        // At t=7200 (bucket 8), the last-hour window is buckets 4..8.
        assert!((t.niw_tokens_last_hour(key, 7200.0) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn epoch_plan_scales_for_forecast_load() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        // Steady 20k-TPS IW demand in East over 2 days of history
        // (θ for Llama2-70B on H100 derives to ≈3.1k input TPS).
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        // One dense row per telemetry key (3 regions), GpuKind::index order.
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let plan = run_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &counts, 0.0,
        );
        assert_eq!(plan.len(), 3);
        // θ ≈ 3.1k ⇒ East local floor ceil(0.6·20000/θ) = 4 (delta ≥ 2
        // over the current 2), global cover ≈ 7 instances.
        let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
        assert!(east.delta_total() >= 2, "east delta {}", east.delta_total());
        let total: i64 = plan.iter().map(|p| p.delta_total() + 2).sum();
        assert!(total >= 7, "total {total}");
        let _ = key;
    }

    #[test]
    fn epoch_plan_scales_in_when_idle() {
        let models = [ModelKind::Llama32_3B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            warm.insert((ModelKind::Llama32_3B, r), vec![10.0; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let counts = vec![[20usize, 0, 0]; Region::ALL.len()];
        let plan = run_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &counts, 0.0,
        );
        for entry in &plan {
            assert_eq!(entry.delta_total(), -18, "idle endpoints drop to min_instances");
        }
    }

    /// The controller-layer mirror of `capacity.rs::prefers_cheaper_gpu`:
    /// with a 2-SKU fleet, a demand surge lands on the SKU with the
    /// better $-per-θ ratio (A100: α is 1.814× cheaper, θ exactly 1.8×
    /// slower), and the expensive incumbents are released.
    #[test]
    fn epoch_prefers_cheaper_sku() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let gpus = [GpuKind::H100x8, GpuKind::A100x8];
        let perf = PerfTable::for_fleet(&gpus, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        // Incumbents are all H100 (row index 0 in GpuKind::index order).
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let plan = run_epoch(&telemetry, &mut forecaster, &perf, &gpus, &params, &counts, 0.0);
        assert_eq!(plan.len(), 3);
        let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
        assert_eq!(east.deltas.len(), 2);
        // Growth goes to the cheaper-per-throughput A100 column; the
        // H100 incumbents are not grown.
        assert!(east.deltas[1] >= 4, "A100 delta {}", east.deltas[1]);
        assert!(east.deltas[0] <= 0, "H100 delta {}", east.deltas[0]);
    }
}
