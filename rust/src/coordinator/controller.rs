//! The hourly forecast + optimization loop (§6.3), plus the telemetry
//! store it reads from.
//!
//! Every control epoch: take the trailing 15-minute input-TPS history per
//! (model, region), forecast the next hour with the [`Forecaster`]
//! (PJRT-compiled seasonal-AR in production), add the β NIW-headroom
//! buffer (10% of last hour's NIW load), and solve the §5 capacity ILP
//! per model.  The resulting δ plans feed the Scaling Logic (§6.4).

use std::collections::BTreeMap;

use crate::config::{ModelKind, Region, ScalingParams, Time};
use crate::forecast::Forecaster;
use crate::opt::capacity::{optimize_capacity, CapacityInputs};
use crate::perf::PerfTable;

/// 15-minute-bucketed input-TPS telemetry per (model, region), split into
/// IW (the forecast target) and NIW (the buffer input).
pub struct Telemetry {
    pub bucket_secs: Time,
    keys: Vec<(ModelKind, Region)>,
    iw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    niw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    /// History buckets prepended before t=0 (forecaster warm-up).
    pub warmup_len: usize,
}

impl Telemetry {
    pub fn new(models: &[ModelKind], bucket_secs: Time) -> Self {
        let mut keys = Vec::new();
        for &m in models {
            for r in Region::ALL {
                keys.push((m, r));
            }
        }
        let zero: BTreeMap<_, _> = keys.iter().map(|&k| (k, Vec::new())).collect();
        Telemetry {
            bucket_secs,
            keys,
            iw_tokens: zero.clone(),
            niw_tokens: zero,
            warmup_len: 0,
        }
    }

    /// Seed pre-trace history (expected TPS per bucket, newest last).
    /// `warmup[k][b]` is TPS for key `k` at bucket `b` (oldest first).
    pub fn warmup(&mut self, iw_tps: &BTreeMap<(ModelKind, Region), Vec<f64>>) {
        let mut len = 0;
        for (k, series) in iw_tps {
            let tokens: Vec<f64> = series.iter().map(|tps| tps * self.bucket_secs).collect();
            len = tokens.len();
            self.iw_tokens.insert(*k, tokens.clone());
            self.niw_tokens.insert(*k, vec![0.0; tokens.len()]);
        }
        self.warmup_len = len;
    }

    fn bucket_index(&self, now: Time) -> usize {
        self.warmup_len + (now / self.bucket_secs) as usize
    }

    /// Record one request's input tokens at its arrival time.
    pub fn record(&mut self, now: Time, model: ModelKind, region: Region, input_tokens: u32, interactive: bool) {
        let idx = self.bucket_index(now);
        let map = if interactive { &mut self.iw_tokens } else { &mut self.niw_tokens };
        let v = map.entry((model, region)).or_default();
        if v.len() <= idx {
            v.resize(idx + 1, 0.0);
        }
        v[idx] += input_tokens as f64;
    }

    /// IW input-TPS history for one key, up to (excluding) bucket at `now`.
    pub fn history_tps(&self, key: (ModelKind, Region), now: Time) -> Vec<f64> {
        let end = self.bucket_index(now);
        let v = self.iw_tokens.get(&key).map(|v| v.as_slice()).unwrap_or(&[]);
        (0..end)
            .map(|i| v.get(i).copied().unwrap_or(0.0) / self.bucket_secs)
            .collect()
    }

    /// Observed IW input TPS over the most recent complete bucket.
    pub fn recent_tps(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let idx = self.bucket_index(now);
        let v = match self.iw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        // Use the previous full bucket; fall back to the live one.
        let i = idx.saturating_sub(1);
        v.get(i).copied().unwrap_or(0.0) / self.bucket_secs
    }

    /// Observed TPS for all keys (LT-UA's gap check).
    pub fn recent_tps_all(&self, now: Time) -> BTreeMap<(ModelKind, Region), f64> {
        self.keys.iter().map(|&k| (k, self.recent_tps(k, now))).collect()
    }

    /// NIW input tokens over the trailing hour (β buffer input).
    pub fn niw_tokens_last_hour(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let end = self.bucket_index(now);
        let per_hour = (3600.0 / self.bucket_secs) as usize;
        let start = end.saturating_sub(per_hour);
        let v = match self.niw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        (start..end).map(|i| v.get(i).copied().unwrap_or(0.0)).sum()
    }

    pub fn keys(&self) -> &[(ModelKind, Region)] {
        &self.keys
    }
}

/// One epoch's scaling plan entry: (model, region, δ, forecast peak TPS).
pub type EpochPlan = Vec<(ModelKind, Region, i64, f64)>;

/// Run one forecast + ILP epoch (§6.3).
///
/// `current_counts` are the allocated instance counts per (model, region);
/// `theta` (per-instance input TPS) comes from the perf table.  Returns
/// the δ plan plus diagnostics (forecast MAPE is tracked by the caller).
pub fn run_epoch(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    params: &ScalingParams,
    current_counts: &BTreeMap<(ModelKind, Region), usize>,
    now: Time,
) -> EpochPlan {
    let keys = telemetry.keys().to_vec();
    let history: Vec<Vec<f64>> = keys.iter().map(|&k| telemetry.history_tps(k, now)).collect();
    let forecasts = forecaster.forecast(&history);

    // Group per model (the ILP decouples across models).
    let mut plan = EpochPlan::new();
    let models: Vec<ModelKind> = {
        let mut ms: Vec<ModelKind> = keys.iter().map(|&(m, _)| m).collect();
        ms.dedup();
        ms.sort();
        ms.dedup();
        ms
    };
    for model in models {
        let profile = perf.profile(model);
        let mut current = Vec::new();
        let mut forecast_tps = Vec::new();
        let mut region_order = Vec::new();
        for (i, &(m, r)) in keys.iter().enumerate() {
            if m != model {
                continue;
            }
            region_order.push(r);
            current.push(vec![current_counts.get(&(m, r)).copied().unwrap_or(0) as f64]);
            // β buffer: 10% of last hour's NIW load as TPS headroom (§6.3).
            let beta = params.niw_buffer_frac * telemetry.niw_tokens_last_hour((m, r), now) / 3600.0;
            forecast_tps.push(forecasts[i].iter().map(|&f| f + beta).collect::<Vec<f64>>());
        }
        let inputs = CapacityInputs {
            current,
            tps_per_instance: vec![profile.input_tps_capacity()],
            forecast_tps: forecast_tps.clone(),
            vm_cost: vec![perf.gpu.dollars_per_hour()],
            start_cost: vec![perf.gpu.dollars_per_hour()
                * (params.local_redeploy_secs / 3600.0)],
            epsilon: params.epsilon,
            min_instances: params.min_instances as f64,
            max_instances: params.max_instances as f64,
        };
        match optimize_capacity(&inputs) {
            Some(cap_plan) => {
                for (j, &r) in region_order.iter().enumerate() {
                    let peak = forecast_tps[j].iter().copied().fold(0.0, f64::max);
                    plan.push((model, r, cap_plan.deltas[j][0], peak));
                }
            }
            None => {
                // Demand beyond max capacity: clamp every region to max.
                for (j, &r) in region_order.iter().enumerate() {
                    let cur = current_counts.get(&(model, r)).copied().unwrap_or(0) as i64;
                    let peak = forecast_tps[j].iter().copied().fold(0.0, f64::max);
                    plan.push((model, r, params.max_instances as i64 - cur, peak));
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::forecast::SeasonalNaive;

    #[test]
    fn telemetry_buckets_and_tps() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(10.0, key.0, key.1, 900, true);
        t.record(20.0, key.0, key.1, 900, true);
        t.record(901.0, key.0, key.1, 1800, true);
        let hist = t.history_tps(key, 1800.0);
        assert_eq!(hist.len(), 2);
        assert!((hist[0] - 2.0).abs() < 1e-9);
        assert!((hist[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn warmup_prepends_history() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        warm.insert(key, vec![5.0; 96]);
        t.warmup(&warm);
        t.record(100.0, key.0, key.1, 4500, true);
        let hist = t.history_tps(key, 900.0);
        // 96 warm-up buckets plus the just-completed live bucket.
        assert_eq!(hist.len(), 97);
        assert!((hist[0] - 5.0).abs() < 1e-9);
        assert!((hist[96] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn niw_last_hour_window() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(100.0, key.0, key.1, 1000, false);   // bucket 0
        t.record(4000.0, key.0, key.1, 2000, false);  // bucket 4
        // At t=7200 (bucket 8), the last-hour window is buckets 4..8.
        assert!((t.niw_tokens_last_hour(key, 7200.0) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn epoch_plan_scales_for_forecast_load() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        // Steady 20k-TPS IW demand in East over 2 days of history
        // (θ for Llama2-70B on H100 derives to ≈3.1k input TPS).
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let mut counts = BTreeMap::new();
        for r in Region::ALL {
            counts.insert((ModelKind::Llama2_70B, r), 2usize);
        }
        let plan = run_epoch(&telemetry, &mut forecaster, &perf, &params, &counts, 0.0);
        assert_eq!(plan.len(), 3);
        // θ ≈ 3.1k ⇒ East local floor ceil(0.6·20000/θ) = 4 (delta ≥ 2
        // over the current 2), global cover ≈ 7 instances.
        let east = plan.iter().find(|p| p.1 == Region::EastUs).unwrap();
        assert!(east.2 >= 2, "east delta {}", east.2);
        let total: i64 = plan.iter().map(|p| p.2 + 2).sum();
        assert!(total >= 7, "total {total}");
        let _ = key;
    }

    #[test]
    fn epoch_plan_scales_in_when_idle() {
        let models = [ModelKind::Llama32_3B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            warm.insert((ModelKind::Llama32_3B, r), vec![10.0; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let mut counts = BTreeMap::new();
        for r in Region::ALL {
            counts.insert((ModelKind::Llama32_3B, r), 20usize);
        }
        let plan = run_epoch(&telemetry, &mut forecaster, &perf, &params, &counts, 0.0);
        for &(_, _, delta, _) in &plan {
            assert_eq!(delta, -18, "idle endpoints drop to min_instances");
        }
    }
}
