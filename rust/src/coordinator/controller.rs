//! The hourly forecast + optimization loop (§6.3), plus the telemetry
//! store it reads from.
//!
//! Every control epoch: take the trailing 15-minute input-TPS history per
//! (model, region), forecast the next hour with the [`Forecaster`]
//! (PJRT-compiled seasonal-AR in production), add the β NIW-headroom
//! buffer (10% of last hour's NIW load), and solve the §5 capacity ILP
//! per model.  The resulting δ plans feed the Scaling Logic (§6.4).
//!
//! The per-model ILPs are independent (no §5 constraint couples models),
//! so [`run_epoch`] fans them across the scoped worker pool from
//! [`crate::experiments::sweep`] — results are position-stable and
//! identical to the sequential path ([`run_epoch_sequential`], which the
//! equivalence test pins).  Each model's solve reuses its
//! [`CapacitySolver`] from [`SolverStates`]: demand drift between epochs
//! only moves the ILP's right-hand side, so epoch N's basis dual-re-solves
//! epoch N+1 in a handful of pivots instead of a cold two-phase run.

use std::collections::BTreeMap;

use crate::config::{
    DisaggParams, GpuKind, GuardrailParams, ModelKind, Region, ScalingParams, Time,
};
use crate::experiments::sweep::sweep;
use crate::forecast::Forecaster;
use crate::metrics::{GuardrailMode, GuardrailStats};
use crate::opt::capacity::{optimize_capacity_warm_faulted, CapacityInputs, CapacitySolver};
use crate::perf::PerfTable;

/// 15-minute-bucketed input-TPS telemetry per (model, region), split into
/// IW (the forecast target) and NIW (the buffer input).
pub struct Telemetry {
    /// Bucket width in seconds (900 = the paper's 15 minutes).
    pub bucket_secs: Time,
    keys: Vec<(ModelKind, Region)>,
    iw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    niw_tokens: BTreeMap<(ModelKind, Region), Vec<f64>>,
    /// History buckets prepended before t=0 (forecaster warm-up).
    pub warmup_len: usize,
}

impl Telemetry {
    /// Empty store covering `models` × every [`Region`].
    pub fn new(models: &[ModelKind], bucket_secs: Time) -> Self {
        let mut keys = Vec::new();
        for &m in models {
            for r in Region::ALL {
                keys.push((m, r));
            }
        }
        let zero: BTreeMap<_, _> = keys.iter().map(|&k| (k, Vec::new())).collect();
        Telemetry {
            bucket_secs,
            keys,
            iw_tokens: zero.clone(),
            niw_tokens: zero,
            warmup_len: 0,
        }
    }

    /// Seed pre-trace history (expected TPS per bucket, newest last).
    /// `warmup[k][b]` is TPS for key `k` at bucket `b` (oldest first).
    pub fn warmup(&mut self, iw_tps: &BTreeMap<(ModelKind, Region), Vec<f64>>) {
        let mut len = 0;
        for (k, series) in iw_tps {
            let tokens: Vec<f64> = series.iter().map(|tps| tps * self.bucket_secs).collect();
            len = tokens.len();
            self.iw_tokens.insert(*k, tokens.clone());
            self.niw_tokens.insert(*k, vec![0.0; tokens.len()]);
        }
        self.warmup_len = len;
    }

    fn bucket_index(&self, now: Time) -> usize {
        self.warmup_len + (now / self.bucket_secs) as usize
    }

    /// Record one request's input tokens at its arrival time.
    pub fn record(&mut self, now: Time, model: ModelKind, region: Region, input_tokens: u32, interactive: bool) {
        let idx = self.bucket_index(now);
        let map = if interactive { &mut self.iw_tokens } else { &mut self.niw_tokens };
        let v = map.entry((model, region)).or_default();
        if v.len() <= idx {
            v.resize(idx + 1, 0.0);
        }
        v[idx] += input_tokens as f64;
    }

    /// IW input-TPS history for one key, up to (excluding) bucket at `now`.
    pub fn history_tps(&self, key: (ModelKind, Region), now: Time) -> Vec<f64> {
        let end = self.bucket_index(now);
        let v = self.iw_tokens.get(&key).map(|v| v.as_slice()).unwrap_or(&[]);
        (0..end)
            .map(|i| v.get(i).copied().unwrap_or(0.0) / self.bucket_secs)
            .collect()
    }

    /// Observed IW input TPS over the most recent complete bucket.
    pub fn recent_tps(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let idx = self.bucket_index(now);
        let v = match self.iw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        // Use the previous full bucket; fall back to the live one.
        let i = idx.saturating_sub(1);
        v.get(i).copied().unwrap_or(0.0) / self.bucket_secs
    }

    /// Observed TPS for all keys (LT-UA's gap check).
    pub fn recent_tps_all(&self, now: Time) -> BTreeMap<(ModelKind, Region), f64> {
        self.keys.iter().map(|&k| (k, self.recent_tps(k, now))).collect()
    }

    /// NIW input tokens over the trailing hour (β buffer input).
    pub fn niw_tokens_last_hour(&self, key: (ModelKind, Region), now: Time) -> f64 {
        let end = self.bucket_index(now);
        let per_hour = (3600.0 / self.bucket_secs) as usize;
        let start = end.saturating_sub(per_hour);
        let v = match self.niw_tokens.get(&key) {
            Some(v) => v,
            None => return 0.0,
        };
        (start..end).map(|i| v.get(i).copied().unwrap_or(0.0)).sum()
    }

    /// The (model, region) keys this store tracks, in row order.
    pub fn keys(&self) -> &[(ModelKind, Region)] {
        &self.keys
    }
}

/// One epoch's scaling plan entry: per-SKU instance-count deltas for one
/// (model, region), aligned with the GPU axis `run_epoch` was given.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochPlanEntry {
    /// The model this entry scales.
    pub model: ModelKind,
    /// The region this entry scales.
    pub region: Region,
    /// δ_{j,k} per GPU SKU, fleet order.
    pub deltas: Vec<i64>,
    /// Forecast peak input TPS for the hour (LT-UA gap checks).
    pub forecast_tps: f64,
}

impl EpochPlanEntry {
    /// Net instance-count delta across SKUs.
    pub fn delta_total(&self) -> i64 {
        self.deltas.iter().sum()
    }
}

/// One control epoch's full scaling plan (every (model, region) pair).
pub type EpochPlan = Vec<EpochPlanEntry>;

/// Per-model warm-start state carried across control epochs (and across
/// [`crate::sim::chunked`] chunk boundaries via the engine handoff): each
/// model keeps its factorized tableau, last basis and last plan, so the
/// next epoch's ILP re-solves warm.  Dropping the state is always safe —
/// the next epoch just solves cold.
#[derive(Debug, Clone, Default)]
pub struct SolverStates {
    by_model: BTreeMap<ModelKind, CapacitySolver>,
}

impl SolverStates {
    /// Empty state: every model's first solve runs cold.
    pub fn new() -> SolverStates {
        SolverStates::default()
    }

    /// The warm-start state for `model`, created on first use.
    pub fn for_model(&mut self, model: ModelKind) -> &mut CapacitySolver {
        self.by_model.entry(model).or_default()
    }
}

/// Per-epoch control-input modifiers — the watchdog's stamp of what the
/// control-plane fault plane is doing to this epoch's inputs, computed
/// by the engine from [`crate::sim::faults::ControlFaultPlan`] and
/// consumed by [`run_epoch_modded`] / [`guardrail_epoch`].
///
/// The clean value changes **no** code path: every modifier is applied
/// behind a branch (or, for the θ deflation, as an exact `x / 1.0`
/// division), so `run_epoch` with clean mods is bit-identical to the
/// pre-guardrail controller.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlEpochMods {
    /// The forecaster's output is suppressed (consumed as zero demand).
    pub forecast_blackout: bool,
    /// `(scale, bias)` distortion applied to every forecast value.
    pub forecast_corruption: Option<(f64, f64)>,
    /// When the telemetry feed is frozen: the last good telemetry time.
    /// All telemetry reads (IW history, NIW buffer) are taken as of this
    /// instant instead of `now` — the controller sees the world as it
    /// was when the feed died.
    pub telemetry_now: Option<Time>,
    /// Every capacity solve this epoch reports the
    /// infeasible/iteration-cap outcome.
    pub solver_fault: bool,
    /// θ safety margin from the residual tracker: every per-instance
    /// capacity is divided by `1 + theta_deflate`, so the ILP plans as
    /// if instances were that much slower — commanding proportionally
    /// more of them.  0 (the clean value) divides by exactly 1.0.
    pub theta_deflate: f64,
}

impl ControlEpochMods {
    /// The no-fault, no-margin value — the naive controller's view.
    pub fn clean() -> ControlEpochMods {
        ControlEpochMods {
            forecast_blackout: false,
            forecast_corruption: None,
            telemetry_now: None,
            solver_fault: false,
            theta_deflate: 0.0,
        }
    }

    /// True when every modifier is at its identity value.
    pub fn is_clean(&self) -> bool {
        *self == ControlEpochMods::clean()
    }
}

impl Default for ControlEpochMods {
    fn default() -> Self {
        ControlEpochMods::clean()
    }
}

/// The guardrail controller's carried state: trailing forecast
/// residuals, the forecasts awaiting verification, the last-good plan
/// and the cascade rung — carried across control epochs (and across
/// chunk boundaries via the engine handoff, which is what keeps chunked
/// guarded runs bit-identical to sequential ones).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GuardrailState {
    /// Trailing relative forecast residuals per (model, region), oldest
    /// first, capped at [`GuardrailParams::residual_window`].
    residuals: BTreeMap<(ModelKind, Region), Vec<f64>>,
    /// Forecast peaks issued by the previous Fresh epoch, awaiting
    /// comparison against observed demand.
    pending: BTreeMap<(ModelKind, Region), f64>,
    /// The last-good plan as absolute targets:
    /// (model, region) → (total instance target, forecast peak TPS).
    last_good: BTreeMap<(ModelKind, Region), (i64, f64)>,
    /// Current cascade rung.  Starts (and, healthy, stays) at `Fresh`.
    pub mode: GuardrailMode,
    /// Consecutive epochs spent on the `Held` rung.
    held_epochs: u32,
}

impl GuardrailState {
    /// Fresh state: no residual history, no last-good plan.
    pub fn new() -> GuardrailState {
        GuardrailState::default()
    }

    /// Root-mean-square of the trailing relative residuals, pooled over
    /// all keys — the error-variance estimate behind the θ margin.  The
    /// second moment (not the centered variance) is deliberate: a
    /// consistently-biased forecast is exactly as dangerous as a noisy
    /// one, and RMS charges for both.
    pub fn residual_rms(&self) -> f64 {
        let mut n = 0usize;
        let mut sumsq = 0.0;
        for w in self.residuals.values() {
            for &x in w {
                n += 1;
                sumsq += x * x;
            }
        }
        if n == 0 {
            0.0
        } else {
            (sumsq / n as f64).sqrt()
        }
    }

    /// The θ margin the residual tracker currently commands.
    pub fn margin(&self, guard: &GuardrailParams) -> f64 {
        (guard.inflation_gain * self.residual_rms()).clamp(0.0, guard.max_inflation)
    }
}

/// One guarded control epoch: watchdog → residual tracker → fallback
/// cascade.
///
/// The watchdog stamps the epoch's inputs with their age (via
/// `mods.telemetry_now`) and declares the epoch *healthy* iff the
/// forecaster is answering, the solver is answering, and telemetry is
/// no older than [`GuardrailParams::max_telemetry_age`].  Healthy
/// epochs run the real ILP with the residual tracker's θ margin folded
/// in and refresh the last-good plan.  Unhealthy epochs fall back:
/// first to the last-good plan held with
/// [`GuardrailParams::held_inflation`] safety inflation (for at most
/// [`GuardrailParams::max_held_epochs`] epochs), then to reactive
/// proportional control — an **empty** plan; the engine's per-tick
/// reactive backstop (`Autoscaler::guardrail_reactive_tick`) takes
/// over until the control plane heals.
///
/// Every rung change is recorded as a first-class
/// [`crate::metrics::GuardrailEvent`], and every epoch accrues rung
/// counts + degraded time in `stats`.
#[allow(clippy::too_many_arguments)]
pub fn guardrail_epoch(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    guard: &GuardrailParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
    mods: &ControlEpochMods,
    state: &mut GuardrailState,
    stats: &mut GuardrailStats,
) -> EpochPlan {
    let t_eff = mods.telemetry_now.unwrap_or(now);
    let telemetry_fresh = now - t_eff <= guard.max_telemetry_age;

    // Residual tracker: score the previous Fresh epoch's forecasts
    // against what actually arrived — only from a live feed (frozen
    // telemetry would teach the tracker that the forecast was perfect).
    if mods.telemetry_now.is_none() {
        for (&key, &fc) in &state.pending {
            let observed = telemetry.recent_tps(key, now);
            let resid = (observed - fc).abs() / fc.max(1.0);
            let w = state.residuals.entry(key).or_default();
            w.push(resid);
            if w.len() > guard.residual_window {
                w.remove(0);
            }
        }
        state.pending.clear();
    }
    let margin = state.margin(guard);

    let healthy = !mods.forecast_blackout && !mods.solver_fault && telemetry_fresh;
    let prev_mode = state.mode;
    let plan = if healthy {
        let guarded = ControlEpochMods { theta_deflate: margin, ..mods.clone() };
        let plan = run_epoch_impl(
            telemetry, forecaster, perf, gpus, params, current_counts, solvers, now, &guarded,
            true,
        );
        state.mode = GuardrailMode::Fresh;
        state.held_epochs = 0;
        state.pending =
            plan.iter().map(|e| ((e.model, e.region), e.forecast_tps)).collect();
        // Plan entries are model-sorted, which may differ from telemetry
        // key order — look each entry's counts row up by key.
        let keys = telemetry.keys();
        let mut base_total = 0i64;
        state.last_good = plan
            .iter()
            .map(|e| {
                let row = keys
                    .iter()
                    .position(|&k| k == (e.model, e.region))
                    .expect("plan entry key missing from telemetry");
                let cur: i64 =
                    gpus.iter().map(|&k| current_counts[row][k.index()] as i64).sum();
                let target = (cur + e.delta_total()).max(0);
                base_total += target;
                ((e.model, e.region), (target, e.forecast_tps))
            })
            .collect();
        // Capacity-margin ledger: instance-hours of extra capacity the
        // θ deflation commanded this epoch (the deflated fleet target
        // includes a `margin/(1+margin)` share of pure safety margin).
        if margin > 0.0 {
            stats.margin_instance_hours +=
                base_total as f64 * (margin / (1.0 + margin)) * (params.control_interval / 3600.0);
        }
        plan
    } else if !state.last_good.is_empty() && state.held_epochs < guard.max_held_epochs {
        state.mode = GuardrailMode::Held;
        state.held_epochs += 1;
        let plan = held_plan(state, gpus, params, guard, telemetry.keys(), current_counts);
        let base_total: i64 = state.last_good.values().map(|&(t, _)| t).sum();
        stats.margin_instance_hours += base_total as f64
            * (guard.held_inflation - 1.0)
            * (params.control_interval / 3600.0);
        plan
    } else {
        state.mode = GuardrailMode::Reactive;
        EpochPlan::new()
    };

    if state.mode != prev_mode {
        let cause = match (prev_mode, state.mode) {
            (_, GuardrailMode::Fresh) => "recovered",
            (GuardrailMode::Held, GuardrailMode::Reactive) if !state.last_good.is_empty() => {
                "held-expired"
            }
            _ if mods.forecast_blackout => "forecast-blackout",
            _ if !telemetry_fresh => "stale-telemetry",
            _ if mods.solver_fault => "solver-failure",
            _ => "degraded",
        };
        stats.record_transition(now, prev_mode, state.mode, cause);
    }
    stats.record_epoch(state.mode, params.control_interval);
    plan
}

/// The middle cascade rung: re-issue the last-good absolute targets,
/// inflated by the safety factor and clamped to the instance bounds,
/// as deltas on the cheapest SKU (mirroring the infeasible-clamp idiom
/// of `solve_epoch`).
fn held_plan(
    state: &GuardrailState,
    gpus: &[GpuKind],
    params: &ScalingParams,
    guard: &GuardrailParams,
    keys: &[(ModelKind, Region)],
    current_counts: &[[usize; GpuKind::COUNT]],
) -> EpochPlan {
    let cheapest = (0..gpus.len())
        .min_by(|&a, &b| {
            gpus[a].dollars_per_hour().partial_cmp(&gpus[b].dollars_per_hour()).unwrap()
        })
        .unwrap_or(0);
    let mut plan = EpochPlan::new();
    for (i, &(m, r)) in keys.iter().enumerate() {
        let Some(&(target, forecast_tps)) = state.last_good.get(&(m, r)) else {
            continue;
        };
        let inflated = ((target as f64 * guard.held_inflation).ceil() as i64)
            .clamp(params.min_instances as i64, params.max_instances as i64);
        let cur: i64 = gpus.iter().map(|&k| current_counts[i][k.index()] as i64).sum();
        let mut deltas = vec![0i64; gpus.len()];
        deltas[cheapest] = inflated - cur;
        plan.push(EpochPlanEntry { model: m, region: r, deltas, forecast_tps });
    }
    plan
}

/// One model's ready-to-solve problem plus the metadata needed to turn
/// its [`crate::opt::CapacityPlan`] (or fallback) into plan entries.
struct ModelJob {
    model: ModelKind,
    inputs: CapacityInputs,
    /// (telemetry-key row, region) pairs for this model, ILP row order.
    region_order: Vec<(usize, Region)>,
    /// Per-region forecast peak TPS (β buffer included).
    peaks: Vec<f64>,
}

/// Run one forecast + ILP epoch (§6.3) over the full `[region][gpu]`
/// capacity formulation of §5.
///
/// `gpus` is the fleet's SKU axis; `current_counts` are the allocated
/// instance counts as a dense array — one row per `telemetry.keys()`
/// entry, indexed by [`GpuKind::index`] (the engine fills a reused
/// buffer straight off the `EndpointMap` aggregates; no per-epoch map
/// allocation).  θ_{i,k} (per-instance input TPS) comes from the perf
/// table, α_k/σ_k from the SKU price sheet.  Returns the per-SKU δ plan.
///
/// `solvers` carries each model's warm-start state from the previous
/// epoch; the per-model ILPs run concurrently on the sweep pool
/// (set `SAGESERVE_SEQUENTIAL=1` to pin them to one thread).
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
) -> EpochPlan {
    run_epoch_impl(
        telemetry,
        forecaster,
        perf,
        gpus,
        params,
        current_counts,
        solvers,
        now,
        &ControlEpochMods::clean(),
        true,
    )
}

/// [`run_epoch`] under the control-plane fault plane: `mods` carries the
/// epoch's input distortions (blackout, corruption, frozen telemetry,
/// forced solver failure).  With [`ControlEpochMods::clean`] this is
/// exactly [`run_epoch`] — the naive controller's path when a
/// control-fault schedule is active but no window is open.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch_modded(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
    mods: &ControlEpochMods,
) -> EpochPlan {
    run_epoch_impl(telemetry, forecaster, perf, gpus, params, current_counts, solvers, now, mods, true)
}

/// [`run_epoch`] with the per-model solves forced onto the caller's
/// thread, in model order.  The parallel path is asserted identical to
/// this one (solves share no state, so the fan-out cannot change the
/// answer); it exists as the reference for that test and for callers
/// that must not spawn.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch_sequential(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
) -> EpochPlan {
    run_epoch_impl(
        telemetry,
        forecaster,
        perf,
        gpus,
        params,
        current_counts,
        solvers,
        now,
        &ControlEpochMods::clean(),
        false,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_epoch_impl(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
    mods: &ControlEpochMods,
    parallel: bool,
) -> EpochPlan {
    let keys = telemetry.keys().to_vec();
    // Frozen telemetry: every read is taken as of the last good instant.
    let t_eff = mods.telemetry_now.unwrap_or(now);
    let history: Vec<Vec<f64>> = keys.iter().map(|&k| telemetry.history_tps(k, t_eff)).collect();
    // The forecaster is always *called* (it may be stateful and must
    // advance identically for every controller flavor); a blackout
    // suppresses its output on the way to the ILP.
    let mut forecasts = forecaster.forecast(&history);
    if mods.forecast_blackout {
        for row in &mut forecasts {
            for v in row.iter_mut() {
                *v = 0.0;
            }
        }
    } else if let Some((scale, bias)) = mods.forecast_corruption {
        for row in &mut forecasts {
            for v in row.iter_mut() {
                *v = (*v * scale + bias).max(0.0);
            }
        }
    }
    // θ deflation (residual-tracker margin): dividing by exactly 1.0
    // when the margin is zero is a bit-exact identity.
    let deflate = 1.0 + mods.theta_deflate;
    let theta =
        |m: ModelKind, k: GpuKind| perf.profile(m, k).input_tps_capacity() / deflate;
    // The ILP's lower bound applies per x_{j,k}; for a heterogeneous
    // fleet that would force min_instances of *every* SKU in every
    // region, so multi-SKU epochs bound at zero and rely on the
    // executing layer's per-endpoint floor.
    let min_instances = if gpus.len() == 1 { params.min_instances as f64 } else { 0.0 };
    solve_epoch(
        telemetry,
        &keys,
        &forecasts,
        &theta,
        gpus,
        params,
        current_counts,
        solvers,
        t_eff,
        min_instances,
        params.max_instances as f64,
        mods.solver_fault,
        parallel,
    )
}

/// Run the per-phase §5 solves for a disaggregated fleet: one capacity
/// ILP sized by the TTFT-gated prefill throughput
/// ([`crate::perf::PerfProfile::prefill_input_tps_capacity`]) over the
/// prefill sub-fleet, and one sized by the ITL-gated decode throughput
/// ([`crate::perf::PerfProfile::decode_input_tps_capacity`]) over the
/// decode sub-fleet.  Both phases see the *same* forecast demand rows —
/// every request is prefilled once and decoded once, so input-equivalent
/// TPS is the common currency — and they share one GPU budget: prefill
/// may claim at most `round(prefill_fraction · max_instances)` slots per
/// endpoint, decode the remainder, each phase keeping at least one.
///
/// The forecast runs **once** (the [`Forecaster`] may be stateful); the
/// two solves reuse it.  Each phase carries its own [`SolverStates`] so
/// warm bases never cross phases (the θ columns differ, which would
/// invalidate the factorization anyway).
///
/// Returns the merged per-SKU δ plan (prefill + decode deltas summed per
/// (model, region, SKU) — the executing layer scales endpoints and the
/// roster assigns phases) plus the **refined prefill fraction**: the
/// share of the combined post-plan target that the prefill solve claimed,
/// clamped to `[0.1, 0.9]`.  Callers feed it back into
/// [`crate::sim::cluster::Cluster::set_disagg`]-managed state so future
/// roster phase assignments track what the ILPs actually sized.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch_disagg(
    telemetry: &Telemetry,
    forecaster: &mut dyn Forecaster,
    perf: &PerfTable,
    gpus: &[GpuKind],
    params: &ScalingParams,
    disagg: &DisaggParams,
    prefill_counts: &[[usize; GpuKind::COUNT]],
    decode_counts: &[[usize; GpuKind::COUNT]],
    solvers_prefill: &mut SolverStates,
    solvers_decode: &mut SolverStates,
    now: Time,
) -> (EpochPlan, f64) {
    let keys = telemetry.keys().to_vec();
    let history: Vec<Vec<f64>> = keys.iter().map(|&k| telemetry.history_tps(k, now)).collect();
    let forecasts = forecaster.forecast(&history);
    let max = params.max_instances as f64;
    let max_prefill = (max * disagg.prefill_fraction).round().max(1.0).min((max - 1.0).max(1.0));
    let max_decode = (max - max_prefill).max(1.0);
    let min_instances = if gpus.len() == 1 { 1.0 } else { 0.0 };
    let theta_p =
        |m: ModelKind, k: GpuKind| perf.profile(m, k).prefill_input_tps_capacity(disagg.ttft_target);
    let theta_d =
        |m: ModelKind, k: GpuKind| perf.profile(m, k).decode_input_tps_capacity(disagg.itl_target);
    let prefill = solve_epoch(
        telemetry, &keys, &forecasts, &theta_p, gpus, params, prefill_counts,
        solvers_prefill, now, min_instances, max_prefill, false, true,
    );
    let decode = solve_epoch(
        telemetry, &keys, &forecasts, &theta_d, gpus, params, decode_counts,
        solvers_decode, now, min_instances, max_decode, false, true,
    );

    // Merge positionally: both solves group the same telemetry keys by
    // the same sorted model order, so entries align 1:1.
    debug_assert_eq!(prefill.len(), decode.len());
    let mut plan = EpochPlan::with_capacity(prefill.len());
    for (p, d) in prefill.iter().zip(&decode) {
        debug_assert_eq!((p.model, p.region), (d.model, d.region));
        plan.push(EpochPlanEntry {
            model: p.model,
            region: p.region,
            deltas: p.deltas.iter().zip(&d.deltas).map(|(&a, &b)| a + b).collect(),
            forecast_tps: p.forecast_tps,
        });
    }

    // Refined split: share of the combined post-plan target the prefill
    // solve claimed.  Degenerate (empty) targets keep the configured
    // fraction; the clamp keeps both phases alive at the roster layer.
    let cur_p: i64 = prefill_counts.iter().flatten().map(|&c| c as i64).sum();
    let cur_d: i64 = decode_counts.iter().flatten().map(|&c| c as i64).sum();
    let target_p = (cur_p + prefill.iter().map(|e| e.delta_total()).sum::<i64>()).max(0) as f64;
    let target_d = (cur_d + decode.iter().map(|e| e.delta_total()).sum::<i64>()).max(0) as f64;
    let frac = if target_p + target_d > 0.0 {
        (target_p / (target_p + target_d)).clamp(0.1, 0.9)
    } else {
        disagg.prefill_fraction
    };
    (plan, frac)
}

/// The shared solve core: forecasts already computed, θ supplied by the
/// caller (unified vs per-phase capacities), instance bounds explicit.
/// `solver_fault` forces every per-model solve into the
/// infeasible/iteration-cap outcome (the control-fault plane's
/// solver-failure injection) — the naive fallback then clamps every
/// region to `max_instances`, which is exactly the over-provisioning
/// failure mode `exp guardrails` measures.
#[allow(clippy::too_many_arguments)]
fn solve_epoch(
    telemetry: &Telemetry,
    keys: &[(ModelKind, Region)],
    forecasts: &[Vec<f64>],
    theta: &dyn Fn(ModelKind, GpuKind) -> f64,
    gpus: &[GpuKind],
    params: &ScalingParams,
    current_counts: &[[usize; GpuKind::COUNT]],
    solvers: &mut SolverStates,
    now: Time,
    min_instances: f64,
    max_instances: f64,
    solver_fault: bool,
    parallel: bool,
) -> EpochPlan {
    assert_eq!(
        current_counts.len(),
        keys.len(),
        "current_counts rows must align with telemetry keys"
    );
    let g = gpus.len();

    // Group per model (the ILP decouples across models).
    let models: Vec<ModelKind> = {
        let mut ms: Vec<ModelKind> = keys.iter().map(|&(m, _)| m).collect();
        ms.dedup();
        ms.sort();
        ms.dedup();
        ms
    };
    let jobs: Vec<ModelJob> = models
        .iter()
        .map(|&model| {
            let mut current = Vec::new();
            let mut forecast_tps: Vec<Vec<f64>> = Vec::new();
            let mut region_order: Vec<(usize, Region)> = Vec::new();
            for (i, &(m, r)) in keys.iter().enumerate() {
                if m != model {
                    continue;
                }
                region_order.push((i, r));
                current.push(
                    gpus.iter().map(|&k| current_counts[i][k.index()] as f64).collect::<Vec<f64>>(),
                );
                // β buffer: 10% of last hour's NIW load as TPS headroom (§6.3).
                let beta =
                    params.niw_buffer_frac * telemetry.niw_tokens_last_hour((m, r), now) / 3600.0;
                forecast_tps.push(forecasts[i].iter().map(|&f| f + beta).collect::<Vec<f64>>());
            }
            let peaks = forecast_tps
                .iter()
                .map(|row| row.iter().copied().fold(0.0, f64::max))
                .collect();
            let inputs = CapacityInputs {
                current,
                tps_per_instance: gpus.iter().map(|&k| theta(model, k)).collect(),
                forecast_tps,
                vm_cost: gpus.iter().map(|&k| k.dollars_per_hour()).collect(),
                start_cost: gpus
                    .iter()
                    .map(|&k| k.dollars_per_hour() * (params.local_redeploy_secs / 3600.0))
                    .collect(),
                epsilon: params.epsilon,
                min_instances,
                max_instances,
            };
            ModelJob { model, inputs, region_order, peaks }
        })
        .collect();

    // Pair each job with its model's persistent solver state.  `models`
    // is sorted + deduped and the BTreeMap iterates in key order, so the
    // filtered iteration aligns positionally with `jobs`.
    for job in &jobs {
        solvers.by_model.entry(job.model).or_default();
    }
    let solver_refs: Vec<&mut CapacitySolver> = solvers
        .by_model
        .iter_mut()
        .filter(|(m, _)| models.binary_search(m).is_ok())
        .map(|(_, s)| s)
        .collect();
    debug_assert_eq!(solver_refs.len(), jobs.len());
    let work: Vec<(&ModelJob, &mut CapacitySolver)> = jobs.iter().zip(solver_refs).collect();
    let solve = |(job, solver): (&ModelJob, &mut CapacitySolver)| {
        optimize_capacity_warm_faulted(&job.inputs, solver, solver_fault)
    };
    let results = if parallel {
        sweep(work, solve)
    } else {
        work.into_iter().map(solve).collect::<Vec<_>>()
    };

    let mut plan = EpochPlan::new();
    for (job, result) in jobs.iter().zip(results) {
        match result {
            Some(cap_plan) => {
                for (j, &(_, r)) in job.region_order.iter().enumerate() {
                    plan.push(EpochPlanEntry {
                        model: job.model,
                        region: r,
                        deltas: cap_plan.deltas[j].clone(),
                        forecast_tps: job.peaks[j],
                    });
                }
            }
            None => {
                // Demand beyond max capacity: clamp every region to max,
                // growing on the cheapest SKU (the executing layer caps
                // the endpoint total anyway).
                let cheapest = (0..g)
                    .min_by(|&a, &b| {
                        gpus[a]
                            .dollars_per_hour()
                            .partial_cmp(&gpus[b].dollars_per_hour())
                            .unwrap()
                    })
                    .unwrap_or(0);
                for (j, &(ki, r)) in job.region_order.iter().enumerate() {
                    let cur: i64 =
                        gpus.iter().map(|&k| current_counts[ki][k.index()] as i64).sum();
                    let mut deltas = vec![0i64; g];
                    deltas[cheapest] = max_instances as i64 - cur;
                    plan.push(EpochPlanEntry {
                        model: job.model,
                        region: r,
                        deltas,
                        forecast_tps: job.peaks[j],
                    });
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::forecast::SeasonalNaive;

    #[test]
    fn telemetry_buckets_and_tps() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(10.0, key.0, key.1, 900, true);
        t.record(20.0, key.0, key.1, 900, true);
        t.record(901.0, key.0, key.1, 1800, true);
        let hist = t.history_tps(key, 1800.0);
        assert_eq!(hist.len(), 2);
        assert!((hist[0] - 2.0).abs() < 1e-9);
        assert!((hist[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn warmup_prepends_history() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        warm.insert(key, vec![5.0; 96]);
        t.warmup(&warm);
        t.record(100.0, key.0, key.1, 4500, true);
        let hist = t.history_tps(key, 900.0);
        // 96 warm-up buckets plus the just-completed live bucket.
        assert_eq!(hist.len(), 97);
        assert!((hist[0] - 5.0).abs() < 1e-9);
        assert!((hist[96] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn niw_last_hour_window() {
        let mut t = Telemetry::new(&[ModelKind::Llama2_70B], 900.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        t.record(100.0, key.0, key.1, 1000, false);   // bucket 0
        t.record(4000.0, key.0, key.1, 2000, false);  // bucket 4
        // At t=7200 (bucket 8), the last-hour window is buckets 4..8.
        assert!((t.niw_tokens_last_hour(key, 7200.0) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn epoch_plan_scales_for_forecast_load() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        // Steady 20k-TPS IW demand in East over 2 days of history
        // (θ for Llama2-70B on H100 derives to ≈3.1k input TPS).
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        // One dense row per telemetry key (3 regions), GpuKind::index order.
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let mut solvers = SolverStates::new();
        let plan = run_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut solvers, 0.0,
        );
        assert_eq!(plan.len(), 3);
        // θ ≈ 3.1k ⇒ East local floor ceil(0.6·20000/θ) = 4 (delta ≥ 2
        // over the current 2), global cover ≈ 7 instances.
        let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
        assert!(east.delta_total() >= 2, "east delta {}", east.delta_total());
        let total: i64 = plan.iter().map(|p| p.delta_total() + 2).sum();
        assert!(total >= 7, "total {total}");
        let _ = key;
    }

    #[test]
    fn epoch_plan_scales_in_when_idle() {
        let models = [ModelKind::Llama32_3B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            warm.insert((ModelKind::Llama32_3B, r), vec![10.0; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let counts = vec![[20usize, 0, 0]; Region::ALL.len()];
        let plan = run_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0,
        );
        for entry in &plan {
            assert_eq!(entry.delta_total(), -18, "idle endpoints drop to min_instances");
        }
    }

    /// The controller-layer mirror of `capacity.rs::prefers_cheaper_gpu`:
    /// with a 2-SKU fleet, a demand surge lands on the SKU with the
    /// better $-per-θ ratio (A100: α is 1.814× cheaper, θ exactly 1.8×
    /// slower), and the expensive incumbents are released.
    #[test]
    fn epoch_prefers_cheaper_sku() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let gpus = [GpuKind::H100x8, GpuKind::A100x8];
        let perf = PerfTable::for_fleet(&gpus, &models);
        let params = ScalingParams::default();
        let mut forecaster = SeasonalNaive::new(96, 4);
        // Incumbents are all H100 (row index 0 in GpuKind::index order).
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let plan = run_epoch(
            &telemetry, &mut forecaster, &perf, &gpus, &params, &counts,
            &mut SolverStates::new(), 0.0,
        );
        assert_eq!(plan.len(), 3);
        let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
        assert_eq!(east.deltas.len(), 2);
        // Growth goes to the cheaper-per-throughput A100 column; the
        // H100 incumbents are not grown.
        assert!(east.deltas[1] >= 4, "A100 delta {}", east.deltas[1]);
        assert!(east.deltas[0] <= 0, "H100 delta {}", east.deltas[0]);
    }

    /// Single hot region, disaggregated epoch: the merged plan grows the
    /// busy endpoint, respects the shared per-endpoint budget (the phase
    /// caps sum to `max_instances`), and reports a usable refined split.
    #[test]
    fn disagg_epoch_sizes_both_phases_under_one_budget() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let disagg = DisaggParams::enabled();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let pre = vec![[1usize, 0, 0]; Region::ALL.len()];
        let dec = vec![[1usize, 0, 0]; Region::ALL.len()];
        let (plan, frac) = run_epoch_disagg(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &disagg,
            &pre, &dec, &mut SolverStates::new(), &mut SolverStates::new(), 0.0,
        );
        assert_eq!(plan.len(), 3);
        assert!((0.1..=0.9).contains(&frac), "refined fraction {frac}");
        let east = plan.iter().find(|p| p.region == Region::EastUs).unwrap();
        assert!(east.delta_total() > 0, "east delta {}", east.delta_total());
        for e in &plan {
            // One prefill + one decode incumbent per endpoint.
            let total = 2 + e.delta_total();
            assert!(total <= params.max_instances as i64, "{:?} total {total}", e.region);
        }
    }

    /// A tighter ITL target shrinks per-instance decode throughput, so
    /// the decode solve claims a (weakly) larger share of the budget.
    #[test]
    fn tighter_itl_target_shifts_budget_toward_decode() {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            warm.insert((ModelKind::Llama2_70B, r), vec![4_000.0; 192]);
        }
        telemetry.warmup(&warm);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let pre = vec![[1usize, 0, 0]; Region::ALL.len()];
        let dec = vec![[1usize, 0, 0]; Region::ALL.len()];
        let mut frac_for = |itl: f64| {
            let disagg = DisaggParams { itl_target: itl, ..DisaggParams::enabled() };
            let mut forecaster = SeasonalNaive::new(96, 4);
            run_epoch_disagg(
                &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &disagg,
                &pre, &dec, &mut SolverStates::new(), &mut SolverStates::new(), 0.0,
            )
            .1
        };
        let loose = frac_for(0.5);
        let tight = frac_for(0.05);
        assert!(tight <= loose + 1e-9, "tight {tight} vs loose {loose}");
    }

    /// Multi-model telemetry for the fan-out tests: distinct demand per
    /// model so the per-model ILPs produce distinct plans.
    fn multi_model_telemetry(models: &[ModelKind]) -> Telemetry {
        let mut telemetry = Telemetry::new(models, 900.0);
        let mut warm = BTreeMap::new();
        for (mi, &m) in models.iter().enumerate() {
            for (ri, r) in Region::ALL.into_iter().enumerate() {
                let tps = 2_000.0 * (mi + 1) as f64 + 300.0 * ri as f64;
                warm.insert((m, r), vec![tps; 192]);
            }
        }
        telemetry.warmup(&warm);
        telemetry
    }

    /// The §5 ILPs share no state across models, so fanning them over the
    /// sweep pool must reproduce the sequential plan bit-for-bit.
    #[test]
    fn parallel_epoch_matches_sequential() {
        let models = [ModelKind::Llama2_70B, ModelKind::Llama31_8B, ModelKind::Llama32_3B];
        let telemetry = multi_model_telemetry(&models);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let counts = vec![[3usize, 0, 0]; models.len() * Region::ALL.len()];
        let mut f_par = SeasonalNaive::new(96, 4);
        let mut f_seq = SeasonalNaive::new(96, 4);
        let par = run_epoch(
            &telemetry, &mut f_par, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0,
        );
        let seq = run_epoch_sequential(
            &telemetry, &mut f_seq, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0,
        );
        assert_eq!(par.len(), models.len() * Region::ALL.len());
        assert_eq!(par, seq);
    }

    /// Hot single-region telemetry shared by the guardrail tests.
    fn hot_east_telemetry() -> Telemetry {
        let models = [ModelKind::Llama2_70B];
        let mut telemetry = Telemetry::new(&models, 900.0);
        let mut warm = BTreeMap::new();
        for r in Region::ALL {
            let tps = if r == Region::EastUs { 20_000.0 } else { 50.0 };
            warm.insert((ModelKind::Llama2_70B, r), vec![tps; 192]);
        }
        telemetry.warmup(&warm);
        telemetry
    }

    /// Clean mods must be a bit-exact no-op: `run_epoch_modded` with
    /// `ControlEpochMods::clean()` equals `run_epoch`.
    #[test]
    fn clean_mods_are_identity() {
        let telemetry = hot_east_telemetry();
        let perf = PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]);
        let params = ScalingParams::default();
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let mut f1 = SeasonalNaive::new(96, 4);
        let mut f2 = SeasonalNaive::new(96, 4);
        let plain = run_epoch(
            &telemetry, &mut f1, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0,
        );
        let modded = run_epoch_modded(
            &telemetry, &mut f2, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 0.0, &ControlEpochMods::clean(),
        );
        assert!(ControlEpochMods::default().is_clean());
        assert_eq!(plain, modded);
    }

    /// A forecast blackout makes the naive controller scale everything
    /// in (zero forecast ⇒ min targets), and a forced solver fault makes
    /// it clamp everything to max — the two failure modes the guarded
    /// cascade exists to absorb.
    #[test]
    fn naive_mods_distort_the_plan_as_designed() {
        let telemetry = hot_east_telemetry();
        let perf = PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]);
        let params = ScalingParams::default();
        let counts = vec![[6usize, 0, 0]; Region::ALL.len()];
        let run = |mods: &ControlEpochMods| {
            let mut f = SeasonalNaive::new(96, 4);
            run_epoch_modded(
                &telemetry, &mut f, &perf, &[GpuKind::H100x8], &params, &counts,
                &mut SolverStates::new(), 0.0, mods,
            )
        };
        let blackout =
            run(&ControlEpochMods { forecast_blackout: true, ..ControlEpochMods::clean() });
        for e in &blackout {
            assert_eq!(
                e.delta_total(),
                params.min_instances as i64 - 6,
                "blackout ⇒ scale-in to the floor ({:?})",
                e.region
            );
            assert_eq!(e.forecast_tps, 0.0, "blackout zeroes the LT-UA gap reference");
        }
        let faulted = run(&ControlEpochMods { solver_fault: true, ..ControlEpochMods::clean() });
        for e in &faulted {
            assert_eq!(
                e.delta_total(),
                params.max_instances as i64 - 6,
                "solver fault ⇒ clamp to max ({:?})",
                e.region
            );
        }
        // Corruption scales the forecast: halving demand must not plan
        // *more* capacity than the honest epoch in the hot region.
        let honest = run(&ControlEpochMods::clean());
        let halved = run(&ControlEpochMods {
            forecast_corruption: Some((0.5, 0.0)),
            ..ControlEpochMods::clean()
        });
        let east = |p: &EpochPlan| {
            p.iter().find(|e| e.region == Region::EastUs).unwrap().delta_total()
        };
        assert!(east(&halved) < east(&honest), "halved forecast plans less east capacity");
    }

    /// θ deflation commands extra capacity: a 50% margin on the hot
    /// region plans at least as many instances as the honest epoch, and
    /// strictly more in the hot region.
    #[test]
    fn theta_deflation_commands_margin_capacity() {
        let telemetry = hot_east_telemetry();
        let perf = PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]);
        let params = ScalingParams::default();
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let run = |deflate: f64| {
            let mut f = SeasonalNaive::new(96, 4);
            run_epoch_modded(
                &telemetry, &mut f, &perf, &[GpuKind::H100x8], &params, &counts,
                &mut SolverStates::new(), 0.0,
                &ControlEpochMods { theta_deflate: deflate, ..ControlEpochMods::clean() },
            )
        };
        let base = run(0.0);
        let inflated = run(0.5);
        let east = |p: &EpochPlan| {
            p.iter().find(|e| e.region == Region::EastUs).unwrap().delta_total()
        };
        assert!(east(&inflated) > east(&base), "50% θ margin grows the hot region");
    }

    /// Residual tracker math: RMS pools bias and noise, and the margin
    /// is gain-scaled then capped.
    #[test]
    fn residual_rms_and_margin_clamp() {
        let mut state = GuardrailState::new();
        assert_eq!(state.residual_rms(), 0.0);
        let key = (ModelKind::Llama2_70B, Region::EastUs);
        state.residuals.insert(key, vec![0.3; 4]);
        assert!((state.residual_rms() - 0.3).abs() < 1e-12, "constant bias is charged");
        let guard = GuardrailParams::enabled();
        let expect = (guard.inflation_gain * 0.3).min(guard.max_inflation);
        assert!((state.margin(&guard) - expect).abs() < 1e-12);
        // A huge error saturates at the cap.
        state.residuals.insert(key, vec![10.0; 4]);
        assert_eq!(state.margin(&guard), guard.max_inflation);
    }

    /// The full cascade: Fresh under healthy inputs, Held (inflated
    /// last-good targets) under a blackout, Reactive (empty plan) once
    /// the hold budget is spent, Fresh again on recovery — with every
    /// transition and degraded second accounted.
    #[test]
    fn cascade_walks_fresh_held_reactive_and_recovers() {
        let telemetry = hot_east_telemetry();
        let perf = PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]);
        let params = ScalingParams::default();
        let guard = GuardrailParams::enabled();
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let mut forecaster = SeasonalNaive::new(96, 4);
        let mut solvers = SolverStates::new();
        let mut state = GuardrailState::new();
        let mut stats = GuardrailStats::default();
        let mut epoch = |mods: &ControlEpochMods,
                         state: &mut GuardrailState,
                         stats: &mut GuardrailStats,
                         now: Time| {
            guardrail_epoch(
                &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &guard,
                &counts, &mut solvers, now, mods, state, stats,
            )
        };

        let clean = ControlEpochMods::clean();
        let dark = ControlEpochMods { forecast_blackout: true, ..ControlEpochMods::clean() };
        let fresh = epoch(&clean, &mut state, &mut stats, 0.0);
        assert_eq!(state.mode, GuardrailMode::Fresh);
        assert!(!fresh.is_empty());
        let east_target = {
            let e = fresh.iter().find(|e| e.region == Region::EastUs).unwrap();
            2 + e.delta_total()
        };
        assert!(east_target > 2, "hot region grows under the fresh plan");

        // Blackout epoch 1 + 2: held, targets inflated, never shrunk.
        let held = epoch(&dark, &mut state, &mut stats, 3600.0);
        assert_eq!(state.mode, GuardrailMode::Held);
        let e = held.iter().find(|e| e.region == Region::EastUs).unwrap();
        let held_target = 2 + e.delta_total();
        assert!(
            held_target >= east_target,
            "held target {held_target} must not shrink below last-good {east_target}"
        );
        assert!(e.forecast_tps > 0.0, "held entries keep the last-good LT-UA reference");
        let _ = epoch(&dark, &mut state, &mut stats, 7200.0);
        assert_eq!(state.mode, GuardrailMode::Held);

        // Blackout epoch 3: hold budget (2) spent ⇒ reactive, empty plan.
        let reactive = epoch(&dark, &mut state, &mut stats, 10_800.0);
        assert_eq!(state.mode, GuardrailMode::Reactive);
        assert!(reactive.is_empty(), "reactive rung plans nothing; the tick backstop scales");

        // Recovery: straight back to Fresh.
        let back = epoch(&clean, &mut state, &mut stats, 14_400.0);
        assert_eq!(state.mode, GuardrailMode::Fresh);
        assert!(!back.is_empty());

        assert_eq!(stats.epochs_fresh, 2);
        assert_eq!(stats.epochs_held, 2);
        assert_eq!(stats.epochs_reactive, 1);
        assert_eq!(stats.degraded_secs, 3.0 * params.control_interval);
        let kinds: Vec<(&str, GuardrailMode, GuardrailMode)> =
            stats.transitions.iter().map(|t| (t.cause, t.from, t.to)).collect();
        assert_eq!(
            kinds,
            vec![
                ("forecast-blackout", GuardrailMode::Fresh, GuardrailMode::Held),
                ("held-expired", GuardrailMode::Held, GuardrailMode::Reactive),
                ("recovered", GuardrailMode::Reactive, GuardrailMode::Fresh),
            ]
        );
        assert!(stats.margin_instance_hours > 0.0, "held inflation fills the margin ledger");
    }

    /// Stale telemetry beyond the watchdog tolerance trips the cascade
    /// even though the forecaster and solver are healthy.
    #[test]
    fn watchdog_trips_on_stale_telemetry() {
        let telemetry = hot_east_telemetry();
        let perf = PerfTable::new(GpuKind::H100x8, &[ModelKind::Llama2_70B]);
        let params = ScalingParams::default();
        let guard = GuardrailParams::enabled();
        let counts = vec![[2usize, 0, 0]; Region::ALL.len()];
        let mut forecaster = SeasonalNaive::new(96, 4);
        let mut solvers = SolverStates::new();
        let mut state = GuardrailState::new();
        let mut stats = GuardrailStats::default();
        let _ = guardrail_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &guard, &counts,
            &mut solvers, 0.0, &ControlEpochMods::clean(), &mut state, &mut stats,
        );
        assert_eq!(state.mode, GuardrailMode::Fresh);
        // Telemetry frozen a full epoch ago: age 3600 s > 1800 s tolerance.
        let stale = ControlEpochMods { telemetry_now: Some(0.0), ..ControlEpochMods::clean() };
        let _ = guardrail_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &guard, &counts,
            &mut solvers, 3600.0, &stale, &mut state, &mut stats,
        );
        assert_eq!(state.mode, GuardrailMode::Held);
        assert_eq!(stats.transitions.last().unwrap().cause, "stale-telemetry");
    }

    /// Epoch N+1 with slightly drifted demand reuses epoch N's basis:
    /// the second run's solves come back warm and its plan matches a
    /// cold-state run of the same epoch.
    #[test]
    fn epoch_warm_state_survives_to_next_epoch() {
        let models = [ModelKind::Llama2_70B, ModelKind::Llama32_3B];
        let telemetry = multi_model_telemetry(&models);
        let perf = PerfTable::new(GpuKind::H100x8, &models);
        let params = ScalingParams::default();
        let counts = vec![[3usize, 0, 0]; models.len() * Region::ALL.len()];
        let mut solvers = SolverStates::new();
        let mut forecaster = SeasonalNaive::new(96, 4);
        let first = run_epoch(
            &telemetry, &mut forecaster, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut solvers, 0.0,
        );
        // Next epoch, 15 minutes on: same matrix (θ, α, σ unchanged), new
        // rhs — the solver state must be reused, and the answer must match
        // a from-scratch solve of the same epoch.
        let mut f2 = SeasonalNaive::new(96, 4);
        let second = run_epoch(
            &telemetry, &mut f2, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut solvers, 900.0,
        );
        let mut f3 = SeasonalNaive::new(96, 4);
        let cold = run_epoch(
            &telemetry, &mut f3, &perf, &[GpuKind::H100x8], &params, &counts,
            &mut SolverStates::new(), 900.0,
        );
        assert_eq!(second, cold);
        assert_eq!(first.len(), second.len());
        for m in models {
            assert!(
                solvers.for_model(m).has_state(),
                "solver state for {m:?} should persist across epochs"
            );
        }
    }
}
