//! Auto-scaling strategies (§4, §6.4) plus the Chiron SOTA baseline [34].
//!
//! * **Siloed** — the legacy O365 deployment: separate IW (16) / NIW (4)
//!   pools per (model, region), each reactively scaled on 70/30 effective
//!   memory-utilization thresholds with a 15 s cooldown.
//! * **Reactive** — the same thresholds over one *unified* pool (§4).
//! * **LT-I** — apply the hourly forecast+ILP δ immediately (§6.4).
//! * **LT-U** — arm the δ target, move toward it only when the 70/30
//!   utilization thresholds are actually breached.
//! * **LT-UA** — LT-U plus the ARIMA-gap override: in the last 20 min of
//!   the hour, keep scaling past the target if observed TPS ≥ 5× forecast
//!   (under-prediction) or below it if ≤ 0.5× (over-prediction).
//! * **Chiron** — interactive/mixed/batch pools (10/5/5 init) scaled by
//!   queue backpressure against Θ·SLA using offline profiles; no
//!   memory-utilization consolidation (which is why it over-provisions —
//!   §7.2.3).

use std::collections::BTreeMap;

use crate::config::{GpuKind, ModelKind, Region, ScalingParams, Tier, Time};
use crate::coordinator::controller::EpochPlanEntry;
use crate::metrics::Metrics;
use crate::perf::PerfTable;
use crate::sim::cluster::{Cluster, PoolTag};
use crate::sim::event::{Event, EventQueue};

/// Scaling strategy selector (CLI-visible names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Legacy separate IW/NIW pools, reactively scaled (§4).
    Siloed,
    /// Unified pool on the same reactive thresholds (§4).
    Reactive,
    /// Long-term forecast, ILP delta applied immediately (§6.4).
    LtI,
    /// Long-term forecast, delta armed and applied on util breach (§6.4).
    LtU,
    /// LT-U plus the ARIMA-gap override window (§6.4).
    LtUa,
    /// The Chiron queue-backpressure SOTA baseline [34].
    Chiron,
}

impl Strategy {
    /// CLI-visible strategy name (`lt-ua`, `chiron`, ...).
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Siloed => "siloed",
            Strategy::Reactive => "reactive",
            Strategy::LtI => "lt-i",
            Strategy::LtU => "lt-u",
            Strategy::LtUa => "lt-ua",
            Strategy::Chiron => "chiron",
        }
    }

    /// Inverse of [`Strategy::name`] (accepts hyphen-free aliases).
    pub fn parse(s: &str) -> Option<Strategy> {
        Some(match s {
            "siloed" => Strategy::Siloed,
            "reactive" => Strategy::Reactive,
            "lt-i" | "lti" => Strategy::LtI,
            "lt-u" | "ltu" => Strategy::LtU,
            "lt-ua" | "ltua" => Strategy::LtUa,
            "chiron" => Strategy::Chiron,
            _ => return None,
        })
    }

    /// Does this strategy use the NIW Queue Manager (unified pool)?
    pub fn uses_queue_manager(self) -> bool {
        !matches!(self, Strategy::Siloed | Strategy::Chiron)
    }

    /// Does this strategy run the hourly forecast + ILP epoch?
    pub fn uses_forecast(self) -> bool {
        matches!(self, Strategy::LtI | Strategy::LtU | Strategy::LtUa)
    }

    /// Initial pool layout per (model, region), given the total instance
    /// budget per endpoint (§4: Siloed 16/4 of 20; §7.1: Chiron 10/5/5).
    pub fn initial_pools(self, total: usize) -> Vec<(PoolTag, usize)> {
        match self {
            Strategy::Siloed => {
                let niw = (total / 5).max(1);
                vec![(PoolTag::SiloIw, total - niw), (PoolTag::SiloNiw, niw)]
            }
            Strategy::Chiron => {
                let batch = total / 4;
                let mixed = total / 4;
                vec![
                    (PoolTag::ChironInteractive, total - batch - mixed),
                    (PoolTag::ChironMixed, mixed),
                    (PoolTag::ChironBatch, batch),
                ]
            }
            _ => vec![(PoolTag::Unified, total)],
        }
    }
}

/// Borrowed simulation pieces the scaler operates on.
pub struct ScaleCtx<'a> {
    /// Current simulated time.
    pub now: Time,
    /// The fleet being scaled.
    pub cluster: &'a mut Cluster,
    /// Ledger/waste accounting sink.
    pub metrics: &'a mut Metrics,
    /// Event heap (for scheduling `ProvisionDone`).
    pub events: &'a mut EventQueue,
    /// Requests displaced by immediate drains; the engine re-routes these
    /// after the autoscaler call returns.
    pub reroutes: Vec<crate::trace::types::Request>,
    /// Control-fault plane: scale-outs are silently swallowed this tick
    /// (the scaler is told they succeeded; no VM ever comes).
    pub act_drop: bool,
    /// Control-fault plane: extra provisioning lead time (secs) added to
    /// every scale-out committed this tick.  0 when no delay window is
    /// open — the untouched path.
    pub act_extra_lead: Time,
}

impl ScaleCtx<'_> {
    /// Commit a successful scale-out: schedule the activation event and
    /// re-record the affected ledgers.  `prev_model` is the model the VM
    /// hosted before — a cross-model spot reclaim removes a donated VM
    /// from *another* endpoint's pool, so that endpoint's spot ledgers
    /// must be re-recorded too (or they would keep accruing spot revenue
    /// for a VM that was already taken back).
    fn commit_scale_out(
        &mut self,
        model: ModelKind,
        region: Region,
        id: crate::sim::cluster::InstanceId,
        ready: Time,
        prev_model: ModelKind,
    ) {
        // Actuation-delay fault: the cloud control plane acknowledged
        // the request but executes it late.  Branch (never add 0.0) so
        // delay-free runs stay bit-identical.
        let ready = if self.act_extra_lead > 0.0 {
            self.metrics.guardrails.actuations_delayed += 1;
            ready + self.act_extra_lead
        } else {
            ready
        };
        self.events.push(ready, Event::ProvisionDone { instance: id });
        self.record_ledgers(model, region);
        if prev_model != model {
            self.record_ledgers(prev_model, region);
        }
    }

    /// Actuation-drop fault: report success without touching the fleet
    /// — the scaler (and its cooldown logic) believes capacity is
    /// coming, but it never does.  Returns true when the drop fired.
    fn drop_actuation(&mut self) -> bool {
        if self.act_drop {
            self.metrics.guardrails.actuations_dropped += 1;
        }
        self.act_drop
    }

    /// Scale out one instance of an explicit SKU and schedule its
    /// ProvisionDone event.
    fn scale_out(&mut self, model: ModelKind, region: Region, pool: PoolTag, gpu: GpuKind) -> bool {
        if self.drop_actuation() {
            return true;
        }
        let Some((id, ready, prev)) =
            self.cluster.scale_out(model, region, pool, gpu, self.now, self.metrics)
        else {
            return false;
        };
        self.commit_scale_out(model, region, id, ready, prev);
        true
    }

    /// Scale out when no per-SKU plan pins the SKU — the per-SKU
    /// spot-market policy, two passes:
    ///
    /// 1. **Spot reclaim, most-valuable SKU first** (descending
    ///    [`GpuKind::spot_dollars_per_hour`]): donated VMs are the
    ///    fastest source (~1 min same-model vs ~10 min fresh) and their
    ///    α is already sunk fleet-wide; the dearest donations are the
    ///    ones external claimants compete hardest for, so they are
    ///    taken back first while they are still in the pool.
    /// 2. **Fresh provisioning, cheapest SKU first** (ascending α) —
    ///    the §5 cost ordering for capacity that actually adds spend.
    ///
    /// (Until PR 4 the single pass was α-ascending over *both* sources,
    /// so a cheap fresh VM outranked an expensive spot reclaim; with
    /// per-SKU spot prices the reclaim/provision split prices the two
    /// sources separately.)
    fn scale_out_spot_then_cheapest(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
    ) -> bool {
        if self.drop_actuation() {
            return true;
        }
        let (order, n) = self.gpus_by_spot_value();
        for &gpu in &order[..n] {
            let Some((id, ready, prev)) =
                self.cluster.reclaim_spot(model, region, pool, gpu, self.now, self.metrics)
            else {
                continue;
            };
            self.commit_scale_out(model, region, id, ready, prev);
            return true;
        }
        let (order, n) = self.gpus_by_cost(false);
        for &gpu in &order[..n] {
            let Some((id, ready)) =
                self.cluster.provision_fresh(model, region, pool, gpu, self.now, self.metrics)
            else {
                continue;
            };
            self.commit_scale_out(model, region, id, ready, model);
            return true;
        }
        false
    }

    /// Begin draining one instance (it converts to spot when empty).
    /// Idle instances (no running batch) convert immediately — otherwise
    /// an idle endpoint would hold Draining instances forever, since only
    /// chunk completions trigger `finish_drain`.
    fn scale_in(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: Option<PoolTag>,
        gpu: Option<GpuKind>,
    ) -> bool {
        let Some(id) = self.cluster.scale_in(model, region, pool, gpu) else {
            return false;
        };
        if self.cluster.instances[id].batch.is_empty() {
            let stragglers = self.cluster.take_waiting(id);
            self.reroutes.extend(stragglers);
            self.cluster.finish_drain(id);
        }
        self.record_ledgers(model, region);
        true
    }

    /// Scale in from the most expensive SKU that has an eligible
    /// instance — releasing dear silicon first minimizes fleet cost.
    fn scale_in_dearest(&mut self, model: ModelKind, region: Region, pool: Option<PoolTag>) -> bool {
        let (order, n) = self.gpus_by_cost(true);
        for &gpu in &order[..n] {
            if self.scale_in(model, region, pool, Some(gpu)) {
                return true;
            }
        }
        false
    }

    /// Fleet SKUs ordered by $/h (ascending, or descending when `desc`),
    /// copied from the cluster's precomputed orders into a stack array —
    /// allocation-free on the per-tick/per-request scaling paths.
    fn gpus_by_cost(&self, desc: bool) -> ([GpuKind; GpuKind::COUNT], usize) {
        let src = if desc { &self.cluster.gpus_cost_desc } else { &self.cluster.gpus_cost_asc };
        let mut out = [GpuKind::H100x8; GpuKind::COUNT];
        out[..src.len()].copy_from_slice(src);
        (out, src.len())
    }

    /// Fleet SKUs by descending spot-market value (the
    /// most-valuable-first reclaim order), stack-copied like
    /// [`ScaleCtx::gpus_by_cost`].
    fn gpus_by_spot_value(&self) -> ([GpuKind; GpuKind::COUNT], usize) {
        let src = &self.cluster.gpus_spot_desc;
        let mut out = [GpuKind::H100x8; GpuKind::COUNT];
        out[..src.len()].copy_from_slice(src);
        (out, src.len())
    }

    /// Sweep Draining instances that can no longer make progress: an
    /// empty batch with no chunk in flight means nothing will ever call
    /// `finish_drain` for them again (only chunk completions do), so
    /// they would sit Draining forever — holding their endpoint slot and
    /// stranding any waiting requests.  The state is unreachable on the
    /// healthy path (`scale_in` converts idle instances immediately and
    /// chunk completions convert the rest), but fault-plane kills and
    /// admission stalls can manufacture it; the engine runs this on
    /// every scale tick as a deterministic backstop.  Displaced waiting
    /// requests land in [`ScaleCtx::reroutes`].  Returns how many
    /// instances were converted.
    pub fn sweep_stalled_drains(&mut self) -> usize {
        let mut swept = 0;
        for id in 0..self.cluster.instances.len() {
            let inst = &self.cluster.instances[id];
            if inst.state != crate::sim::instance::InstState::Draining
                || !inst.batch.is_empty()
                || inst.chunk_scheduled
            {
                continue;
            }
            let (model, region) = (inst.model, inst.region);
            let stragglers = self.cluster.take_waiting(id);
            self.reroutes.extend(stragglers);
            self.cluster.finish_drain(id);
            self.record_ledgers(model, region);
            swept += 1;
        }
        swept
    }

    /// Re-record the instance-count, per-SKU GPU-hour and spot ledgers
    /// for one endpoint at `now` — called after any change to its
    /// allocation or the region's donated pool, so every step-function
    /// ledger integrates exactly.
    pub fn record_ledgers(&mut self, model: ModelKind, region: Region) {
        let allocated = self.cluster.allocated_count(model, region);
        self.metrics
            .instances
            .entry((model, region))
            .or_default()
            .record(self.now, allocated);
        // Per-SKU GPU-hour attribution rides on the same change points.
        let by_gpu = self.cluster.allocated_by_gpu(model, region);
        for gi in 0..self.cluster.gpus.len() {
            let gpu = self.cluster.gpus[gi];
            self.metrics
                .instances_by_gpu
                .entry((model, region, gpu))
                .or_default()
                .record(self.now, by_gpu[gpu.index()]);
        }
        // Spot ledgers: per-SKU counts in one pass over the region's
        // donated pool — the single source of truth both spot-hour
        // totals and the spot-market revenue integration derive from.
        let mut spot_by_gpu = [0usize; GpuKind::COUNT];
        if let Some(pool) = self.cluster.spot_pool.get(&region) {
            for &i in pool {
                let inst = &self.cluster.instances[i];
                if inst.model == model {
                    spot_by_gpu[inst.gpu.index()] += 1;
                }
            }
        }
        for gi in 0..self.cluster.gpus.len() {
            let gpu = self.cluster.gpus[gi];
            self.metrics
                .spot_instances_by_gpu
                .entry((model, region, gpu))
                .or_default()
                .record(self.now, spot_by_gpu[gpu.index()]);
        }
    }

    fn cooldown_ok(&self, model: ModelKind, region: Region, params: &ScalingParams) -> bool {
        let ep = &self.cluster.endpoints[&(model, region)];
        self.now - ep.last_scale >= params.cooldown_secs || ep.last_scale == 0.0
    }

    fn touch_cooldown(&mut self, model: ModelKind, region: Region) {
        self.cluster.endpoints.get_mut(&(model, region)).unwrap().last_scale = self.now;
    }
}

/// Chiron per-pool scaling state.
#[derive(Debug, Default)]
struct ChironState {
    /// Exponentially-smoothed interactive backpressure per (model, region).
    pressure: BTreeMap<(ModelKind, Region), f64>,
}

/// The autoscaler: strategy + mutable state.
pub struct Autoscaler {
    /// The strategy under test.
    pub strategy: Strategy,
    /// Thresholds, cooldowns and control-interval knobs.
    pub params: ScalingParams,
    /// Chiron's Θ (0.6 per §7.1).
    pub chiron_theta: f64,
    chiron: ChironState,
}

impl Autoscaler {
    /// A fresh autoscaler with empty strategy state.
    pub fn new(strategy: Strategy, params: ScalingParams) -> Self {
        Autoscaler { strategy, params, chiron_theta: 0.6, chiron: ChironState::default() }
    }

    /// Per-request reactive check (§4: scaling decisions made per request,
    /// 15 s cooldown).  Applies to Siloed and Reactive; LT-U/LT-UA use the
    /// same thresholds but only toward their armed targets (on_tick).
    pub fn on_request(&mut self, ctx: &mut ScaleCtx, model: ModelKind, region: Region, tier: Tier) {
        match self.strategy {
            Strategy::Reactive => {
                self.reactive_check(ctx, model, region, PoolTag::Unified, None);
            }
            Strategy::Siloed => {
                let pool = if tier.is_interactive() { PoolTag::SiloIw } else { PoolTag::SiloNiw };
                self.reactive_check(ctx, model, region, pool, Some(pool));
            }
            _ => {}
        }
    }

    fn reactive_check(
        &mut self,
        ctx: &mut ScaleCtx,
        model: ModelKind,
        region: Region,
        out_pool: PoolTag,
        filter: Option<PoolTag>,
    ) {
        if !ctx.cooldown_ok(model, region, &self.params) {
            return;
        }
        let util = ctx.cluster.pool_util(model, region, filter);
        if util > self.params.scale_out_util {
            if ctx.scale_out_spot_then_cheapest(model, region, out_pool) {
                ctx.touch_cooldown(model, region);
            }
        } else if util < self.params.scale_in_util {
            if ctx.scale_in_dearest(model, region, filter) {
                ctx.touch_cooldown(model, region);
            }
        }
    }

    /// Hourly control epoch: arm or apply the per-SKU ILP deltas (LT
    /// strategies).  Execution order is cost-aware: positive deltas run
    /// cheapest-SKU-first, negative deltas most-expensive-first.
    pub fn on_epoch(&mut self, ctx: &mut ScaleCtx, plans: &[EpochPlanEntry]) {
        if !self.strategy.uses_forecast() {
            return;
        }
        let gpus: Vec<GpuKind> = ctx.cluster.gpus.clone();
        // SKU indices by ascending $/h (stable: ties keep fleet order).
        let mut cost_order: Vec<usize> = (0..gpus.len()).collect();
        cost_order.sort_by(|&a, &b| {
            gpus[a].dollars_per_hour().partial_cmp(&gpus[b].dollars_per_hour()).unwrap()
        });
        for entry in plans {
            let (model, region) = (entry.model, entry.region);
            let current = ctx.cluster.allocated_count(model, region) as i64;
            let delta_total = entry.delta_total();
            let target = (current + delta_total).max(self.params.min_instances as i64) as usize;
            let alloc_by_gpu = ctx.cluster.allocated_by_gpu(model, region);
            {
                let ep = ctx.cluster.endpoints.get_mut(&(model, region)).unwrap();
                ep.target = Some(target);
                ep.forecast_tps = entry.forecast_tps;
                ep.target_by_gpu = [None; GpuKind::COUNT];
                for (k, &gpu) in gpus.iter().enumerate() {
                    let cur_k = alloc_by_gpu[gpu.index()] as i64;
                    let delta_k = entry.deltas.get(k).copied().unwrap_or(0);
                    ep.target_by_gpu[gpu.index()] = Some((cur_k + delta_k).max(0) as usize);
                }
            }
            if self.strategy == Strategy::LtI {
                // Immediate: jump straight to the recommended per-SKU
                // counts.  Removals (dearest SKU first) run before
                // additions (cheapest first) so a mixed-sign SKU-swap
                // plan frees endpoint slots before filling them — at
                // max_instances the additions would otherwise all fail
                // and the swap would under-execute into a net shrink.
                // Single-sign plans (every single-SKU plan) are
                // unaffected by the ordering.
                for &k in cost_order.iter().rev() {
                    let d = entry.deltas.get(k).copied().unwrap_or(0);
                    for _ in 0..(-d).max(0) {
                        if !ctx.scale_in(model, region, None, Some(gpus[k])) {
                            break;
                        }
                    }
                }
                for &k in &cost_order {
                    let d = entry.deltas.get(k).copied().unwrap_or(0);
                    for _ in 0..d.max(0) {
                        if !ctx.scale_out(model, region, PoolTag::Unified, gpus[k]) {
                            break;
                        }
                    }
                }
            }
        }
    }

    /// Periodic tick: LT-U/LT-UA deferred progression, the LT-UA
    /// forecast-gap override, and Chiron's backpressure loop.
    /// `observed_tps`: current input TPS per (model, region);
    /// `epoch_elapsed`: seconds into the current control hour.
    pub fn on_tick(
        &mut self,
        ctx: &mut ScaleCtx,
        observed_tps: &BTreeMap<(ModelKind, Region), f64>,
        epoch_elapsed: Time,
    ) {
        match self.strategy {
            Strategy::LtU | Strategy::LtUa => {
                self.lt_tick(ctx, observed_tps, epoch_elapsed);
            }
            Strategy::Chiron => self.chiron_tick(ctx, observed_tps),
            _ => {}
        }
    }

    fn lt_tick(
        &mut self,
        ctx: &mut ScaleCtx,
        observed_tps: &BTreeMap<(ModelKind, Region), f64>,
        epoch_elapsed: Time,
    ) {
        // Index-based endpoint walk (`EndpointMap::key_at`): no per-tick
        // key Vec — the endpoint set is fixed after construction.
        for idx in 0..ctx.cluster.endpoints.len() {
            let (model, region) = ctx.cluster.endpoints.key_at(idx);
            let (target, forecast_tps) = {
                let ep = &ctx.cluster.endpoints[&(model, region)];
                match ep.target {
                    Some(t) => (t, ep.forecast_tps),
                    None => continue,
                }
            };
            if !ctx.cooldown_ok(model, region, &self.params) {
                continue;
            }
            let allocated = ctx.cluster.allocated_count(model, region);
            let util = ctx.cluster.pool_util(model, region, None);
            // Deferred progression toward the armed target (LT-U core).
            if allocated < target && util > self.params.scale_out_util {
                if self.lt_scale_out_step(ctx, model, region) {
                    ctx.touch_cooldown(model, region);
                }
                continue;
            }
            if allocated > target && util < self.params.scale_in_util {
                if self.lt_scale_in_step(ctx, model, region) {
                    ctx.touch_cooldown(model, region);
                }
                continue;
            }
            // LT-UA: forecast-gap override in the last 20 min of the hour.
            if self.strategy == Strategy::LtUa
                && epoch_elapsed >= self.params.control_interval - self.params.ua_window
            {
                let observed = observed_tps.get(&(model, region)).copied().unwrap_or(0.0);
                if forecast_tps > 0.0 {
                    let ratio = observed / forecast_tps;
                    if ratio >= self.params.ua_over_factor && allocated >= target {
                        if ctx.scale_out_spot_then_cheapest(model, region, PoolTag::Unified) {
                            ctx.touch_cooldown(model, region);
                        }
                    } else if ratio <= self.params.ua_under_factor
                        && allocated <= target
                        && util < self.params.scale_in_util
                    {
                        if ctx.scale_in_dearest(model, region, None) {
                            ctx.touch_cooldown(model, region);
                        }
                    }
                }
            }
        }
    }

    /// The guardrail cascade's bottom rung: a per-tick reactive backstop
    /// over the **Unified** pool, used by the LT strategies when the
    /// control plane is so degraded that no plan — fresh or held — is
    /// trustworthy.  Same 70/30 thresholds as the Reactive strategy,
    /// driven from the scale tick instead of per request, and reading
    /// live cluster utilization rather than the telemetry feed (the
    /// feed may be the very thing that failed).  Scale-in stops at the
    /// configured floor: a blind backstop must never drain an endpoint.
    pub fn guardrail_reactive_tick(&mut self, ctx: &mut ScaleCtx) {
        for idx in 0..ctx.cluster.endpoints.len() {
            let (model, region) = ctx.cluster.endpoints.key_at(idx);
            if !ctx.cooldown_ok(model, region, &self.params) {
                continue;
            }
            let util = ctx.cluster.pool_util(model, region, None);
            if util > self.params.scale_out_util {
                if ctx.scale_out_spot_then_cheapest(model, region, PoolTag::Unified) {
                    ctx.touch_cooldown(model, region);
                }
            } else if util < self.params.scale_in_util {
                let allocated = ctx.cluster.allocated_count(model, region);
                if allocated > self.params.min_instances
                    && ctx.scale_in_dearest(model, region, None)
                {
                    ctx.touch_cooldown(model, region);
                }
            }
        }
    }

    /// One LT-U progression step toward the armed per-SKU targets:
    /// cheapest SKU still below its target first; if every per-SKU
    /// target is met (reactive drift between epochs), the unpinned
    /// spot-first policy decides.
    fn lt_scale_out_step(&self, ctx: &mut ScaleCtx, model: ModelKind, region: Region) -> bool {
        let (alloc, targets) = {
            let ep = &ctx.cluster.endpoints[&(model, region)];
            (ep.alloc_by_gpu, ep.target_by_gpu)
        };
        let (order, n) = ctx.gpus_by_cost(false);
        for &gpu in &order[..n] {
            if let Some(t) = targets[gpu.index()] {
                if alloc[gpu.index()] < t && ctx.scale_out(model, region, PoolTag::Unified, gpu) {
                    return true;
                }
            }
        }
        ctx.scale_out_spot_then_cheapest(model, region, PoolTag::Unified)
    }

    /// One LT-U scale-in step: most-expensive SKU above its armed
    /// per-SKU target first, then most-expensive with any eligible
    /// instance.
    fn lt_scale_in_step(&self, ctx: &mut ScaleCtx, model: ModelKind, region: Region) -> bool {
        let (alloc, targets) = {
            let ep = &ctx.cluster.endpoints[&(model, region)];
            (ep.alloc_by_gpu, ep.target_by_gpu)
        };
        let (order, n) = ctx.gpus_by_cost(true);
        for &gpu in &order[..n] {
            if let Some(t) = targets[gpu.index()] {
                if alloc[gpu.index()] > t && ctx.scale_in(model, region, None, Some(gpu)) {
                    return true;
                }
            }
        }
        for &gpu in &order[..n] {
            if ctx.scale_in(model, region, None, Some(gpu)) {
                return true;
            }
        }
        false
    }

    /// Chiron: scale the interactive pool when estimated queueing delay
    /// breaches Θ × TTFT-SLA (backpressure, from offline profiles); the
    /// batch pool when the NIW backlog's estimated drain time threatens
    /// the 24 h completion deadline.  Interactive consolidation stays
    /// conservative (that's the published behaviour we compare against).
    fn chiron_tick(&mut self, ctx: &mut ScaleCtx, _observed: &BTreeMap<(ModelKind, Region), f64>) {
        // Index-based endpoint walk: no per-tick key Vec.
        for idx in 0..ctx.cluster.endpoints.len() {
            let (model, region) = ctx.cluster.endpoints.key_at(idx);
            if !ctx.cooldown_ok(model, region, &self.params) {
                continue;
            }
            // Estimated interactive queue delay from offline profiles:
            // pending tokens / Σ_k (instances_k × per-SKU profile TPS).
            // Everything comes straight from the per-pool per-SKU
            // aggregates — O(1) per endpoint.
            let mut pending = 0u64;
            let mut n_int = 0usize;
            let mut int_counts = [0usize; GpuKind::COUNT];
            let mut niw_pending = 0u64;
            let mut batch_counts = [0usize; GpuKind::COUNT];
            {
                let ep = &ctx.cluster.endpoints[&(model, region)];
                for pool in PoolTag::ALL {
                    let a = &ep.agg[pool.index()];
                    if pool.serves_iw() {
                        pending += a.pending_tokens;
                        n_int += a.count;
                        for k in 0..GpuKind::COUNT {
                            int_counts[k] += a.count_by_gpu[k];
                        }
                    }
                    if matches!(pool, PoolTag::ChironMixed | PoolTag::ChironBatch) {
                        niw_pending += a.pending_tokens;
                        for k in 0..GpuKind::COUNT {
                            batch_counts[k] += a.count_by_gpu[k];
                        }
                    }
                }
            }
            let primary = ctx.cluster.gpus[0];
            let capacity_tps = fleet_prompt_tps(&ctx.cluster.perf, model, &int_counts, primary);
            let est_delay = pending as f64 / capacity_tps;
            let key = (model, region);
            let smoothed = {
                let p = self.chiron.pressure.entry(key).or_insert(0.0);
                *p = 0.7 * *p + 0.3 * est_delay;
                *p
            };
            // Strictest IW SLA = 1 s (IW-F); Θ = 0.6.
            let sla_budget = self.chiron_theta * 1.0;
            if smoothed > sla_budget {
                if ctx.scale_out_spot_then_cheapest(model, region, PoolTag::ChironInteractive) {
                    ctx.touch_cooldown(model, region);
                    continue;
                }
            } else if smoothed < 0.05 * sla_budget {
                // Conservative scale-in: only at very low pressure AND low
                // utilization, and never below the initial interactive size.
                let util = ctx.cluster.pool_util(model, region, Some(PoolTag::ChironInteractive));
                if util < 0.15 && n_int > 10 {
                    if ctx.scale_in_dearest(model, region, Some(PoolTag::ChironInteractive)) {
                        ctx.touch_cooldown(model, region);
                        continue;
                    }
                }
            }
            // Deadline-driven batch-pool scale-out: if the NIW pools'
            // backlog would take more than Θ × the 24 h deadline to
            // drain at their profiled throughput, grow the batch pool
            // now instead of waiting for backpressure (the fairer
            // baseline the ROADMAP asked for).
            let batch_tps = fleet_prompt_tps(&ctx.cluster.perf, model, &batch_counts, primary);
            let est_drain = niw_pending as f64 / batch_tps;
            let deadline = Tier::Niw.deadline().unwrap_or(24.0 * 3600.0);
            if est_drain > self.chiron_theta * deadline {
                if ctx.scale_out_spot_then_cheapest(model, region, PoolTag::ChironBatch) {
                    ctx.touch_cooldown(model, region);
                }
            }
        }
    }
}

/// Σ_k counts_k × prompt-TPS(model, SKU_k): the fleet's aggregate
/// profiled throughput for a set of per-SKU instance counts.  Falls back
/// to one `fallback`-SKU instance when the set is empty (the pre-scaling
/// "at least one instance" convention).
fn fleet_prompt_tps(
    perf: &PerfTable,
    model: ModelKind,
    counts: &[usize; GpuKind::COUNT],
    fallback: GpuKind,
) -> f64 {
    let mut tps = 0.0;
    let mut total = 0usize;
    for k in 0..GpuKind::COUNT {
        if counts[k] > 0 {
            tps += counts[k] as f64 * perf.profile(model, GpuKind::from_index(k)).prompt_tps;
            total += counts[k];
        }
    }
    if total == 0 {
        perf.profile(model, fallback).prompt_tps
    } else {
        tps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::perf::PerfTable;

    fn setup(strategy: Strategy, per_endpoint: usize) -> (Cluster, Metrics, EventQueue, Autoscaler) {
        let params = ScalingParams::default();
        let pools = strategy.initial_pools(per_endpoint);
        let cluster = Cluster::new(
            &[ModelKind::Llama2_70B],
            PerfTable::new(GpuKind::A100x8, &[ModelKind::Llama2_70B]),
            params.clone(),
            &pools,
            20,
        );
        (cluster, Metrics::default(), EventQueue::new(), Autoscaler::new(strategy, params))
    }

    fn load_instances(cluster: &mut Cluster, frac: f64) {
        for id in 0..cluster.instances.len() {
            cluster.mutate(id, |inst| {
                inst.kv_used = (inst.kv_capacity as f64 * frac) as u64;
            });
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [Strategy::Siloed, Strategy::Reactive, Strategy::LtI, Strategy::LtU,
                  Strategy::LtUa, Strategy::Chiron] {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("nope"), None);
    }

    #[test]
    fn initial_pools_match_paper() {
        let siloed = Strategy::Siloed.initial_pools(20);
        assert_eq!(siloed, vec![(PoolTag::SiloIw, 16), (PoolTag::SiloNiw, 4)]);
        let chiron = Strategy::Chiron.initial_pools(20);
        assert_eq!(
            chiron,
            vec![(PoolTag::ChironInteractive, 10), (PoolTag::ChironMixed, 5), (PoolTag::ChironBatch, 5)]
        );
        assert_eq!(Strategy::LtUa.initial_pools(20), vec![(PoolTag::Unified, 20)]);
    }

    #[test]
    fn reactive_scales_out_above_threshold() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Reactive, 4);
        load_instances(&mut cluster, 0.9);
        let before = cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs);
        let mut ctx = ScaleCtx { now: 100.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), before + 1);
        assert_eq!(events.len(), 1); // ProvisionDone scheduled
    }

    #[test]
    fn reactive_scales_in_below_threshold() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Reactive, 4);
        load_instances(&mut cluster, 0.05);
        let mut ctx = ScaleCtx { now: 100.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        // The instance was idle, so it converted to spot immediately.
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 3);
        assert_eq!(cluster.spot_count(Region::EastUs), 1);
    }

    #[test]
    fn cooldown_blocks_rapid_scaling() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Reactive, 4);
        load_instances(&mut cluster, 0.9);
        let mut ctx = ScaleCtx { now: 100.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        let mut ctx = ScaleCtx { now: 105.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        // Second call inside the 15 s cooldown: no extra instance.
        assert_eq!(events.len(), 1);
    }

    #[test]
    fn siloed_scales_only_the_signalling_pool() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Siloed, 15);
        // Saturate only the NIW silo.
        for id in 0..cluster.instances.len() {
            if cluster.instances[id].pool == PoolTag::SiloNiw {
                cluster.mutate(id, |inst| {
                    inst.kv_used = (inst.kv_capacity as f64 * 0.95) as u64;
                });
            }
        }
        let mut ctx = ScaleCtx { now: 50.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::Niw);
        // But an IW request must not trigger anything (IW pool is idle).
        let mut ctx = ScaleCtx { now: 200.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_request(&mut ctx, ModelKind::Llama2_70B, Region::EastUs, Tier::IwF);
        // one scale_out from NIW, and the idle IW pool triggers scale_in
        let niw_pool: Vec<_> = cluster.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)]
            .instances
            .iter()
            .filter(|&&i| cluster.instances[i].pool == PoolTag::SiloNiw)
            .collect();
        assert_eq!(niw_pool.len(), 4); // 3 + 1 scaled out (15 → 12/3 split)
    }

    fn plan1(delta: i64, forecast_tps: f64) -> Vec<EpochPlanEntry> {
        vec![EpochPlanEntry {
            model: ModelKind::Llama2_70B,
            region: Region::EastUs,
            deltas: vec![delta],
            forecast_tps,
        }]
    }

    #[test]
    fn lt_i_applies_delta_immediately() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::LtI, 4);
        let mut ctx = ScaleCtx { now: 3600.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_epoch(&mut ctx, &plan1(3, 1000.0));
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 7);
    }

    #[test]
    fn lt_u_defers_until_util_breach() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::LtU, 4);
        let mut ctx = ScaleCtx { now: 3600.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_epoch(&mut ctx, &plan1(3, 1000.0));
        // Target armed but nothing applied yet.
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 4);
        // Low util tick: still nothing.
        let obs = BTreeMap::new();
        let mut ctx = ScaleCtx { now: 3700.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_tick(&mut ctx, &obs, 100.0);
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 4);
        // Util breach: one step toward the target per tick+cooldown.
        load_instances(&mut cluster, 0.9);
        let mut ctx = ScaleCtx { now: 3800.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_tick(&mut ctx, &obs, 200.0);
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 5);
    }

    #[test]
    fn lt_ua_overrides_on_forecast_gap() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::LtUa, 4);
        let mut ctx = ScaleCtx { now: 3600.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_epoch(&mut ctx, &plan1(0, 100.0));
        // Observed TPS 8× the forecast, inside the last-20-min window, at
        // target count ⇒ scale out beyond the target.
        let mut obs = BTreeMap::new();
        obs.insert((ModelKind::Llama2_70B, Region::EastUs), 800.0);
        let mut ctx = ScaleCtx { now: 7000.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_tick(&mut ctx, &obs, 3000.0); // elapsed 3000 ≥ 3600-1200
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 5);
    }

    #[test]
    fn lt_u_does_not_override_on_gap() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::LtU, 4);
        let mut ctx = ScaleCtx { now: 3600.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_epoch(&mut ctx, &plan1(0, 100.0));
        let mut obs = BTreeMap::new();
        obs.insert((ModelKind::Llama2_70B, Region::EastUs), 800.0);
        let mut ctx = ScaleCtx { now: 7000.0, cluster: &mut cluster, metrics: &mut metrics, events: &mut events, reroutes: Vec::new(), act_drop: false, act_extra_lead: 0.0 };
        scaler.on_tick(&mut ctx, &obs, 3000.0);
        assert_eq!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs), 4);
    }

    #[test]
    fn stalled_drain_sweep_converts_and_reroutes() {
        use crate::sim::instance::InstState;
        let (mut cluster, mut metrics, mut events, _scaler) = setup(Strategy::Reactive, 4);
        // Manufacture the documented footgun: a Draining instance with an
        // empty batch, no chunk in flight, and a stranded waiting request
        // — nothing on the healthy path would ever finish_drain it.
        let id = 0;
        let region = cluster.instances[id].region;
        cluster.push_waiting(id, crate::trace::types::Request {
            id: 7,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: region,
            tier: Tier::IwF,
            app: crate::trace::types::AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        });
        cluster.mutate(id, |inst| inst.state = InstState::Draining);
        let before_spot = cluster.spot_count(region);
        let mut ctx = ScaleCtx {
            now: 100.0,
            cluster: &mut cluster,
            metrics: &mut metrics,
            events: &mut events,
            reroutes: Vec::new(),
            act_drop: false,
            act_extra_lead: 0.0,
        };
        let swept = ctx.sweep_stalled_drains();
        assert_eq!(swept, 1, "the stalled drain must be converted");
        assert_eq!(ctx.reroutes.len(), 1, "the stranded request must be rerouted");
        assert_eq!(ctx.reroutes[0].id, 7);
        assert_eq!(cluster.spot_count(region), before_spot + 1);
        assert_eq!(cluster.instances[id].state, InstState::Spot);
        assert!(cluster.aggregates_consistent());
        // Idempotent: a second sweep finds nothing.
        let mut ctx = ScaleCtx {
            now: 115.0,
            cluster: &mut cluster,
            metrics: &mut metrics,
            events: &mut events,
            reroutes: Vec::new(),
            act_drop: false,
            act_extra_lead: 0.0,
        };
        assert_eq!(ctx.sweep_stalled_drains(), 0);
    }

    #[test]
    fn chiron_scales_on_backpressure() {
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Chiron, 12);
        // Pile pending tokens on interactive instances.
        for id in 0..cluster.instances.len() {
            if cluster.instances[id].pool == PoolTag::ChironInteractive {
                cluster.push_waiting(id, crate::trace::types::Request {
                    id: 1,
                    arrival: 0.0,
                    model: ModelKind::Llama2_70B,
                    origin: Region::EastUs,
                    tier: Tier::IwF,
                    app: crate::trace::types::AppKind::Chat,
                    input_tokens: 4_000_000,
                    output_tokens: 1000,
                });
            }
        }
        let obs = BTreeMap::new();
        // Several ticks to build smoothed pressure past Θ.
        for k in 0..5 {
            let mut ctx = ScaleCtx {
                now: 100.0 + 20.0 * k as f64,
                cluster: &mut cluster,
                metrics: &mut metrics,
                events: &mut events,
                reroutes: Vec::new(),
            };
            scaler.on_tick(&mut ctx, &obs, 0.0);
        }
        assert!(cluster.allocated_count(ModelKind::Llama2_70B, Region::EastUs) > 12);
    }

    #[test]
    fn chiron_batch_pool_scales_on_deadline_pressure() {
        // 12/endpoint chiron split: 6 interactive / 3 mixed / 3 batch.
        let (mut cluster, mut metrics, mut events, mut scaler) = setup(Strategy::Chiron, 12);
        // Pile an NIW backlog on the batch pool that would take far more
        // than Θ×24 h to drain at the profiled throughput (~70 k prompt
        // TPS across the 6 NIW-serving instances ⇒ threshold ≈ 3.6 G
        // tokens).
        for id in 0..cluster.instances.len() {
            if cluster.instances[id].pool == PoolTag::ChironBatch
                && cluster.instances[id].region == Region::EastUs
                && cluster.instances[id].model == ModelKind::Llama2_70B
            {
                for n in 0..20 {
                    cluster.push_waiting(id, crate::trace::types::Request {
                        id: n,
                        arrival: 0.0,
                        model: ModelKind::Llama2_70B,
                        origin: Region::EastUs,
                        tier: Tier::Niw,
                        app: crate::trace::types::AppKind::DocSummary,
                        input_tokens: 500_000_000,
                        output_tokens: 1000,
                    });
                }
            }
        }
        let before_batch = cluster.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)]
            .agg[PoolTag::ChironBatch.index()]
            .count;
        let obs = BTreeMap::new();
        let mut ctx = ScaleCtx {
            now: 100.0,
            cluster: &mut cluster,
            metrics: &mut metrics,
            events: &mut events,
            reroutes: Vec::new(),
            act_drop: false,
            act_extra_lead: 0.0,
        };
        scaler.on_tick(&mut ctx, &obs, 0.0);
        // A fresh instance lands in Provisioning, so count it via the
        // roster: one more ChironBatch instance allocated.
        let after_batch = cluster.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)]
            .instances
            .iter()
            .filter(|&&i| cluster.instances[i].pool == PoolTag::ChironBatch)
            .count();
        assert_eq!(after_batch, before_batch + 1, "deadline pressure must grow the batch pool");
        assert!(cluster.aggregates_consistent());
    }
}
