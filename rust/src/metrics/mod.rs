//! Metrics: latency recorders, SLA accounting, instance-hour ledgers and
//! the scaling-waste ledger — everything the evaluation figures consume.
//!
//! Heterogeneous-fleet cost accounting splits on-demand spend from
//! spot-market value per SKU: allocated hours are priced at α_k
//! ([`Metrics::fleet_dollar_cost`]), donated hours earn the per-SKU
//! [`crate::config::SpotMarket`] curve ([`Metrics::spot_revenue`]), and
//! [`Metrics::net_fleet_cost`] is the difference — the number the
//! `exp hetero` ablation compares fleets and routing policies on.

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

use std::collections::BTreeMap;

use crate::config::{GpuKind, ModelKind, Region, SpotMarket, Tier, Time, HOUR};
use crate::trace::types::Request;

/// Per-request outcome recorded at completion.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestOutcome {
    pub tier: Tier,
    pub model: ModelKind,
    pub region: Region,
    /// Time to first token, seconds.
    pub ttft: Time,
    /// End-to-end latency, seconds.
    pub e2e: Time,
    pub arrival: Time,
    pub input_tokens: u32,
    pub output_tokens: u32,
    /// True if the TTFT SLA (IW) or deadline (NIW) was met.
    pub sla_met: bool,
}

/// Percentile over a non-empty f64 slice (nearest-rank).  Uses quickselect
/// (`select_nth_unstable_by`) instead of a full sort — O(n) per call, and
/// each call re-selects so repeated percentiles over the same buffer stay
/// correct regardless of the partial reorderings earlier calls left.
pub fn percentile(values: &mut [f64], p: f64) -> f64 {
    assert!(!values.is_empty(), "percentile of empty slice");
    let rank = ((p / 100.0) * (values.len() - 1) as f64).round() as usize;
    let rank = rank.min(values.len() - 1);
    let (_, v, _) = values.select_nth_unstable_by(rank, |a, b| a.partial_cmp(b).unwrap());
    *v
}

/// Latency statistics for a set of outcomes.
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub ttft_p50: f64,
    pub ttft_p75: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p75: f64,
    pub e2e_p95: f64,
    pub mean_ttft: f64,
    pub mean_e2e: f64,
    pub sla_violation_rate: f64,
}

impl LatencySummary {
    pub fn from_outcomes<'a>(outcomes: impl Iterator<Item = &'a RequestOutcome>) -> Self {
        let mut ttft = Vec::new();
        let mut e2e = Vec::new();
        let mut violations = 0usize;
        for o in outcomes {
            ttft.push(o.ttft);
            e2e.push(o.e2e);
            if !o.sla_met {
                violations += 1;
            }
        }
        Self::from_parts(ttft, e2e, violations)
    }

    /// Summarize pre-collected latency vectors (the grouped single-pass
    /// paths hand these over without re-scanning outcomes).
    pub fn from_parts(mut ttft: Vec<f64>, mut e2e: Vec<f64>, violations: usize) -> Self {
        if ttft.is_empty() {
            return LatencySummary::default();
        }
        let count = ttft.len();
        let mean_ttft = ttft.iter().sum::<f64>() / count as f64;
        let mean_e2e = e2e.iter().sum::<f64>() / count as f64;
        LatencySummary {
            count,
            ttft_p50: percentile(&mut ttft, 50.0),
            ttft_p75: percentile(&mut ttft, 75.0),
            ttft_p95: percentile(&mut ttft, 95.0),
            ttft_p99: percentile(&mut ttft, 99.0),
            e2e_p50: percentile(&mut e2e, 50.0),
            e2e_p75: percentile(&mut e2e, 75.0),
            e2e_p95: percentile(&mut e2e, 95.0),
            mean_ttft,
            mean_e2e,
            sla_violation_rate: violations as f64 / count as f64,
        }
    }
}

/// Step-function integrator: instance count over time → instance-hours
/// (the area-under-curve metric of Fig 8/11).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct InstanceHourLedger {
    /// (time, count) change points, time-ordered.
    pub points: Vec<(Time, usize)>,
}

impl InstanceHourLedger {
    pub fn record(&mut self, t: Time, count: usize) {
        if let Some(&(lt, lc)) = self.points.last() {
            debug_assert!(t >= lt, "ledger time went backwards");
            if lc == count {
                return;
            }
        }
        self.points.push((t, count));
    }

    /// Integrated instance-hours over [0, end].
    pub fn instance_hours(&self, end: Time) -> f64 {
        let mut total = 0.0;
        for w in self.points.windows(2) {
            let (t0, c) = w[0];
            let (t1, _) = w[1];
            total += c as f64 * (t1.min(end) - t0.min(end));
        }
        if let Some(&(t, c)) = self.points.last() {
            if t < end {
                total += c as f64 * (end - t);
            }
        }
        total / HOUR
    }

    /// Count in effect at time `t`.
    pub fn count_at(&self, t: Time) -> usize {
        match self.points.iter().rev().find(|&&(pt, _)| pt <= t) {
            Some(&(_, c)) => c,
            None => 0,
        }
    }

    /// Sample the step function at fixed intervals (for plotting).
    pub fn sample(&self, end: Time, step: Time) -> Vec<(Time, usize)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= end {
            out.push((t, self.count_at(t)));
            t += step;
        }
        out
    }

    /// Integrate `count × rate(t)` over `[0, end]` where `rate` is $/h
    /// and *hour-constant* (the [`SpotMarket`] curve's contract):
    /// segments split at wall-clock hour boundaries, so the integral is
    /// exact.  Returns dollars.
    pub fn dollars(&self, end: Time, rate: impl Fn(Time) -> f64) -> f64 {
        let mut total = 0.0;
        let mut add = |t0: Time, t1: Time, count: usize| {
            if count == 0 || t1 <= t0 {
                return;
            }
            let mut t = t0;
            while t < t1 {
                let next_hour = ((t / HOUR).floor() + 1.0) * HOUR;
                let seg_end = next_hour.min(t1);
                total += count as f64 * rate(t) * (seg_end - t) / HOUR;
                t = seg_end;
            }
        };
        for w in self.points.windows(2) {
            add(w[0].0.min(end), w[1].0.min(end), w[0].1);
        }
        if let Some(&(t, c)) = self.points.last() {
            if t < end {
                add(t, end, c);
            }
        }
        total
    }
}

/// GPU-hours wasted on scaling: time VMs spend provisioning, by cause
/// (Fig 13b's ledger).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScalingWasteLedger {
    /// cause → (events, wasted seconds).
    pub by_cause: BTreeMap<String, (u64, Time)>,
}

impl ScalingWasteLedger {
    pub fn record(&mut self, cause: &str, wasted_secs: Time) {
        let e = self.by_cause.entry(cause.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += wasted_secs;
    }

    pub fn total_gpu_hours(&self) -> f64 {
        self.by_cause.values().map(|&(_, s)| s).sum::<f64>() / HOUR
    }

    pub fn total_events(&self) -> u64 {
        self.by_cause.values().map(|&(n, _)| n).sum()
    }
}

/// Top-level metrics container for one simulation run.  `PartialEq` backs
/// the parallel-sweep equivalence test: two runs are "identical" iff every
/// outcome, ledger point and sample matches exactly.
#[derive(Debug, Default, PartialEq)]
pub struct Metrics {
    pub outcomes: Vec<RequestOutcome>,
    /// (model, region) → active-instance ledger.
    pub instances: BTreeMap<(ModelKind, Region), InstanceHourLedger>,
    /// (model, region, GPU SKU) → allocated-instance ledger: the per-SKU
    /// GPU-hour and dollar-cost attribution for heterogeneous fleets
    /// (recorded at the same change points as `instances`).
    pub instances_by_gpu: BTreeMap<(ModelKind, Region, GpuKind), InstanceHourLedger>,
    /// (model, region, GPU SKU) → spot-donated-instance ledger: the
    /// single source of truth for donated capacity — totals
    /// ([`Metrics::spot_hours`]) and the spot-market revenue integration
    /// both derive from it.
    pub spot_instances_by_gpu: BTreeMap<(ModelKind, Region, GpuKind), InstanceHourLedger>,
    pub scaling_waste: ScalingWasteLedger,
    /// Effective memory-utilization samples: (time, model, region, util).
    pub util_samples: Vec<(Time, ModelKind, Region, f64)>,
    /// Dropped/unserved requests (should stay 0 in healthy runs).
    pub dropped: u64,
}

impl Metrics {
    pub fn record_outcome(&mut self, req: &Request, region: Region, ttft: Time, e2e: Time) {
        let sla_met = match req.tier.ttft_sla() {
            Some(sla) => ttft <= sla,
            None => match req.deadline() {
                Some(d) => req.arrival + e2e <= d,
                None => true,
            },
        };
        self.outcomes.push(RequestOutcome {
            tier: req.tier,
            model: req.model,
            region,
            ttft,
            e2e,
            arrival: req.arrival,
            input_tokens: req.input_tokens,
            output_tokens: req.output_tokens,
            sla_met,
        });
    }

    pub fn latency_by_tier(&self, tier: Tier) -> LatencySummary {
        LatencySummary::from_outcomes(self.outcomes.iter().filter(|o| o.tier == tier))
    }

    pub fn latency_by_model(&self, model: ModelKind) -> LatencySummary {
        LatencySummary::from_outcomes(self.outcomes.iter().filter(|o| o.model == model))
    }

    pub fn latency_by_model_tier(&self, model: ModelKind, tier: Tier) -> LatencySummary {
        LatencySummary::from_outcomes(
            self.outcomes.iter().filter(|o| o.model == model && o.tier == tier),
        )
    }

    /// Every (model, tier) latency summary in ONE pass over the outcomes.
    /// The per-cell `latency_by_model_tier` filter re-scans the full
    /// outcome list for each cell — quadratic across a report table; this
    /// groups first, then summarizes each bucket.
    pub fn latency_by_model_tier_all(&self) -> BTreeMap<(ModelKind, Tier), LatencySummary> {
        let mut groups: BTreeMap<(ModelKind, Tier), (Vec<f64>, Vec<f64>, usize)> =
            BTreeMap::new();
        for o in &self.outcomes {
            let g = groups.entry((o.model, o.tier)).or_default();
            g.0.push(o.ttft);
            g.1.push(o.e2e);
            if !o.sla_met {
                g.2 += 1;
            }
        }
        groups
            .into_iter()
            .map(|(k, (ttft, e2e, v))| (k, LatencySummary::from_parts(ttft, e2e, v)))
            .collect()
    }

    /// Interactive-traffic latency summaries per model, single grouping
    /// pass (the experiment tables' common cell shape).
    pub fn interactive_latency_by_model(&self) -> BTreeMap<ModelKind, LatencySummary> {
        let mut groups: BTreeMap<ModelKind, (Vec<f64>, Vec<f64>, usize)> = BTreeMap::new();
        for o in &self.outcomes {
            if !o.tier.is_interactive() {
                continue;
            }
            let g = groups.entry(o.model).or_default();
            g.0.push(o.ttft);
            g.1.push(o.e2e);
            if !o.sla_met {
                g.2 += 1;
            }
        }
        groups
            .into_iter()
            .map(|(k, (ttft, e2e, v))| (k, LatencySummary::from_parts(ttft, e2e, v)))
            .collect()
    }

    /// Interactive-traffic latency summaries for one model in fixed
    /// arrival-time bins over `[0, end)` — ONE pass over the outcomes
    /// (the `week`/`burst` figures used to re-scan every outcome per
    /// bin).  Returns one summary per bin, index `i` covering arrivals
    /// in `[i*bin, (i+1)*bin)`; empty bins yield a default summary with
    /// `count == 0`.
    pub fn interactive_latency_bins(
        &self,
        model: ModelKind,
        bin: Time,
        end: Time,
    ) -> Vec<LatencySummary> {
        let n_bins = (end / bin).ceil().max(0.0) as usize;
        if n_bins == 0 {
            return Vec::new();
        }
        let mut groups: Vec<(Vec<f64>, Vec<f64>, usize)> = vec![Default::default(); n_bins];
        for o in &self.outcomes {
            if o.model != model || !o.tier.is_interactive() {
                continue;
            }
            let b = (o.arrival / bin) as usize;
            if b >= n_bins {
                continue; // arrival past the last bin edge
            }
            let g = &mut groups[b];
            g.0.push(o.ttft);
            g.1.push(o.e2e);
            if !o.sla_met {
                g.2 += 1;
            }
        }
        groups
            .into_iter()
            .map(|(ttft, e2e, v)| LatencySummary::from_parts(ttft, e2e, v))
            .collect()
    }

    /// Total instance-hours for a model across regions.
    pub fn model_instance_hours(&self, model: ModelKind, end: Time) -> f64 {
        self.instances
            .iter()
            .filter(|((m, _), _)| *m == model)
            .map(|(_, l)| l.instance_hours(end))
            .sum()
    }

    /// Total spot-donated instance-hours (derived from the per-SKU
    /// ledgers — every spot VM is a fleet SKU, so the split is total).
    pub fn spot_hours(&self, end: Time) -> f64 {
        self.spot_instances_by_gpu.values().map(|l| l.instance_hours(end)).sum()
    }

    /// GPU-hours (instance-hours) per SKU across all models and regions.
    pub fn gpu_hours_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.instances_by_gpu {
            *out.entry(*gpu).or_insert(0.0) += ledger.instance_hours(end);
        }
        out
    }

    /// Total fleet dollar cost: per-SKU GPU-hours × the SKU's on-demand
    /// $/h (α_k) — the §7.2.1 cost metric generalized to mixed fleets.
    pub fn fleet_dollar_cost(&self, end: Time) -> f64 {
        self.gpu_hours_by_sku(end)
            .iter()
            .map(|(gpu, hours)| gpu.dollars_per_hour() * hours)
            .sum()
    }

    /// On-demand dollar cost split per SKU (hours × α_k) — one half of
    /// the spot-vs-on-demand breakdown.
    pub fn fleet_dollar_cost_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        self.gpu_hours_by_sku(end)
            .into_iter()
            .map(|(gpu, hours)| (gpu, gpu.dollars_per_hour() * hours))
            .collect()
    }

    /// Spot-donated GPU-hours per SKU across all models and regions.
    pub fn spot_hours_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.spot_instances_by_gpu {
            *out.entry(*gpu).or_insert(0.0) += ledger.instance_hours(end);
        }
        out
    }

    /// Spot-market revenue per SKU: donated hours priced along the
    /// diurnal [`SpotMarket`] curve (exact — the curve is hour-constant
    /// and the ledger integration splits at hour boundaries).
    pub fn spot_revenue_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.spot_instances_by_gpu {
            let g = *gpu;
            *out.entry(g).or_insert(0.0) += ledger.dollars(end, |t| SpotMarket::price(g, t));
        }
        out
    }

    /// Total spot-market revenue over `[0, end]` — what the donated pool
    /// earns back at per-SKU spot prices.
    pub fn spot_revenue(&self, end: Time) -> f64 {
        self.spot_revenue_by_sku(end).values().sum()
    }

    /// Net fleet cost: on-demand spend minus spot-market revenue — the
    /// heterogeneous-fleet headline metric (`exp hetero`).
    pub fn net_fleet_cost(&self, end: Time) -> f64 {
        self.fleet_dollar_cost(end) - self.spot_revenue(end)
    }

    /// Mean effective memory utilization for a model across samples.
    pub fn mean_util(&self, model: ModelKind) -> f64 {
        let vals: Vec<f64> = self
            .util_samples
            .iter()
            .filter(|(_, m, _, _)| *m == model)
            .map(|&(_, _, _, u)| u)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&mut v, 50.0), 3.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 100.0), 5.0);
    }

    #[test]
    fn ledger_integrates_steps() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(3600.0, 4);
        l.record(7200.0, 0);
        // 2 inst × 1 h + 4 inst × 1 h = 6 instance-hours.
        assert!((l.instance_hours(7200.0) - 6.0).abs() < 1e-9);
        // Trailing segment extends to `end`.
        l.record(7200.0, 1);
        assert!((l.instance_hours(10_800.0) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_count_at() {
        let mut l = InstanceHourLedger::default();
        l.record(10.0, 3);
        l.record(20.0, 5);
        assert_eq!(l.count_at(5.0), 0);
        assert_eq!(l.count_at(15.0), 3);
        assert_eq!(l.count_at(25.0), 5);
    }

    #[test]
    fn ledger_dedups_equal_counts() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(10.0, 2);
        assert_eq!(l.points.len(), 1);
    }

    #[test]
    fn sla_accounting() {
        use crate::trace::types::AppKind;
        let mut m = Metrics::default();
        let req = Request {
            id: 0,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        };
        m.record_outcome(&req, Region::EastUs, 0.5, 2.0); // meets 1s TTFT
        m.record_outcome(&req, Region::EastUs, 1.5, 3.0); // violates
        let s = m.latency_by_tier(Tier::IwF);
        assert_eq!(s.count, 2);
        assert!((s.sla_violation_rate - 0.5).abs() < 1e-9);
    }

    #[test]
    fn grouped_summaries_match_filtered() {
        use crate::trace::types::AppKind;
        let mut m = Metrics::default();
        for i in 0..40u64 {
            let req = Request {
                id: i,
                arrival: i as f64,
                model: if i % 2 == 0 { ModelKind::Llama2_70B } else { ModelKind::Bloom176B },
                origin: Region::EastUs,
                tier: if i % 3 == 0 { Tier::Niw } else { Tier::IwF },
                app: AppKind::Chat,
                input_tokens: 100,
                output_tokens: 10,
            };
            m.record_outcome(&req, Region::EastUs, 0.1 + i as f64 * 0.07, 2.0 + i as f64);
        }
        let grouped = m.latency_by_model_tier_all();
        for (&(model, tier), s) in &grouped {
            let filtered = m.latency_by_model_tier(model, tier);
            assert_eq!(s.count, filtered.count);
            assert_eq!(s.ttft_p95, filtered.ttft_p95, "{model} {tier}");
            assert_eq!(s.e2e_p50, filtered.e2e_p50, "{model} {tier}");
            assert_eq!(s.sla_violation_rate, filtered.sla_violation_rate);
        }
        let iw = m.interactive_latency_by_model();
        for (&model, s) in &iw {
            let filtered = LatencySummary::from_outcomes(
                m.outcomes.iter().filter(|o| o.model == model && o.tier.is_interactive()),
            );
            assert_eq!(s.count, filtered.count);
            assert_eq!(s.ttft_p75, filtered.ttft_p75);
        }
    }

    #[test]
    fn binned_summaries_match_filtered_windows() {
        use crate::trace::types::AppKind;
        let mut m = Metrics::default();
        for i in 0..200u64 {
            let req = Request {
                id: i,
                arrival: i as f64 * 7.3,
                model: if i % 2 == 0 { ModelKind::Llama2_70B } else { ModelKind::Bloom176B },
                origin: Region::EastUs,
                tier: if i % 5 == 0 { Tier::Niw } else { Tier::IwF },
                app: AppKind::Chat,
                input_tokens: 100,
                output_tokens: 10,
            };
            m.record_outcome(&req, Region::EastUs, 0.1 + (i % 13) as f64 * 0.2, 3.0 + i as f64);
        }
        let (bin, end) = (300.0, 200.0 * 7.3);
        let bins = m.interactive_latency_bins(ModelKind::Llama2_70B, bin, end);
        assert_eq!(bins.len(), (end / bin).ceil() as usize);
        for (i, s) in bins.iter().enumerate() {
            let t = i as f64 * bin;
            let window = LatencySummary::from_outcomes(m.outcomes.iter().filter(|o| {
                o.model == ModelKind::Llama2_70B
                    && o.tier.is_interactive()
                    && o.arrival >= t
                    && o.arrival < t + bin
            }));
            assert_eq!(s.count, window.count, "bin {i}");
            assert_eq!(s.ttft_p95, window.ttft_p95, "bin {i}");
            assert_eq!(s.e2e_p95, window.e2e_p95, "bin {i}");
            assert_eq!(s.sla_violation_rate, window.sla_violation_rate, "bin {i}");
        }
    }

    #[test]
    fn per_sku_hours_and_dollar_cost() {
        let mut m = Metrics::default();
        m.instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::H100x8))
            .or_default()
            .record(0.0, 2);
        m.instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::A100x8))
            .or_default()
            .record(0.0, 4);
        let by_sku = m.gpu_hours_by_sku(HOUR);
        assert!((by_sku[&GpuKind::H100x8] - 2.0).abs() < 1e-9);
        assert!((by_sku[&GpuKind::A100x8] - 4.0).abs() < 1e-9);
        let cost = m.fleet_dollar_cost(HOUR);
        let want = 2.0 * GpuKind::H100x8.dollars_per_hour() + 4.0 * GpuKind::A100x8.dollars_per_hour();
        assert!((cost - want).abs() < 1e-9);
    }

    #[test]
    fn ledger_dollars_integrates_hour_constant_rates() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(2.0 * HOUR, 0);
        // Constant $10/h: 2 instances × 2 h = $40.
        assert!((l.dollars(3.0 * HOUR, |_| 10.0) - 40.0).abs() < 1e-9);
        // Rate that doubles after the first hour: 2×10 + 2×20 = $60,
        // even when the segment spans the boundary.
        let stepped = |t: Time| if t < HOUR { 10.0 } else { 20.0 };
        assert!((l.dollars(3.0 * HOUR, stepped) - 60.0).abs() < 1e-9);
        // Truncation at `end` mid-segment.
        assert!((l.dollars(0.5 * HOUR, |_| 10.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn spot_revenue_prices_donated_hours_per_sku() {
        use crate::config::SpotMarket;
        let mut m = Metrics::default();
        // One H100 donated for the first two (off-peak) hours of the day.
        let led = m
            .spot_instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::H100x8))
            .or_default();
        led.record(0.0, 1);
        led.record(2.0 * HOUR, 0);
        // One A100 donated across the 08:00→10:00 off-peak/peak edge.
        let led = m
            .spot_instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::WestUs, GpuKind::A100x8))
            .or_default();
        led.record(8.0 * HOUR, 1);
        led.record(10.0 * HOUR, 0);
        let end = 24.0 * HOUR;
        let by_sku = m.spot_revenue_by_sku(end);
        let h100 = 2.0 * GpuKind::H100x8.spot_dollars_per_hour() * SpotMarket::OFF_PEAK;
        let a100 = GpuKind::A100x8.spot_dollars_per_hour()
            * (SpotMarket::OFF_PEAK + SpotMarket::PEAK);
        assert!((by_sku[&GpuKind::H100x8] - h100).abs() < 1e-9);
        assert!((by_sku[&GpuKind::A100x8] - a100).abs() < 1e-9);
        assert!((m.spot_revenue(end) - h100 - a100).abs() < 1e-9);
        // Net cost = on-demand − spot revenue (no allocated hours here).
        assert!((m.net_fleet_cost(end) + h100 + a100).abs() < 1e-9);
    }

    #[test]
    fn waste_ledger_totals() {
        let mut w = ScalingWasteLedger::default();
        w.record("vm-provision", 600.0);
        w.record("vm-provision", 600.0);
        w.record("spot-reclaim", 60.0);
        assert_eq!(w.total_events(), 3);
        assert!((w.total_gpu_hours() - 1260.0 / 3600.0).abs() < 1e-9);
    }
}
