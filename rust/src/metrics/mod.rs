//! Metrics: streaming latency/SLA accounting, instance-hour ledgers and
//! the scaling-waste ledger — everything the evaluation figures consume.
//!
//! # Streaming core (O(bins), not O(requests))
//!
//! The engine records every completion into a set of **mergeable
//! accumulators** instead of a per-request outcome log: per
//! (model, tier, region) whole-run cells ([`GroupCell`]) and per
//! (model, region, arrival-time-bin) cells ([`BinCell`]), each carrying
//! counts, SLA violations, latency sums and fixed-layout log-bucketed
//! [`LatencyHistogram`]s for TTFT/E2E percentiles.  Peak memory is
//! proportional to the number of *bins*, not the number of requests, so
//! paper-scale sweeps (`--scale 1.0`, ≈10 M req/day) are bounded by
//! cores, not RAM — see PERF.md "Streaming metrics memory model".
//!
//! Summary extraction ([`LatencySummary`]) folds cells on the stack —
//! no `Vec<f64>` collection or re-sorting per report group — and
//! percentiles come from the histograms (≤ ~3.7 % relative error; the
//! error bound is asserted by the histogram tests).
//!
//! [`Metrics::merge`] combines shards: histogram/count merges are exact,
//! and shards that partition completions by (model, region) — e.g. a
//! region-sharded replay — merge **bit-identically** to one sequential
//! accumulation (`tests/metrics_streaming.rs`).
//!
//! # Exact mode
//!
//! [`MetricsMode::Exact`] additionally keeps the classic per-request
//! [`RequestOutcome`] log for fidelity tests and fig-level plots that
//! need exact percentiles or raw outcome streams
//! (`simulate --metrics exact`).  Streaming accumulators are maintained
//! in both modes, so every summary API works identically.
//!
//! # Cost accounting
//!
//! Heterogeneous-fleet cost accounting splits on-demand spend from
//! spot-market value per SKU: allocated hours are priced at α_k
//! ([`Metrics::fleet_dollar_cost`]), donated hours earn the per-SKU
//! [`crate::config::SpotMarket`] curve ([`Metrics::spot_revenue`]), and
//! [`Metrics::net_fleet_cost`] is the difference — the number the
//! `exp hetero` ablation compares fleets and routing policies on.

mod hist;

pub use hist::{bucket_of, LatencyHistogram, BUCKETS};

use std::collections::BTreeMap;

use crate::config::{GpuKind, ModelKind, Region, SpotMarket, Tier, Time, HOUR};
use crate::trace::types::Request;

/// Number of model slots in the dense accumulator grids.
const MODELS: usize = ModelKind::ALL.len();
/// Number of tier slots.
const TIERS: usize = Tier::ALL.len();
/// Number of region slots.
const REGIONS: usize = Region::ALL.len();
/// Whole-run cell count: one [`GroupCell`] per (model, tier, region).
const CELLS: usize = MODELS * TIERS * REGIONS;

/// Per-request outcome recorded at completion ([`MetricsMode::Exact`]
/// only — the streaming accumulators carry everything the reports need).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestOutcome {
    /// SLA tier the request arrived under.
    pub tier: Tier,
    /// Model the request targeted.
    pub model: ModelKind,
    /// Region that actually served the request.
    pub region: Region,
    /// Time to first token, seconds.
    pub ttft: Time,
    /// End-to-end latency, seconds.
    pub e2e: Time,
    /// Mean inter-token latency over the streamed decode, seconds:
    /// `(e2e − ttft) / max(1, output_tokens − 1)`.  Zero-gap requests
    /// (single-token outputs) report 0.
    pub itl: Time,
    /// Arrival time, seconds since simulation start.
    pub arrival: Time,
    /// Prompt length, tokens.
    pub input_tokens: u32,
    /// Generated length, tokens.
    pub output_tokens: u32,
    /// True if the TTFT SLA (IW) or deadline (NIW) was met.
    pub sla_met: bool,
}

/// Percentile over a non-empty f64 slice (nearest-rank).  Uses quickselect
/// (`select_nth_unstable_by`) instead of a full sort — O(n) per call, and
/// each call re-selects so repeated percentiles over the same buffer stay
/// correct regardless of the partial reorderings earlier calls left.
pub fn percentile(values: &mut [f64], p: f64) -> f64 {
    assert!(!values.is_empty(), "percentile of empty slice");
    let rank = ((p / 100.0) * (values.len() - 1) as f64).round() as usize;
    let rank = rank.min(values.len() - 1);
    let (_, v, _) = values.select_nth_unstable_by(rank, |a, b| a.partial_cmp(b).unwrap());
    *v
}

/// How [`Metrics`] stores per-request information.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Streaming accumulators only — O(bins) memory, the sweep default.
    /// Percentiles are histogram-derived (≤ ~3.7 % relative error).
    #[default]
    Streaming,
    /// Streaming accumulators **plus** the full [`RequestOutcome`] log —
    /// O(requests) memory, for fidelity tests and fig-level plots.
    Exact,
}

/// Construction parameters for [`Metrics`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsConfig {
    /// Streaming-only or streaming + exact outcome log.
    pub mode: MetricsMode,
    /// Width of the arrival-time bins (and utilization bins), seconds.
    /// Report-time bins ([`Metrics::interactive_latency_bins`]) must be
    /// an integer multiple of this.
    pub bin: Time,
}

impl Default for MetricsConfig {
    fn default() -> Self {
        // 15-minute bins: divides every report cadence in the suite
        // (hourly fig16a windows, 3 h fig16b windows) and matches the
        // engine's utilization sampling period.
        MetricsConfig { mode: MetricsMode::Streaming, bin: 900.0 }
    }
}

/// Whole-run streaming accumulator for one (model, tier, region) group:
/// everything a [`LatencySummary`] needs, in O(1)-per-request updates.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupCell {
    /// Completions recorded into this group.
    pub count: u64,
    /// Completions that missed their SLA/deadline.
    pub violations: u64,
    /// Sum of TTFTs, seconds (mean numerator).
    pub sum_ttft: f64,
    /// Sum of end-to-end latencies, seconds.
    pub sum_e2e: f64,
    /// Sum of per-request mean inter-token latencies, seconds.
    pub sum_itl: f64,
    /// TTFT distribution.
    pub ttft: LatencyHistogram,
    /// End-to-end latency distribution.
    pub e2e: LatencyHistogram,
    /// Inter-token latency distribution (per-request decode-stream
    /// means) — the streaming-SLO axis disaggregated decode sizing is
    /// gated on.
    pub itl: LatencyHistogram,
}

impl GroupCell {
    fn merge(&mut self, other: &GroupCell) {
        self.count += other.count;
        self.violations += other.violations;
        self.sum_ttft += other.sum_ttft;
        self.sum_e2e += other.sum_e2e;
        self.sum_itl += other.sum_itl;
        self.ttft.merge(&other.ttft);
        self.e2e.merge(&other.e2e);
        self.itl.merge(&other.itl);
    }
}

/// Streaming accumulator for one (model, region, arrival-time-bin):
/// per-tier scalar stats plus interactive-only latency histograms (the
/// binned-percentile consumers — `fig16a`/`fig16b` — are IW-only).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BinCell {
    /// Completions per tier (indexed by [`Tier::index`]).
    pub count: [u64; TIERS],
    /// SLA/deadline misses per tier.
    pub violations: [u64; TIERS],
    /// Sum of TTFTs per tier, seconds.
    pub sum_ttft: [f64; TIERS],
    /// Sum of end-to-end latencies per tier, seconds.
    pub sum_e2e: [f64; TIERS],
    /// Interactive-traffic TTFT distribution.
    pub iw_ttft: LatencyHistogram,
    /// Interactive-traffic end-to-end latency distribution.
    pub iw_e2e: LatencyHistogram,
}

impl BinCell {
    fn merge(&mut self, other: &BinCell) {
        for t in 0..TIERS {
            self.count[t] += other.count[t];
            self.violations[t] += other.violations[t];
            self.sum_ttft[t] += other.sum_ttft[t];
            self.sum_e2e[t] += other.sum_e2e[t];
        }
        self.iw_ttft.merge(&other.iw_ttft);
        self.iw_e2e.merge(&other.iw_e2e);
    }
}

/// One fixed-cadence utilization bin: mean (`sum / count`) and max of
/// the effective-memory-utilization samples that fell into it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilBin {
    /// Sum of samples in the bin.
    pub sum: f64,
    /// Number of samples in the bin.
    pub count: u64,
    /// Largest sample in the bin.
    pub max: f64,
}

impl Default for UtilBin {
    fn default() -> Self {
        UtilBin { sum: 0.0, count: 0, max: f64::NEG_INFINITY }
    }
}

impl UtilBin {
    fn merge(&mut self, other: &UtilBin) {
        self.sum += other.sum;
        self.count += other.count;
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

/// Latency statistics for a set of completions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencySummary {
    /// Number of completions summarized.
    pub count: usize,
    /// Median TTFT, seconds.
    pub ttft_p50: f64,
    /// 75th-percentile TTFT, seconds.
    pub ttft_p75: f64,
    /// 95th-percentile TTFT, seconds.
    pub ttft_p95: f64,
    /// 99th-percentile TTFT, seconds.
    pub ttft_p99: f64,
    /// Median end-to-end latency, seconds.
    pub e2e_p50: f64,
    /// 75th-percentile end-to-end latency, seconds.
    pub e2e_p75: f64,
    /// 95th-percentile end-to-end latency, seconds.
    pub e2e_p95: f64,
    /// Mean TTFT, seconds.
    pub mean_ttft: f64,
    /// Mean end-to-end latency, seconds.
    pub mean_e2e: f64,
    /// Fraction of completions that missed their SLA/deadline.
    pub sla_violation_rate: f64,
}

impl LatencySummary {
    /// Exact summary over an outcome iterator — the
    /// [`MetricsMode::Exact`] / fidelity path (quickselect percentiles).
    pub fn from_outcomes<'a>(outcomes: impl Iterator<Item = &'a RequestOutcome>) -> Self {
        let mut ttft = Vec::new();
        let mut e2e = Vec::new();
        let mut violations = 0usize;
        for o in outcomes {
            ttft.push(o.ttft);
            e2e.push(o.e2e);
            if !o.sla_met {
                violations += 1;
            }
        }
        Self::from_parts(ttft, e2e, violations)
    }

    /// Summarize pre-collected latency vectors (exact percentiles).
    pub fn from_parts(mut ttft: Vec<f64>, mut e2e: Vec<f64>, violations: usize) -> Self {
        if ttft.is_empty() {
            return LatencySummary::default();
        }
        let count = ttft.len();
        let mean_ttft = ttft.iter().sum::<f64>() / count as f64;
        let mean_e2e = e2e.iter().sum::<f64>() / count as f64;
        LatencySummary {
            count,
            ttft_p50: percentile(&mut ttft, 50.0),
            ttft_p75: percentile(&mut ttft, 75.0),
            ttft_p95: percentile(&mut ttft, 95.0),
            ttft_p99: percentile(&mut ttft, 99.0),
            e2e_p50: percentile(&mut e2e, 50.0),
            e2e_p75: percentile(&mut e2e, 75.0),
            e2e_p95: percentile(&mut e2e, 95.0),
            mean_ttft,
            mean_e2e,
            sla_violation_rate: violations as f64 / count as f64,
        }
    }

    /// Summarize streaming accumulators: scalar stats plus two merged
    /// histograms.  Allocation-free — percentiles walk the histograms.
    pub fn from_accum(
        count: u64,
        violations: u64,
        sum_ttft: f64,
        sum_e2e: f64,
        ttft: &LatencyHistogram,
        e2e: &LatencyHistogram,
    ) -> Self {
        if count == 0 {
            return LatencySummary::default();
        }
        LatencySummary {
            count: count as usize,
            ttft_p50: ttft.percentile(50.0),
            ttft_p75: ttft.percentile(75.0),
            ttft_p95: ttft.percentile(95.0),
            ttft_p99: ttft.percentile(99.0),
            e2e_p50: e2e.percentile(50.0),
            e2e_p75: e2e.percentile(75.0),
            e2e_p95: e2e.percentile(95.0),
            mean_ttft: sum_ttft / count as f64,
            mean_e2e: sum_e2e / count as f64,
            sla_violation_rate: violations as f64 / count as f64,
        }
    }
}

/// Step-function integrator: instance count over time → instance-hours
/// (the area-under-curve metric of Fig 8/11).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct InstanceHourLedger {
    /// (time, count) change points, time-ordered.
    pub points: Vec<(Time, usize)>,
}

impl InstanceHourLedger {
    /// Record the instance count in effect from time `t` on (consecutive
    /// equal counts are deduplicated).
    pub fn record(&mut self, t: Time, count: usize) {
        if let Some(&(lt, lc)) = self.points.last() {
            debug_assert!(t >= lt, "ledger time went backwards");
            if lc == count {
                return;
            }
        }
        self.points.push((t, count));
    }

    /// Integrated instance-hours over [0, end].
    pub fn instance_hours(&self, end: Time) -> f64 {
        let mut total = 0.0;
        for w in self.points.windows(2) {
            let (t0, c) = w[0];
            let (t1, _) = w[1];
            total += c as f64 * (t1.min(end) - t0.min(end));
        }
        if let Some(&(t, c)) = self.points.last() {
            if t < end {
                total += c as f64 * (end - t);
            }
        }
        total / HOUR
    }

    /// Count in effect at time `t`.
    pub fn count_at(&self, t: Time) -> usize {
        match self.points.iter().rev().find(|&&(pt, _)| pt <= t) {
            Some(&(_, c)) => c,
            None => 0,
        }
    }

    /// Sample the step function at fixed intervals (for plotting).
    pub fn sample(&self, end: Time, step: Time) -> Vec<(Time, usize)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= end {
            out.push((t, self.count_at(t)));
            t += step;
        }
        out
    }

    /// Integrate `count × rate(t)` over `[0, end]` where `rate` is $/h
    /// and *hour-constant* (the [`SpotMarket`] curve's contract):
    /// segments split at wall-clock hour boundaries, so the integral is
    /// exact.  Returns dollars.
    pub fn dollars(&self, end: Time, rate: impl Fn(Time) -> f64) -> f64 {
        let mut total = 0.0;
        let mut add = |t0: Time, t1: Time, count: usize| {
            if count == 0 || t1 <= t0 {
                return;
            }
            let mut t = t0;
            while t < t1 {
                let next_hour = ((t / HOUR).floor() + 1.0) * HOUR;
                let seg_end = next_hour.min(t1);
                total += count as f64 * rate(t) * (seg_end - t) / HOUR;
                t = seg_end;
            }
        };
        for w in self.points.windows(2) {
            add(w[0].0.min(end), w[1].0.min(end), w[0].1);
        }
        if let Some(&(t, c)) = self.points.last() {
            if t < end {
                add(t, end, c);
            }
        }
        total
    }

    /// Sum another step function into this one: the merged ledger's
    /// count at any time is the sum of the two inputs' counts (shards
    /// tracking disjoint instance subsets combine exactly — integrals
    /// and `count_at` reads are preserved).
    pub fn merge(&mut self, other: &InstanceHourLedger) {
        if other.points.is_empty() {
            return;
        }
        if self.points.is_empty() {
            self.points = other.points.clone();
            return;
        }
        let a = std::mem::take(&mut self.points);
        let b = &other.points;
        let (mut i, mut j) = (0usize, 0usize);
        let (mut la, mut lb) = (0usize, 0usize);
        let mut out: Vec<(Time, usize)> = Vec::with_capacity(a.len() + b.len());
        while i < a.len() || j < b.len() {
            let t = match (a.get(i), b.get(j)) {
                (Some(&(ta, _)), Some(&(tb, _))) => ta.min(tb),
                (Some(&(ta, _)), None) => ta,
                (None, Some(&(tb, _))) => tb,
                (None, None) => break,
            };
            while i < a.len() && a[i].0 == t {
                la = a[i].1;
                i += 1;
            }
            while j < b.len() && b[j].0 == t {
                lb = b[j].1;
                j += 1;
            }
            let level = la + lb;
            if out.last().map_or(true, |&(_, l)| l != level) {
                out.push((t, level));
            }
        }
        self.points = out;
    }
}

/// One fault incident (outage window, spot shock, …) and its recovery
/// lifecycle — the per-incident record behind the time-to-recover column
/// of `fault_recovery.csv`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultIncident {
    /// Incident kind (`"region-outage"`, `"spot-shock"`, …).
    pub kind: &'static str,
    /// The region the incident hit.
    pub region: Region,
    /// When the fault opened, seconds since simulation start.
    pub start: Time,
    /// When the fault condition itself lifted (e.g. the outage window
    /// closed); `None` while still in effect.
    pub fault_end: Option<Time>,
    /// When serving capacity was restored to the pre-incident level
    /// (replacement VMs active); `None` if the run ended first.
    pub recovered_at: Option<Time>,
}

impl FaultIncident {
    /// Seconds from fault start to capacity recovery, if recovered.
    pub fn time_to_recover(&self) -> Option<Time> {
        self.recovered_at.map(|t| t - self.start)
    }
}

/// First-class failure accounting for the fault plane: per
/// (model, tier, region) kill/lost/shed counts, retry totals and the
/// incident log.  All-zero in fault-free runs (the cells stay
/// unallocated), so `Metrics` equality with pre-fault-plane runs is
/// preserved bit-for-bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FailureStats {
    /// In-flight requests killed by instance loss, dense
    /// `[model][tier][region]`; empty until the first kill.
    killed: Vec<u64>,
    /// Requests lost for good (retry budget exhausted or no live region).
    lost: Vec<u64>,
    /// NIW requests shed by graceful degradation.
    shed: Vec<u64>,
    /// Successful retry re-dispatches (a request retried twice counts
    /// twice — the numerator of the retry-amplification factor).
    pub retries: u64,
    /// Fault incidents in open order.
    pub incidents: Vec<FaultIncident>,
}

impl FailureStats {
    fn cell(v: &mut Vec<u64>, model: ModelKind, tier: Tier, region: Region) -> &mut u64 {
        if v.is_empty() {
            v.resize(CELLS, 0);
        }
        &mut v[(model.index() * TIERS + tier.index()) * REGIONS + region.index()]
    }

    fn read(v: &[u64], model: ModelKind, tier: Tier, region: Region) -> u64 {
        if v.is_empty() {
            0
        } else {
            v[(model.index() * TIERS + tier.index()) * REGIONS + region.index()]
        }
    }

    /// Count one in-flight request killed by instance loss.
    pub fn record_killed(&mut self, model: ModelKind, tier: Tier, region: Region) {
        *Self::cell(&mut self.killed, model, tier, region) += 1;
    }

    /// Count one request lost for good.
    pub fn record_lost(&mut self, model: ModelKind, tier: Tier, region: Region) {
        *Self::cell(&mut self.lost, model, tier, region) += 1;
    }

    /// Count one NIW request shed under graceful degradation.
    pub fn record_shed(&mut self, model: ModelKind, tier: Tier, region: Region) {
        *Self::cell(&mut self.shed, model, tier, region) += 1;
    }

    /// Kills in one (model, tier, region) cell.
    pub fn killed(&self, model: ModelKind, tier: Tier, region: Region) -> u64 {
        Self::read(&self.killed, model, tier, region)
    }

    /// Losses in one (model, tier, region) cell.
    pub fn lost(&self, model: ModelKind, tier: Tier, region: Region) -> u64 {
        Self::read(&self.lost, model, tier, region)
    }

    /// Sheds in one (model, tier, region) cell.
    pub fn shed(&self, model: ModelKind, tier: Tier, region: Region) -> u64 {
        Self::read(&self.shed, model, tier, region)
    }

    /// Total kills across all cells.
    pub fn killed_total(&self) -> u64 {
        self.killed.iter().sum()
    }

    /// Total losses across all cells.
    pub fn lost_total(&self) -> u64 {
        self.lost.iter().sum()
    }

    /// Total sheds across all cells.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    /// Sheds restricted to interactive tiers — must stay 0 (graceful
    /// degradation sacrifices NIW batch work first, never IW traffic);
    /// the `exp faults` ablation asserts this.
    pub fn shed_interactive_total(&self) -> u64 {
        if self.shed.is_empty() {
            return 0;
        }
        let mut sum = 0;
        for (mi, _) in ModelKind::ALL.iter().enumerate() {
            for (ti, tier) in Tier::ALL.iter().enumerate() {
                if !tier.is_interactive() {
                    continue;
                }
                for ri in 0..REGIONS {
                    sum += self.shed[(mi * TIERS + ti) * REGIONS + ri];
                }
            }
        }
        sum
    }

    /// Retry-amplification factor: dispatches per completed request,
    /// `1 + retries / completed` (1.0 in a fault-free run).
    pub fn retry_amplification(&self, completed: u64) -> f64 {
        if completed == 0 {
            1.0
        } else {
            1.0 + self.retries as f64 / completed as f64
        }
    }

    /// Open a new incident; returns its index for later closure.
    pub fn open_incident(&mut self, kind: &'static str, region: Region, start: Time) -> usize {
        self.incidents.push(FaultIncident {
            kind,
            region,
            start,
            fault_end: None,
            recovered_at: None,
        });
        self.incidents.len() - 1
    }

    /// Mark the fault condition itself as lifted (outage window closed).
    pub fn set_fault_end(&mut self, idx: usize, t: Time) {
        self.incidents[idx].fault_end = Some(t);
    }

    /// Mark capacity as recovered to the pre-incident level.
    pub fn set_recovered(&mut self, idx: usize, t: Time) {
        self.incidents[idx].recovered_at = Some(t);
    }

    /// Absorb another shard (elementwise cell sums, appended incidents).
    pub fn merge(&mut self, other: &FailureStats) {
        for (mine, theirs) in [
            (&mut self.killed, &other.killed),
            (&mut self.lost, &other.lost),
            (&mut self.shed, &other.shed),
        ] {
            if theirs.is_empty() {
                continue;
            }
            if mine.is_empty() {
                mine.resize(CELLS, 0);
            }
            for (a, b) in mine.iter_mut().zip(theirs) {
                *a += b;
            }
        }
        self.retries += other.retries;
        self.incidents.extend(other.incidents.iter().cloned());
    }
}

/// The guardrail controller's operating mode at one control epoch — the
/// rungs of the fallback cascade (see `coordinator::controller`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardrailMode {
    /// A fresh ILP plan was computed from live inputs.
    Fresh,
    /// The last-good plan is held with safety inflation.
    Held,
    /// Reactive proportional control (no usable plan at all).
    Reactive,
}

impl Default for GuardrailMode {
    /// The healthy rung: a fresh ILP plan.
    fn default() -> Self {
        GuardrailMode::Fresh
    }
}

impl GuardrailMode {
    /// Short lowercase label for CSV/log output.
    pub fn name(self) -> &'static str {
        match self {
            GuardrailMode::Fresh => "fresh",
            GuardrailMode::Held => "held",
            GuardrailMode::Reactive => "reactive",
        }
    }
}

/// One fallback-cascade transition: the guardrail controller moved from
/// one rung to another, and why.
#[derive(Debug, Clone, PartialEq)]
pub struct GuardrailEvent {
    /// When the transition happened, seconds since simulation start.
    pub at: Time,
    /// The mode being left.
    pub from: GuardrailMode,
    /// The mode being entered.
    pub to: GuardrailMode,
    /// Cause label (`"forecast-blackout"`, `"stale-telemetry"`,
    /// `"solver-failure"`, `"held-expired"`, `"recovered"`, …).
    pub cause: &'static str,
}

/// First-class guardrail accounting: fallback transitions, per-cause
/// degraded-epoch counts, time in degraded mode and the capacity-margin
/// ledger.  All-zero when no control faults fire and guardrails are off,
/// so `Metrics` equality with pre-guardrail runs is preserved
/// bit-for-bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GuardrailStats {
    /// Every fallback-cascade transition, in occurrence order.
    pub transitions: Vec<GuardrailEvent>,
    /// Control epochs planned from a fresh ILP solve (guarded runs only).
    pub epochs_fresh: u64,
    /// Control epochs served by the held last-good plan.
    pub epochs_held: u64,
    /// Control epochs served by reactive proportional control.
    pub epochs_reactive: u64,
    /// Seconds spent below the Fresh rung — time in degraded mode.
    pub degraded_secs: Time,
    /// Control epochs that observed a forecast blackout.
    pub blackout_epochs: u64,
    /// Control epochs that observed corrupted forecaster output.
    pub corrupt_epochs: u64,
    /// Control epochs whose telemetry inputs were stale beyond the
    /// watchdog's tolerance.
    pub stale_epochs: u64,
    /// Control epochs whose capacity solve was forced to fail.
    pub solver_fault_epochs: u64,
    /// Scale-out actuations silently dropped by the fault plane.
    pub actuations_dropped: u64,
    /// Scale-out actuations landed late by the fault plane.
    pub actuations_delayed: u64,
    /// Instance-hours of extra capacity commanded by the residual
    /// tracker's error-variance margin (the capacity-margin ledger).
    pub margin_instance_hours: f64,
}

impl GuardrailStats {
    /// Record one cascade transition.
    pub fn record_transition(
        &mut self,
        at: Time,
        from: GuardrailMode,
        to: GuardrailMode,
        cause: &'static str,
    ) {
        self.transitions.push(GuardrailEvent { at, from, to, cause });
    }

    /// Count one control epoch spent on the given rung; epochs below
    /// Fresh also accrue `degraded_secs`.
    pub fn record_epoch(&mut self, mode: GuardrailMode, epoch_secs: Time) {
        match mode {
            GuardrailMode::Fresh => self.epochs_fresh += 1,
            GuardrailMode::Held => {
                self.epochs_held += 1;
                self.degraded_secs += epoch_secs;
            }
            GuardrailMode::Reactive => {
                self.epochs_reactive += 1;
                self.degraded_secs += epoch_secs;
            }
        }
    }

    /// Total fallback transitions recorded.
    pub fn transition_count(&self) -> u64 {
        self.transitions.len() as u64
    }

    /// True when nothing was recorded — the state of every fault-free,
    /// guardrail-off run.
    pub fn is_empty(&self) -> bool {
        *self == GuardrailStats::default()
    }

    /// Absorb another shard (summed counters, appended transitions).
    pub fn merge(&mut self, other: &GuardrailStats) {
        self.transitions.extend(other.transitions.iter().cloned());
        self.epochs_fresh += other.epochs_fresh;
        self.epochs_held += other.epochs_held;
        self.epochs_reactive += other.epochs_reactive;
        self.degraded_secs += other.degraded_secs;
        self.blackout_epochs += other.blackout_epochs;
        self.corrupt_epochs += other.corrupt_epochs;
        self.stale_epochs += other.stale_epochs;
        self.solver_fault_epochs += other.solver_fault_epochs;
        self.actuations_dropped += other.actuations_dropped;
        self.actuations_delayed += other.actuations_delayed;
        self.margin_instance_hours += other.margin_instance_hours;
    }
}

/// GPU-hours wasted on scaling: time VMs spend provisioning, by cause
/// (Fig 13b's ledger).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScalingWasteLedger {
    /// cause → (events, wasted seconds).
    pub by_cause: BTreeMap<String, (u64, Time)>,
}

impl ScalingWasteLedger {
    /// Record one scaling event's wasted provisioning time.
    pub fn record(&mut self, cause: &str, wasted_secs: Time) {
        let e = self.by_cause.entry(cause.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += wasted_secs;
    }

    /// Total wasted GPU-hours across causes.
    pub fn total_gpu_hours(&self) -> f64 {
        self.by_cause.values().map(|&(_, s)| s).sum::<f64>() / HOUR
    }

    /// Total scaling events across causes.
    pub fn total_events(&self) -> u64 {
        self.by_cause.values().map(|&(n, _)| n).sum()
    }

    /// Absorb another waste ledger (per-cause event/second sums).
    pub fn merge(&mut self, other: &ScalingWasteLedger) {
        for (cause, &(n, s)) in &other.by_cause {
            let e = self.by_cause.entry(cause.clone()).or_insert((0, 0.0));
            e.0 += n;
            e.1 += s;
        }
    }
}

/// Top-level metrics container for one simulation run.  `PartialEq` backs
/// the parallel-sweep equivalence tests: two runs are "identical" iff
/// every accumulator cell, histogram bucket, ledger point and (in Exact
/// mode) outcome matches exactly.
#[derive(Debug, PartialEq)]
pub struct Metrics {
    cfg: MetricsConfig,
    /// Completions recorded (maintained in every mode — conservation
    /// checks read this instead of `outcomes.len()`).
    pub completed: u64,
    /// Per-request outcome log — populated in [`MetricsMode::Exact`]
    /// only; empty under streaming.
    pub outcomes: Vec<RequestOutcome>,
    /// (model, region) → active-instance ledger.
    pub instances: BTreeMap<(ModelKind, Region), InstanceHourLedger>,
    /// (model, region, GPU SKU) → allocated-instance ledger: the per-SKU
    /// GPU-hour and dollar-cost attribution for heterogeneous fleets
    /// (recorded at the same change points as `instances`).
    pub instances_by_gpu: BTreeMap<(ModelKind, Region, GpuKind), InstanceHourLedger>,
    /// (model, region, GPU SKU) → spot-donated-instance ledger: the
    /// single source of truth for donated capacity — totals
    /// ([`Metrics::spot_hours`]) and the spot-market revenue integration
    /// both derive from it.
    pub spot_instances_by_gpu: BTreeMap<(ModelKind, Region, GpuKind), InstanceHourLedger>,
    /// GPU-hours wasted on provisioning, by cause.
    pub scaling_waste: ScalingWasteLedger,
    /// Dropped/unserved requests (should stay 0 in healthy runs).
    pub dropped: u64,
    /// Prefill→decode handoffs initiated (disaggregated runs only; stays
    /// 0 in unified runs, preserving bit-identity with them).
    pub handoffs: u64,
    /// Handoffs admitted to a decode instance (the rest were either
    /// still in flight at cutoff or dropped).
    pub handoff_admissions: u64,
    /// Handoffs abandoned because no live decode instance ever admitted
    /// them.
    pub handoff_drops: u64,
    /// Total KV-cache migration time paid by handoffs, seconds — the
    /// explicit disaggregation tax (`exp disagg`'s overhead column).
    pub kv_transfer_secs: f64,
    /// Fault-plane failure accounting (all-zero without a fault plan).
    pub failures: FailureStats,
    /// Control-plane guardrail accounting (all-zero without control
    /// faults or guardrails).
    pub guardrails: GuardrailStats,
    /// Whole-run cells, dense `[model][tier][region]`; empty until the
    /// first completion.
    cells: Vec<GroupCell>,
    /// Arrival-binned cells, dense `[model][region]` slots each holding
    /// a by-bin series; empty until the first completion.
    bins: Vec<Vec<BinCell>>,
    /// Utilization bins, dense `[model][region]` slots; empty until the
    /// first sample.
    util: Vec<Vec<UtilBin>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new(MetricsConfig::default())
    }
}

impl Metrics {
    /// Create an empty metrics container for the given mode/bin width.
    ///
    /// Panics if `cfg.bin` is not positive (a zero/negative bin would
    /// turn the first recorded arrival into a huge bin index).
    pub fn new(cfg: MetricsConfig) -> Self {
        assert!(cfg.bin > 0.0, "metrics bin width must be positive (got {})", cfg.bin);
        Metrics {
            cfg,
            completed: 0,
            outcomes: Vec::new(),
            instances: BTreeMap::new(),
            instances_by_gpu: BTreeMap::new(),
            spot_instances_by_gpu: BTreeMap::new(),
            scaling_waste: ScalingWasteLedger::default(),
            dropped: 0,
            handoffs: 0,
            handoff_admissions: 0,
            handoff_drops: 0,
            kv_transfer_secs: 0.0,
            failures: FailureStats::default(),
            guardrails: GuardrailStats::default(),
            cells: Vec::new(),
            bins: Vec::new(),
            util: Vec::new(),
        }
    }

    /// The recording mode this container was built with.
    pub fn mode(&self) -> MetricsMode {
        self.cfg.mode
    }

    /// Width of the arrival/utilization bins, seconds.
    pub fn bin_width(&self) -> Time {
        self.cfg.bin
    }

    /// Record one completion: SLA evaluation plus O(1) streaming
    /// accumulator updates (and, in Exact mode, the outcome log push).
    pub fn record_outcome(&mut self, req: &Request, region: Region, ttft: Time, e2e: Time) {
        let sla_met = match req.tier.ttft_sla() {
            Some(sla) => ttft <= sla,
            None => match req.deadline() {
                Some(d) => req.arrival + e2e <= d,
                None => true,
            },
        };
        self.completed += 1;
        let (m, t, r) = (req.model.index(), req.tier.index(), region.index());
        // Per-request mean inter-token latency: the decode stream emits
        // `output_tokens − 1` gaps after the first token.  Computed the
        // same way in unified and disaggregated runs, so the histograms
        // stay comparable (and bit-identical for identical outcomes).
        let gaps = req.output_tokens.saturating_sub(1).max(1);
        let itl = ((e2e - ttft) / gaps as f64).max(0.0);
        // Bucket each latency once; both the whole-run and the binned
        // histogram reuse the index.
        let tb = bucket_of(ttft);
        let eb = bucket_of(e2e);

        if self.cells.is_empty() {
            self.cells.resize_with(CELLS, GroupCell::default);
        }
        let cell = &mut self.cells[(m * TIERS + t) * REGIONS + r];
        cell.count += 1;
        if !sla_met {
            cell.violations += 1;
        }
        cell.sum_ttft += ttft;
        cell.sum_e2e += e2e;
        cell.sum_itl += itl;
        cell.ttft.record_at(tb, ttft);
        cell.e2e.record_at(eb, e2e);
        cell.itl.record(itl);

        if self.bins.is_empty() {
            self.bins.resize_with(MODELS * REGIONS, Vec::new);
        }
        let bin = (req.arrival / self.cfg.bin) as usize;
        let series = &mut self.bins[m * REGIONS + r];
        if series.len() <= bin {
            series.resize_with(bin + 1, BinCell::default);
        }
        let bc = &mut series[bin];
        bc.count[t] += 1;
        if !sla_met {
            bc.violations[t] += 1;
        }
        bc.sum_ttft[t] += ttft;
        bc.sum_e2e[t] += e2e;
        if req.tier.is_interactive() {
            bc.iw_ttft.record_at(tb, ttft);
            bc.iw_e2e.record_at(eb, e2e);
        }

        if self.cfg.mode == MetricsMode::Exact {
            self.outcomes.push(RequestOutcome {
                tier: req.tier,
                model: req.model,
                region,
                ttft,
                e2e,
                itl,
                arrival: req.arrival,
                input_tokens: req.input_tokens,
                output_tokens: req.output_tokens,
                sla_met,
            });
        }
    }

    /// Record one effective-memory-utilization sample into its
    /// fixed-cadence bin (replaces the old unbounded sample `Vec`).
    pub fn record_util(&mut self, now: Time, model: ModelKind, region: Region, util: f64) {
        if self.util.is_empty() {
            self.util.resize_with(MODELS * REGIONS, Vec::new);
        }
        let bin = (now / self.cfg.bin) as usize;
        let series = &mut self.util[model.index() * REGIONS + region.index()];
        if series.len() <= bin {
            series.resize_with(bin + 1, UtilBin::default);
        }
        let b = &mut series[bin];
        b.sum += util;
        b.count += 1;
        if util > b.max {
            b.max = util;
        }
    }

    /// Fold the whole-run cells selected by `want` into one summary —
    /// stack-allocated histograms, no per-group latency vectors.
    fn summarize_cells(
        &self,
        want: impl Fn(ModelKind, Tier, Region) -> bool,
    ) -> LatencySummary {
        if self.cells.is_empty() {
            return LatencySummary::default();
        }
        let (mut count, mut violations) = (0u64, 0u64);
        let (mut sum_ttft, mut sum_e2e) = (0.0f64, 0.0f64);
        let mut ttft = LatencyHistogram::default();
        let mut e2e = LatencyHistogram::default();
        for (mi, &model) in ModelKind::ALL.iter().enumerate() {
            for (ti, &tier) in Tier::ALL.iter().enumerate() {
                for (ri, &region) in Region::ALL.iter().enumerate() {
                    if !want(model, tier, region) {
                        continue;
                    }
                    let cell = &self.cells[(mi * TIERS + ti) * REGIONS + ri];
                    if cell.count == 0 {
                        continue;
                    }
                    count += cell.count;
                    violations += cell.violations;
                    sum_ttft += cell.sum_ttft;
                    sum_e2e += cell.sum_e2e;
                    ttft.merge(&cell.ttft);
                    e2e.merge(&cell.e2e);
                }
            }
        }
        LatencySummary::from_accum(count, violations, sum_ttft, sum_e2e, &ttft, &e2e)
    }

    /// Latency summary for one SLA tier across all models and regions.
    pub fn latency_by_tier(&self, tier: Tier) -> LatencySummary {
        self.summarize_cells(|_, t, _| t == tier)
    }

    /// Latency summary for one model across all tiers and regions.
    pub fn latency_by_model(&self, model: ModelKind) -> LatencySummary {
        self.summarize_cells(|m, _, _| m == model)
    }

    /// Latency summary for one (model, tier) across regions.
    pub fn latency_by_model_tier(&self, model: ModelKind, tier: Tier) -> LatencySummary {
        self.summarize_cells(|m, t, _| m == model && t == tier)
    }

    /// Latency summary for one (tier, serving region) across models —
    /// the Fig 6c per-region cell.
    pub fn latency_by_tier_region(&self, tier: Tier, region: Region) -> LatencySummary {
        self.summarize_cells(|_, t, r| t == tier && r == region)
    }

    /// Interactive-traffic latency summary across all models (the
    /// `exp hetero` SLA-attainment cell).
    pub fn interactive_latency(&self) -> LatencySummary {
        self.summarize_cells(|_, t, _| t.is_interactive())
    }

    /// Fold one histogram axis over the interactive whole-run cells.
    fn fold_iw_hist(&self, pick: impl Fn(&GroupCell) -> &LatencyHistogram) -> LatencyHistogram {
        let mut h = LatencyHistogram::default();
        if self.cells.is_empty() {
            return h;
        }
        for mi in 0..MODELS {
            for (ti, tier) in Tier::ALL.iter().enumerate() {
                if !tier.is_interactive() {
                    continue;
                }
                for ri in 0..REGIONS {
                    h.merge(pick(&self.cells[(mi * TIERS + ti) * REGIONS + ri]));
                }
            }
        }
        h
    }

    /// Fraction of interactive completions whose TTFT met `target`
    /// (bucket-granular, see [`LatencyHistogram::fraction_leq`]); 1.0
    /// with no interactive traffic.  The prefill-sizing attainment
    /// column of `exp disagg`.
    pub fn ttft_attainment(&self, target: Time) -> f64 {
        self.fold_iw_hist(|c| &c.ttft).fraction_leq(target)
    }

    /// Fraction of interactive completions whose mean inter-token
    /// latency met `target`; 1.0 with no interactive traffic.  The
    /// decode-sizing attainment column of `exp disagg`.
    pub fn itl_attainment(&self, target: Time) -> f64 {
        self.fold_iw_hist(|c| &c.itl).fraction_leq(target)
    }

    /// 95th-percentile interactive inter-token latency, seconds
    /// (histogram-derived, ≤ ~3.7 % relative error).
    pub fn itl_p95(&self) -> f64 {
        self.fold_iw_hist(|c| &c.itl).percentile(95.0)
    }

    /// Every non-empty (model, tier) latency summary — one stack fold
    /// per populated group, no outcome re-scans.
    pub fn latency_by_model_tier_all(&self) -> BTreeMap<(ModelKind, Tier), LatencySummary> {
        let mut out = BTreeMap::new();
        for &model in &ModelKind::ALL {
            for &tier in &Tier::ALL {
                let s = self.latency_by_model_tier(model, tier);
                if s.count > 0 {
                    out.insert((model, tier), s);
                }
            }
        }
        out
    }

    /// Interactive-traffic latency summaries per model (the experiment
    /// tables' common cell shape); models with no IW completions are
    /// omitted, matching the historical grouped-scan behaviour.
    pub fn interactive_latency_by_model(&self) -> BTreeMap<ModelKind, LatencySummary> {
        let mut out = BTreeMap::new();
        for &model in &ModelKind::ALL {
            let s = self.summarize_cells(|m, t, _| m == model && t.is_interactive());
            if s.count > 0 {
                out.insert(model, s);
            }
        }
        out
    }

    /// Interactive-traffic latency summaries for one model in fixed
    /// arrival-time bins over `[0, end)`.  Returns one summary per bin,
    /// index `i` covering arrivals in `[i*bin, (i+1)*bin)`; empty bins
    /// yield a default summary with `count == 0`.
    ///
    /// `bin` must be a positive integer multiple of
    /// [`Metrics::bin_width`] — report bins are exact merges of the
    /// streaming bins (histogram merges are exact, so a 3-hour report
    /// bin over 15-minute streaming bins equals direct 3-hour
    /// accumulation).
    pub fn interactive_latency_bins(
        &self,
        model: ModelKind,
        bin: Time,
        end: Time,
    ) -> Vec<LatencySummary> {
        let n_bins = (end / bin).ceil().max(0.0) as usize;
        if n_bins == 0 {
            return Vec::new();
        }
        let ratio = bin / self.cfg.bin;
        let k = ratio.round() as usize;
        assert!(
            k >= 1 && (ratio - k as f64).abs() < 1e-6,
            "report bin {bin}s must be an integer multiple of the streaming bin {}s",
            self.cfg.bin
        );
        let mi = model.index();
        let mut out = Vec::with_capacity(n_bins);
        for i in 0..n_bins {
            let (lo, hi) = (i * k, (i + 1) * k);
            let (mut count, mut violations) = (0u64, 0u64);
            let (mut sum_ttft, mut sum_e2e) = (0.0f64, 0.0f64);
            let mut ttft = LatencyHistogram::default();
            let mut e2e = LatencyHistogram::default();
            for r in 0..REGIONS {
                let Some(series) = self.bins.get(mi * REGIONS + r) else { continue };
                for cell in series.iter().take(hi.min(series.len())).skip(lo) {
                    for (ti, &tier) in Tier::ALL.iter().enumerate() {
                        if !tier.is_interactive() {
                            continue;
                        }
                        count += cell.count[ti];
                        violations += cell.violations[ti];
                        sum_ttft += cell.sum_ttft[ti];
                        sum_e2e += cell.sum_e2e[ti];
                    }
                    ttft.merge(&cell.iw_ttft);
                    e2e.merge(&cell.iw_e2e);
                }
            }
            out.push(LatencySummary::from_accum(count, violations, sum_ttft, sum_e2e, &ttft, &e2e));
        }
        out
    }

    /// The arrival-binned cell series for one (model, region) — per-tier
    /// scalar stats plus IW histograms per streaming bin (for custom
    /// over-time reports and the shard-merge tests).
    pub fn bin_series(&self, model: ModelKind, region: Region) -> &[BinCell] {
        self.bins
            .get(model.index() * REGIONS + region.index())
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// The utilization bin series for one (model, region).
    pub fn util_series(&self, model: ModelKind, region: Region) -> &[UtilBin] {
        self.util
            .get(model.index() * REGIONS + region.index())
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Total instance-hours for a model across regions.
    pub fn model_instance_hours(&self, model: ModelKind, end: Time) -> f64 {
        self.instances
            .iter()
            .filter(|((m, _), _)| *m == model)
            .map(|(_, l)| l.instance_hours(end))
            .sum()
    }

    /// Total spot-donated instance-hours (derived from the per-SKU
    /// ledgers — every spot VM is a fleet SKU, so the split is total).
    pub fn spot_hours(&self, end: Time) -> f64 {
        self.spot_instances_by_gpu.values().map(|l| l.instance_hours(end)).sum()
    }

    /// GPU-hours (instance-hours) per SKU across all models and regions.
    pub fn gpu_hours_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.instances_by_gpu {
            *out.entry(*gpu).or_insert(0.0) += ledger.instance_hours(end);
        }
        out
    }

    /// Total fleet dollar cost: per-SKU GPU-hours × the SKU's on-demand
    /// $/h (α_k) — the §7.2.1 cost metric generalized to mixed fleets.
    pub fn fleet_dollar_cost(&self, end: Time) -> f64 {
        self.gpu_hours_by_sku(end)
            .iter()
            .map(|(gpu, hours)| gpu.dollars_per_hour() * hours)
            .sum()
    }

    /// On-demand dollar cost split per SKU (hours × α_k) — one half of
    /// the spot-vs-on-demand breakdown.
    pub fn fleet_dollar_cost_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        self.gpu_hours_by_sku(end)
            .into_iter()
            .map(|(gpu, hours)| (gpu, gpu.dollars_per_hour() * hours))
            .collect()
    }

    /// Spot-donated GPU-hours per SKU across all models and regions.
    pub fn spot_hours_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.spot_instances_by_gpu {
            *out.entry(*gpu).or_insert(0.0) += ledger.instance_hours(end);
        }
        out
    }

    /// Spot-market revenue per SKU: donated hours priced along the
    /// diurnal [`SpotMarket`] curve (exact — the curve is hour-constant
    /// and the ledger integration splits at hour boundaries).
    pub fn spot_revenue_by_sku(&self, end: Time) -> BTreeMap<GpuKind, f64> {
        let mut out = BTreeMap::new();
        for ((_, _, gpu), ledger) in &self.spot_instances_by_gpu {
            let g = *gpu;
            *out.entry(g).or_insert(0.0) += ledger.dollars(end, |t| SpotMarket::price(g, t));
        }
        out
    }

    /// Total spot-market revenue over `[0, end]` — what the donated pool
    /// earns back at per-SKU spot prices.
    pub fn spot_revenue(&self, end: Time) -> f64 {
        self.spot_revenue_by_sku(end).values().sum()
    }

    /// Net fleet cost: on-demand spend minus spot-market revenue — the
    /// heterogeneous-fleet headline metric (`exp hetero`).
    pub fn net_fleet_cost(&self, end: Time) -> f64 {
        self.fleet_dollar_cost(end) - self.spot_revenue(end)
    }

    /// Mean effective memory utilization for a model across all samples
    /// (regions folded in canonical order — deterministic).
    pub fn mean_util(&self, model: ModelKind) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u64);
        for r in 0..REGIONS {
            if let Some(series) = self.util.get(model.index() * REGIONS + r) {
                for b in series {
                    sum += b.sum;
                    n += b.count;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Absorb another metrics container recorded over a disjoint shard
    /// of the same run (e.g. completions partitioned by region, or a
    /// time-sliced chunk).
    ///
    /// Counts and histograms merge exactly in every case.  Floating
    /// latency/utilization sums are per-(model, region) — shards that
    /// partition completions *by key* therefore merge **bit-identically**
    /// to one sequential accumulation; shards that interleave updates to
    /// the same key merge within f64 rounding.  Ledgers under the same
    /// key are combined as step-function sums (integral-exact).
    ///
    /// This rounding caveat is exactly why `sim::chunked` *carries* one
    /// accumulator across chunk boundaries (inside the `SimHandoff`)
    /// instead of merging per-chunk shards: time-sliced chunks of a
    /// single run interleave on every key, so only the carried
    /// accumulator — same cells, same update order — can promise
    /// bit-identity with the sequential engine.
    pub fn merge(&mut self, other: &Metrics) {
        // Hard asserts: silently merging misaligned bin series would
        // attribute completions to wrong time windows, and mixed modes
        // would leave the outcome log covering only some shards (merge
        // is a cold report-side API — the checks cost nothing).
        assert!(
            self.cfg.bin == other.cfg.bin,
            "shards must share a bin width ({} vs {})",
            self.cfg.bin,
            other.cfg.bin
        );
        assert!(
            self.cfg.mode == other.cfg.mode,
            "shards must share a metrics mode ({:?} vs {:?})",
            self.cfg.mode,
            other.cfg.mode
        );
        self.completed += other.completed;
        self.dropped += other.dropped;
        self.handoffs += other.handoffs;
        self.handoff_admissions += other.handoff_admissions;
        self.handoff_drops += other.handoff_drops;
        self.kv_transfer_secs += other.kv_transfer_secs;
        self.failures.merge(&other.failures);
        self.guardrails.merge(&other.guardrails);
        self.outcomes.extend(other.outcomes.iter().cloned());
        if !other.cells.is_empty() {
            if self.cells.is_empty() {
                self.cells = other.cells.clone();
            } else {
                for (a, b) in self.cells.iter_mut().zip(&other.cells) {
                    a.merge(b);
                }
            }
        }
        if !other.bins.is_empty() {
            if self.bins.is_empty() {
                self.bins = other.bins.clone();
            } else {
                for (sa, sb) in self.bins.iter_mut().zip(&other.bins) {
                    if sa.len() < sb.len() {
                        sa.resize_with(sb.len(), BinCell::default);
                    }
                    for (a, b) in sa.iter_mut().zip(sb) {
                        a.merge(b);
                    }
                }
            }
        }
        if !other.util.is_empty() {
            if self.util.is_empty() {
                self.util = other.util.clone();
            } else {
                for (sa, sb) in self.util.iter_mut().zip(&other.util) {
                    if sa.len() < sb.len() {
                        sa.resize_with(sb.len(), UtilBin::default);
                    }
                    for (a, b) in sa.iter_mut().zip(sb) {
                        a.merge(b);
                    }
                }
            }
        }
        for (k, l) in &other.instances {
            self.instances.entry(*k).or_default().merge(l);
        }
        for (k, l) in &other.instances_by_gpu {
            self.instances_by_gpu.entry(*k).or_default().merge(l);
        }
        for (k, l) in &other.spot_instances_by_gpu {
            self.spot_instances_by_gpu.entry(*k).or_default().merge(l);
        }
        self.scaling_waste.merge(&other.scaling_waste);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::types::AppKind;

    fn req(i: u64, arrival: Time, model: ModelKind, tier: Tier) -> Request {
        Request {
            id: i,
            arrival,
            model,
            origin: Region::EastUs,
            tier,
            app: AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&mut v, 50.0), 3.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 100.0), 5.0);
    }

    #[test]
    fn ledger_integrates_steps() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(3600.0, 4);
        l.record(7200.0, 0);
        // 2 inst × 1 h + 4 inst × 1 h = 6 instance-hours.
        assert!((l.instance_hours(7200.0) - 6.0).abs() < 1e-9);
        // Trailing segment extends to `end`.
        l.record(7200.0, 1);
        assert!((l.instance_hours(10_800.0) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_count_at() {
        let mut l = InstanceHourLedger::default();
        l.record(10.0, 3);
        l.record(20.0, 5);
        assert_eq!(l.count_at(5.0), 0);
        assert_eq!(l.count_at(15.0), 3);
        assert_eq!(l.count_at(25.0), 5);
    }

    #[test]
    fn ledger_dedups_equal_counts() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(10.0, 2);
        assert_eq!(l.points.len(), 1);
    }

    #[test]
    fn ledger_merge_sums_step_functions() {
        let mut a = InstanceHourLedger::default();
        a.record(0.0, 2);
        a.record(100.0, 1);
        let mut b = InstanceHourLedger::default();
        b.record(50.0, 3);
        b.record(100.0, 0);
        let (ia, ib) = (a.instance_hours(200.0), b.instance_hours(200.0));
        a.merge(&b);
        // Integral is preserved exactly ...
        assert!((a.instance_hours(200.0) - ia - ib).abs() < 1e-9);
        // ... and the merged step function is the pointwise sum.
        assert_eq!(a.count_at(25.0), 2);
        assert_eq!(a.count_at(75.0), 5);
        assert_eq!(a.count_at(150.0), 1);
        // Merging into an empty ledger clones.
        let mut empty = InstanceHourLedger::default();
        empty.merge(&a);
        assert_eq!(empty, a);
    }

    #[test]
    fn sla_accounting() {
        let mut m = Metrics::default();
        let r = req(0, 0.0, ModelKind::Llama2_70B, Tier::IwF);
        m.record_outcome(&r, Region::EastUs, 0.5, 2.0); // meets 1s TTFT
        m.record_outcome(&r, Region::EastUs, 1.5, 3.0); // violates
        let s = m.latency_by_tier(Tier::IwF);
        assert_eq!(s.count, 2);
        assert!((s.sla_violation_rate - 0.5).abs() < 1e-9);
        assert_eq!(m.completed, 2);
        // Streaming mode keeps no outcome log.
        assert!(m.outcomes.is_empty());
    }

    #[test]
    fn itl_and_attainment_accounting() {
        let mut m = Metrics::new(MetricsConfig { mode: MetricsMode::Exact, bin: 900.0 });
        // 10 output tokens ⇒ 9 gaps; e2e − ttft = 0.9 ⇒ itl = 0.1.
        let r = req(0, 0.0, ModelKind::Llama2_70B, Tier::IwF);
        m.record_outcome(&r, Region::EastUs, 0.5, 1.4);
        assert!((m.outcomes[0].itl - 0.1).abs() < 1e-12);
        // A second request with a slow decode stream: itl = 0.5.
        m.record_outcome(&r, Region::EastUs, 0.5, 5.0);
        // Attainment is bucket-granular: a 0.2 s target keeps only the
        // 0.1 s request.
        assert!((m.itl_attainment(0.2) - 0.5).abs() < 1e-9);
        assert_eq!(m.itl_attainment(10.0), 1.0);
        assert_eq!(m.ttft_attainment(1.0), 1.0);
        assert!(m.itl_p95() > 0.4);
        // No interactive traffic: attainment is vacuously perfect.
        assert_eq!(Metrics::default().itl_attainment(0.2), 1.0);
        assert_eq!(Metrics::default().ttft_attainment(1.0), 1.0);
    }

    #[test]
    fn exact_mode_keeps_outcome_log() {
        let mut m = Metrics::new(MetricsConfig { mode: MetricsMode::Exact, bin: 900.0 });
        let r = req(0, 10.0, ModelKind::Llama2_70B, Tier::IwF);
        m.record_outcome(&r, Region::WestUs, 0.3, 1.2);
        assert_eq!(m.outcomes.len(), 1);
        assert_eq!(m.outcomes[0].region, Region::WestUs);
        assert!(m.outcomes[0].sla_met);
        // Streaming summaries are maintained in Exact mode too.
        assert_eq!(m.latency_by_tier(Tier::IwF).count, 1);
    }

    /// Streaming grouped summaries vs the exact outcome log: counts,
    /// means and violation rates match exactly; percentiles within the
    /// histogram error bound.
    #[test]
    fn grouped_summaries_match_exact_log() {
        let mut m = Metrics::new(MetricsConfig { mode: MetricsMode::Exact, bin: 900.0 });
        for i in 0..400u64 {
            let model = if i % 2 == 0 { ModelKind::Llama2_70B } else { ModelKind::Bloom176B };
            let tier = if i % 3 == 0 { Tier::Niw } else { Tier::IwF };
            let r = req(i, i as f64, model, tier);
            m.record_outcome(&r, Region::EastUs, 0.1 + (i % 37) as f64 * 0.07, 2.0 + i as f64 * 0.5);
        }
        for (&(model, tier), s) in &m.latency_by_model_tier_all() {
            let exact = LatencySummary::from_outcomes(
                m.outcomes.iter().filter(|o| o.model == model && o.tier == tier),
            );
            assert_eq!(s.count, exact.count, "{model} {tier}");
            assert_eq!(s.sla_violation_rate, exact.sla_violation_rate);
            assert!((s.mean_ttft - exact.mean_ttft).abs() < 1e-9 * exact.mean_ttft.max(1.0));
            assert!((s.mean_e2e - exact.mean_e2e).abs() < 1e-9 * exact.mean_e2e.max(1.0));
            for (h, e) in [
                (s.ttft_p50, exact.ttft_p50),
                (s.ttft_p95, exact.ttft_p95),
                (s.e2e_p50, exact.e2e_p50),
                (s.e2e_p95, exact.e2e_p95),
            ] {
                assert!((h - e).abs() / e.max(1e-9) < 0.045, "{model} {tier}: {h} vs {e}");
            }
        }
        let iw = m.interactive_latency_by_model();
        for (&model, s) in &iw {
            let exact = LatencySummary::from_outcomes(
                m.outcomes.iter().filter(|o| o.model == model && o.tier.is_interactive()),
            );
            assert_eq!(s.count, exact.count);
            assert!((s.ttft_p75 - exact.ttft_p75).abs() / exact.ttft_p75 < 0.045);
        }
        // The all-model interactive fold agrees with a filtered scan.
        let all_iw = m.interactive_latency();
        let exact_iw =
            LatencySummary::from_outcomes(m.outcomes.iter().filter(|o| o.tier.is_interactive()));
        assert_eq!(all_iw.count, exact_iw.count);
        assert_eq!(all_iw.sla_violation_rate, exact_iw.sla_violation_rate);
    }

    #[test]
    fn binned_summaries_match_filtered_windows() {
        let mut m = Metrics::new(MetricsConfig { mode: MetricsMode::Exact, bin: 300.0 });
        for i in 0..200u64 {
            let model = if i % 2 == 0 { ModelKind::Llama2_70B } else { ModelKind::Bloom176B };
            let tier = if i % 5 == 0 { Tier::Niw } else { Tier::IwF };
            let r = req(i, i as f64 * 7.3, model, tier);
            m.record_outcome(&r, Region::EastUs, 0.1 + (i % 13) as f64 * 0.2, 3.0 + i as f64);
        }
        let (bin, end) = (300.0, 200.0 * 7.3);
        let bins = m.interactive_latency_bins(ModelKind::Llama2_70B, bin, end);
        assert_eq!(bins.len(), (end / bin).ceil() as usize);
        for (i, s) in bins.iter().enumerate() {
            let t = i as f64 * bin;
            let window = LatencySummary::from_outcomes(m.outcomes.iter().filter(|o| {
                o.model == ModelKind::Llama2_70B
                    && o.tier.is_interactive()
                    && o.arrival >= t
                    && o.arrival < t + bin
            }));
            assert_eq!(s.count, window.count, "bin {i}");
            assert_eq!(s.sla_violation_rate, window.sla_violation_rate, "bin {i}");
            if window.count > 0 {
                assert!(
                    (s.ttft_p95 - window.ttft_p95).abs() / window.ttft_p95.max(1e-9) < 0.045,
                    "bin {i}: {} vs {}",
                    s.ttft_p95,
                    window.ttft_p95
                );
                assert!(
                    (s.e2e_p95 - window.e2e_p95).abs() / window.e2e_p95.max(1e-9) < 0.045,
                    "bin {i}"
                );
            }
        }
        // Coarser report bins are exact merges of the streaming bins:
        // counts at 600 s equal the sum of the two 300 s halves.
        let coarse = m.interactive_latency_bins(ModelKind::Llama2_70B, 600.0, end);
        for (i, c) in coarse.iter().enumerate() {
            let fine: usize =
                bins[i * 2..(i * 2 + 2).min(bins.len())].iter().map(|s| s.count).sum();
            assert_eq!(c.count, fine, "coarse bin {i}");
        }
    }

    #[test]
    fn util_bins_mean_and_max() {
        let mut m = Metrics::default();
        m.record_util(0.0, ModelKind::Llama2_70B, Region::EastUs, 0.2);
        m.record_util(100.0, ModelKind::Llama2_70B, Region::EastUs, 0.6);
        m.record_util(1000.0, ModelKind::Llama2_70B, Region::WestUs, 0.4);
        assert!((m.mean_util(ModelKind::Llama2_70B) - 0.4).abs() < 1e-12);
        let series = m.util_series(ModelKind::Llama2_70B, Region::EastUs);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].count, 2);
        assert!((series[0].max - 0.6).abs() < 1e-12);
        assert!(m.util_series(ModelKind::Llama2_70B, Region::CentralUs).is_empty());
    }

    #[test]
    fn per_sku_hours_and_dollar_cost() {
        let mut m = Metrics::default();
        m.instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::H100x8))
            .or_default()
            .record(0.0, 2);
        m.instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::A100x8))
            .or_default()
            .record(0.0, 4);
        let by_sku = m.gpu_hours_by_sku(HOUR);
        assert!((by_sku[&GpuKind::H100x8] - 2.0).abs() < 1e-9);
        assert!((by_sku[&GpuKind::A100x8] - 4.0).abs() < 1e-9);
        let cost = m.fleet_dollar_cost(HOUR);
        let want = 2.0 * GpuKind::H100x8.dollars_per_hour() + 4.0 * GpuKind::A100x8.dollars_per_hour();
        assert!((cost - want).abs() < 1e-9);
    }

    #[test]
    fn ledger_dollars_integrates_hour_constant_rates() {
        let mut l = InstanceHourLedger::default();
        l.record(0.0, 2);
        l.record(2.0 * HOUR, 0);
        // Constant $10/h: 2 instances × 2 h = $40.
        assert!((l.dollars(3.0 * HOUR, |_| 10.0) - 40.0).abs() < 1e-9);
        // Rate that doubles after the first hour: 2×10 + 2×20 = $60,
        // even when the segment spans the boundary.
        let stepped = |t: Time| if t < HOUR { 10.0 } else { 20.0 };
        assert!((l.dollars(3.0 * HOUR, stepped) - 60.0).abs() < 1e-9);
        // Truncation at `end` mid-segment.
        assert!((l.dollars(0.5 * HOUR, |_| 10.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn spot_revenue_prices_donated_hours_per_sku() {
        use crate::config::SpotMarket;
        let mut m = Metrics::default();
        // One H100 donated for the first two (off-peak) hours of the day.
        let led = m
            .spot_instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::EastUs, GpuKind::H100x8))
            .or_default();
        led.record(0.0, 1);
        led.record(2.0 * HOUR, 0);
        // One A100 donated across the 08:00→10:00 off-peak/peak edge.
        let led = m
            .spot_instances_by_gpu
            .entry((ModelKind::Llama2_70B, Region::WestUs, GpuKind::A100x8))
            .or_default();
        led.record(8.0 * HOUR, 1);
        led.record(10.0 * HOUR, 0);
        let end = 24.0 * HOUR;
        let by_sku = m.spot_revenue_by_sku(end);
        let h100 = 2.0 * GpuKind::H100x8.spot_dollars_per_hour() * SpotMarket::OFF_PEAK;
        let a100 = GpuKind::A100x8.spot_dollars_per_hour()
            * (SpotMarket::OFF_PEAK + SpotMarket::PEAK);
        assert!((by_sku[&GpuKind::H100x8] - h100).abs() < 1e-9);
        assert!((by_sku[&GpuKind::A100x8] - a100).abs() < 1e-9);
        assert!((m.spot_revenue(end) - h100 - a100).abs() < 1e-9);
        // Net cost = on-demand − spot revenue (no allocated hours here).
        assert!((m.net_fleet_cost(end) + h100 + a100).abs() < 1e-9);
    }

    #[test]
    fn failure_stats_cells_incidents_and_merge() {
        let mut f = FailureStats::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::CentralUs);
        f.record_killed(m, Tier::IwF, r);
        f.record_killed(m, Tier::IwF, r);
        f.record_lost(m, Tier::Niw, r);
        f.record_shed(m, Tier::Niw, r);
        f.retries += 3;
        assert_eq!(f.killed(m, Tier::IwF, r), 2);
        assert_eq!(f.killed_total(), 2);
        assert_eq!(f.lost_total(), 1);
        assert_eq!(f.shed_total(), 1);
        assert_eq!(f.shed_interactive_total(), 0, "only NIW was shed");
        assert!((f.retry_amplification(6) - 1.5).abs() < 1e-12);
        assert_eq!(FailureStats::default().retry_amplification(0), 1.0);

        let idx = f.open_incident("region-outage", r, 100.0);
        f.set_fault_end(idx, 200.0);
        f.set_recovered(idx, 350.0);
        assert_eq!(f.incidents[idx].time_to_recover(), Some(250.0));

        // Merge: cell sums + appended incidents; merging an empty shard
        // is an identity (the fault-free bit-identity guarantee).
        let snapshot = f.clone();
        f.merge(&FailureStats::default());
        assert_eq!(f, snapshot);
        let mut g = FailureStats::default();
        g.record_killed(m, Tier::IwF, r);
        g.retries = 1;
        f.merge(&g);
        assert_eq!(f.killed_total(), 3);
        assert_eq!(f.retries, 4);
        assert_eq!(f.incidents.len(), 1);
    }

    #[test]
    fn guardrail_stats_epochs_transitions_and_merge() {
        let mut g = GuardrailStats::default();
        assert!(g.is_empty(), "fresh container records nothing");
        g.record_epoch(GuardrailMode::Fresh, 3600.0);
        assert_eq!(g.epochs_fresh, 1);
        assert_eq!(g.degraded_secs, 0.0, "fresh epochs are not degraded time");
        g.record_transition(3600.0, GuardrailMode::Fresh, GuardrailMode::Held, "forecast-blackout");
        g.record_epoch(GuardrailMode::Held, 3600.0);
        g.record_transition(7200.0, GuardrailMode::Held, GuardrailMode::Reactive, "held-expired");
        g.record_epoch(GuardrailMode::Reactive, 3600.0);
        assert_eq!(g.transition_count(), 2);
        assert_eq!(g.degraded_secs, 7200.0);
        assert!(!g.is_empty());
        assert_eq!(GuardrailMode::Reactive.name(), "reactive");

        // Merging an empty shard is an identity (the bit-identity
        // guarantee for fault-free runs), and counters/transitions sum.
        let snapshot = g.clone();
        g.merge(&GuardrailStats::default());
        assert_eq!(g, snapshot);
        let mut h = GuardrailStats::default();
        h.record_epoch(GuardrailMode::Held, 1800.0);
        h.blackout_epochs = 2;
        h.actuations_dropped = 1;
        h.margin_instance_hours = 0.5;
        g.merge(&h);
        assert_eq!(g.epochs_held, 2);
        assert_eq!(g.degraded_secs, 9000.0);
        assert_eq!(g.blackout_epochs, 2);
        assert_eq!(g.actuations_dropped, 1);
        assert_eq!(g.transitions.len(), 2);
    }

    #[test]
    fn waste_ledger_totals() {
        let mut w = ScalingWasteLedger::default();
        w.record("vm-provision", 600.0);
        w.record("vm-provision", 600.0);
        w.record("spot-reclaim", 60.0);
        assert_eq!(w.total_events(), 3);
        assert!((w.total_gpu_hours() - 1260.0 / 3600.0).abs() < 1e-9);
        let mut w2 = ScalingWasteLedger::default();
        w2.record("vm-provision", 60.0);
        w.merge(&w2);
        assert_eq!(w.total_events(), 4);
        assert_eq!(w.by_cause["vm-provision"].0, 3);
    }
}
