//! Deterministic log-bucketed latency histograms — the percentile engine
//! behind the streaming metrics core.
//!
//! Buckets are geometric with a **static** layout (no per-run adaptation):
//! 32 buckets per decade across 8 decades, `[1 ms, 100 000 s)`, plus an
//! underflow and an overflow bucket.  A static layout is what makes the
//! histograms *mergeable*: two shards bucket every value identically, so
//! `merge` is an exact element-wise count sum and merged percentiles are
//! bit-identical to single-pass accumulation.
//!
//! Accuracy: within the covered range a percentile is reported as the
//! geometric midpoint of its bucket, so the relative error versus the
//! exact nearest-rank percentile is at most `10^(1/64) − 1 ≈ 3.7 %`
//! (asserted with margin by the histogram tests).  Out-of-range values
//! fall into the underflow/overflow buckets and are reported as the
//! exactly-tracked global min/max.

use crate::config::Time;

/// Log-bucket resolution: buckets per decade.
const PER_DECADE: f64 = 32.0;
/// Lower edge of the first regular bucket, seconds.
const MIN_LAT: f64 = 1e-3;
/// Covered decades above [`MIN_LAT`] (upper edge `1e5` s ≈ 28 h).
const DECADES: usize = 8;
/// Total bucket count: underflow + 8 × 32 regular + overflow.
pub const BUCKETS: usize = DECADES * PER_DECADE as usize + 2;

/// Dense bucket index for a latency value (pure function of `v`; shards
/// bucket identically, which is what makes histogram merges exact).
#[inline]
pub fn bucket_of(v: Time) -> usize {
    // NaN and anything ≤ MIN_LAT land in the underflow bucket.
    if !(v > MIN_LAT) {
        return 0;
    }
    // Saturating float→int cast, clamped *before* the +1 shift so even
    // pathological inputs (∞) stay in the overflow bucket.
    let b = ((v / MIN_LAT).log10() * PER_DECADE) as usize;
    b.min(BUCKETS - 2) + 1
}

/// A fixed-layout log-bucketed histogram of latency samples with exact
/// min/max tracking.  ~2 KiB regardless of how many samples it absorbs —
/// the O(1)-in-requests building block of [`crate::metrics::Metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    counts: [u64; BUCKETS],
    total: u64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: [0; BUCKETS],
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LatencyHistogram {
    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: Time) {
        self.record_at(bucket_of(v), v);
    }

    /// Record one sample whose bucket the caller already computed (the
    /// completion hot path buckets each value once and feeds both the
    /// whole-run and the time-binned histogram).
    #[inline]
    pub fn record_at(&mut self, bucket: usize, v: Time) {
        self.counts[bucket] += 1;
        self.total += 1;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Absorb another histogram: element-wise count sum plus min/max
    /// union.  Exact — merged shards are indistinguishable from a single
    /// sequential accumulation of the same samples.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.total += other.total;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Number of recorded samples.
    #[inline]
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Smallest recorded sample (`+∞` when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest recorded sample (`−∞` when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Nearest-rank percentile, `p` in `[0, 100]`; `0.0` when empty.
    ///
    /// The rank is computed exactly as [`crate::metrics::percentile`]
    /// computes it over a sorted slice, so the reported value lives in
    /// the same bucket as the exact answer and differs from it by at
    /// most half a bucket width (≈3.7 % relative) within the covered
    /// range; under/overflow ranks report the exact min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.total - 1) as f64).round() as u64;
        let rank = rank.min(self.total - 1);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return self.representative(b);
            }
        }
        self.max
    }

    /// Number of samples at or below `x`, bucket-granular: every sample
    /// in a bucket whose index is ≤ `bucket_of(x)` counts.  Samples
    /// sharing `x`'s bucket but above it are over-counted, so the answer
    /// is exact in *rank* only up to one bucket — equivalently, it is the
    /// exact count for some threshold within `10^(1/32)` (≈7.5 %) of `x`.
    /// The SLO-attainment consumers accept that: attainment curves are
    /// read at bucket resolution, same as percentiles.
    #[inline]
    pub fn count_leq(&self, x: Time) -> u64 {
        let b = bucket_of(x);
        self.counts[..=b].iter().sum()
    }

    /// Fraction of samples at or below `x` (bucket-granular, see
    /// [`LatencyHistogram::count_leq`]); `1.0` when empty — an empty
    /// histogram violates no SLO.
    pub fn fraction_leq(&self, x: Time) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.count_leq(x) as f64 / self.total as f64
        }
    }

    /// Reported value for a bucket: geometric midpoint, clamped to the
    /// exact observed [min, max] (so single-sample and extreme ranks
    /// stay honest).
    fn representative(&self, bucket: usize) -> f64 {
        let v = if bucket == 0 {
            self.min
        } else if bucket == BUCKETS - 1 {
            self.max
        } else {
            MIN_LAT * 10f64.powf((bucket as f64 - 0.5) / PER_DECADE)
        };
        // NaN samples land in the underflow bucket without touching
        // min/max; guard the clamp so a poisoned histogram degrades
        // instead of panicking (`f64::clamp` asserts min <= max).
        if self.min <= self.max {
            v.clamp(self.min, self.max)
        } else {
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::percentile;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_mapping_is_monotonic_and_bounded() {
        let mut last = 0usize;
        let mut v = 1e-5;
        while v < 1e7 {
            let b = bucket_of(v);
            assert!(b >= last, "bucket order broke at {v}");
            assert!(b < BUCKETS);
            last = b;
            v *= 1.01;
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-1.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(1e9), BUCKETS - 1);
    }

    #[test]
    fn percentile_error_is_bounded_by_bucket_width() {
        // Log-uniform samples across the realistic latency range.
        let mut rng = Rng::seed_from_u64(7);
        let mut hist = LatencyHistogram::default();
        let mut exact: Vec<f64> = Vec::new();
        for _ in 0..50_000 {
            let v = 10f64.powf(rng.range(-2.0, 3.0));
            hist.record(v);
            exact.push(v);
        }
        for p in [0.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let e = percentile(&mut exact, p);
            let h = hist.percentile(p);
            let rel = (h - e).abs() / e;
            // Guaranteed bound is 10^(1/64) − 1 ≈ 3.7 %; assert with margin.
            assert!(rel < 0.045, "p{p}: hist {h} vs exact {e} (rel {rel:.4})");
        }
    }

    #[test]
    fn itl_percentile_error_within_documented_bound() {
        // Inter-token latencies live in the 1 ms – 1 s range (decode
        // iteration times); the histogram must hold the documented
        // ≤ 10^(1/64) − 1 ≈ 3.7 % bound there exactly as it does for
        // TTFT/E2E.  Assert against the hard bound plus float slack.
        let mut rng = Rng::seed_from_u64(23);
        let mut hist = LatencyHistogram::default();
        let mut exact: Vec<f64> = Vec::new();
        for _ in 0..50_000 {
            let v = 10f64.powf(rng.range(-2.9, 0.0));
            hist.record(v);
            exact.push(v);
        }
        let bound = 10f64.powf(1.0 / 64.0) - 1.0;
        for p in [50.0, 90.0, 95.0, 99.0] {
            let e = percentile(&mut exact, p);
            let h = hist.percentile(p);
            let rel = (h - e).abs() / e;
            assert!(rel <= bound + 1e-9, "ITL p{p}: hist {h} vs exact {e} (rel {rel:.5})");
        }
    }

    #[test]
    fn count_leq_is_bucket_granular_and_monotonic() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.fraction_leq(1.0), 1.0); // empty: no violations
        for v in [0.01, 0.02, 0.05, 0.2, 0.5, 2.0, 8.0] {
            h.record(v);
        }
        assert_eq!(h.count_leq(0.0), 0);
        assert_eq!(h.count_leq(1e9), h.count());
        // Thresholds a bucket apart see monotone non-decreasing counts.
        let mut last = 0u64;
        let mut x = 1e-3;
        while x < 100.0 {
            let c = h.count_leq(x);
            assert!(c >= last);
            last = c;
            x *= 1.2;
        }
        // Well-separated values resolve exactly (each in its own bucket).
        assert_eq!(h.count_leq(0.1), 3);
        assert_eq!(h.count_leq(1.0), 5);
        assert!((h.fraction_leq(1.0) - 5.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_sequential_accumulation() {
        let mut rng = Rng::seed_from_u64(11);
        let (mut all, mut a, mut b) = (
            LatencyHistogram::default(),
            LatencyHistogram::default(),
            LatencyHistogram::default(),
        );
        for i in 0..10_000 {
            let v = 10f64.powf(rng.range(-4.0, 6.0)); // includes under/overflow
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a, all, "merged shards must equal sequential accumulation");
    }

    #[test]
    fn empty_and_single_sample_cases() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.percentile(95.0), 0.0);
        h.record(0.42);
        // Single sample: every percentile is clamped to the value itself.
        for p in [0.0, 50.0, 100.0] {
            assert!((h.percentile(p) - 0.42).abs() < 1e-12);
        }
    }

    #[test]
    fn nan_samples_degrade_without_panicking() {
        let mut h = LatencyHistogram::default();
        h.record(f64::NAN); // underflow bucket; min/max untouched
        assert_eq!(h.count(), 1);
        let _ = h.percentile(50.0); // degraded value, but no clamp panic
    }

    #[test]
    fn out_of_range_values_report_exact_extrema() {
        let mut h = LatencyHistogram::default();
        h.record(1e-6);
        h.record(1e-6);
        h.record(5e8);
        assert!((h.percentile(0.0) - 1e-6).abs() < 1e-18);
        assert!((h.percentile(100.0) - 5e8).abs() < 1.0);
    }
}
