//! Fault-plane ablation (`exp faults`): how each autoscaling strategy
//! rides out deterministic capacity loss on the week-long trace.
//!
//! Two scenarios, each run under Reactive, LT-UA and Chiron:
//!
//! * **region-dark** — CentralUs goes dark for 12 h mid-week (days 2 to
//!   2.5): every in-flight request there is killed and retried
//!   cross-region, routing excludes the region, and the autoscaler
//!   re-provisions the survivors.
//! * **spot-shock** — 60% of every region's donated spot pool is
//!   reclaimed at day 3, on top of a continuous 1-crash/day/instance VM
//!   hazard (the "bad week" a capacity planner fears).
//!
//! Emits `fault_recovery.csv` with per-(scenario, strategy) failure
//! accounting: kills, retries, losses, sheds, the retry-amplification
//! factor, interactive SLA attainment and worst-incident time-to-recover.
//! The run also asserts the graceful-degradation invariant — shed work is
//! NIW only, never interactive.
//!
//! Quick mode (`SAGESERVE_EXP_QUICK=1`, used by the `make verify` smoke
//! set) shrinks the trace to one day and rescales the fault schedule so
//! the whole ablation finishes in seconds.

use anyhow::Result;

use crate::config::{Epoch, Region, Tier, HOUR};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::sim::faults::FaultPlan;
use crate::trace::generator::TraceConfig;

/// True when the smoke-mode env toggle is set (same convention as
/// `SAGESERVE_BENCH_QUICK`).
fn quick_mode() -> bool {
    std::env::var("SAGESERVE_EXP_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

/// The two fault scenarios, scaled to the trace length.
fn scenarios(days: f64) -> Vec<(&'static str, FaultPlan)> {
    // Fault instants sit at fixed fractions of the trace so quick mode
    // exercises the identical phases (outage mid-trace, shock later).
    let span = days * 24.0 * HOUR;
    let dark = FaultPlan::region_dark(Region::CentralUs, span * 2.0 / 7.0, span * 2.5 / 7.0);
    let mut shock = FaultPlan::spot_shock(span * 3.0 / 7.0, 0.6);
    shock.crash_rate_per_day = 1.0;
    vec![("region-dark", dark), ("spot-shock", shock)]
}

/// Interactive SLA attainment across both IW tiers (count-weighted).
fn iw_sla_attainment(metrics: &crate::metrics::Metrics) -> f64 {
    let (mut violations, mut count) = (0.0, 0.0);
    for tier in Tier::ALL {
        if !tier.is_interactive() {
            continue;
        }
        let s = metrics.latency_by_tier(tier);
        violations += s.sla_violation_rate * s.count as f64;
        count += s.count as f64;
    }
    if count > 0.0 {
        1.0 - violations / count
    } else {
        1.0
    }
}

/// Run the fault ablation and write `fault_recovery.csv`.
pub fn faults(opts: &ExpOptions) -> Result<()> {
    let quick = quick_mode();
    let days = if quick { 1.0 } else { 7.0 };
    let scale = if quick { opts.scale.min(0.05) } else { opts.scale };
    let strategies = [Strategy::Reactive, Strategy::LtUa, Strategy::Chiron];

    let scens = scenarios(days);
    let mut labels = Vec::new();
    let mut cfgs = Vec::new();
    for (name, plan) in &scens {
        for &strategy in &strategies {
            labels.push(*name);
            cfgs.push(SimConfig {
                trace: TraceConfig {
                    epoch: Epoch::Jul2025,
                    days,
                    scale,
                    seed: opts.seed,
                    start_weekday: 0,
                    ..Default::default()
                },
                strategy,
                faults: plan.clone(),
                pjrt_forecaster: opts.pjrt,
                artifacts_dir: opts.artifacts_dir.clone(),
                ..Default::default()
            });
        }
    }
    println!(
        "  running {} fault runs ({} scenarios × {} strategies, {days} day(s)) in parallel ...",
        cfgs.len(),
        scens.len(),
        strategies.len()
    );
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (label, res) in labels.iter().zip(&results) {
        let f = &res.metrics.failures;
        assert_eq!(
            f.shed_interactive_total(),
            0,
            "graceful degradation must never shed interactive traffic"
        );
        let amp = f.retry_amplification(res.metrics.completed);
        let attainment = iw_sla_attainment(&res.metrics);
        // Worst incident: the longest fault-start→capacity-restored gap.
        // Incidents the run ended on (never recovered) report blank.
        let ttr = f
            .incidents
            .iter()
            .filter_map(|i| i.time_to_recover())
            .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.max(t))));
        let ttr_cell = ttr.map_or(String::new(), |t| format!("{t:.0}"));
        rows.push(format!(
            "{label},{},{},{},{},{},{},{amp:.4},{attainment:.4},{ttr_cell}",
            res.strategy.name(),
            res.metrics.completed,
            f.killed_total(),
            f.retries,
            f.lost_total(),
            f.shed_total(),
        ));
        table.push(vec![
            label.to_string(),
            res.strategy.name().into(),
            f.killed_total().to_string(),
            f.retries.to_string(),
            f.lost_total().to_string(),
            f.shed_total().to_string(),
            format!("{amp:.3}"),
            format!("{:.2}%", attainment * 100.0),
            ttr.map_or("-".into(), |t| format!("{:.1} h", t / HOUR)),
        ]);
    }
    opts.csv(
        "fault_recovery.csv",
        "scenario,strategy,completed,killed,retried,lost,shed,\
         retry_amplification,iw_sla_attainment,time_to_recover_s",
        &rows,
    )?;
    print_table(
        "Fault ablation — failure accounting and recovery per strategy \
         (expect: forecast-aware strategies re-provision around the dark \
          region; retry amplification stays near 1; interactive work is \
          never shed)",
        &[
            "scenario", "strategy", "killed", "retried", "lost", "shed", "retry-amp",
            "IW SLA", "worst TTR",
        ],
        &table,
    );
    Ok(())
}
