//! §5 — ILP solver runtime table (paper: 1.41 s at l=4, r=3, g=1;
//! 33 s at l=20, r=20, g=5 with a commercial solver).
//!
//! Our formulation decouples per model, so an (l, r, g) problem is l
//! independent (r, g) ILPs — we report the summed wall time.

use anyhow::Result;
use std::time::Instant;

use crate::config::{ModelKind, Region, Tier};
use crate::experiments::{print_table, ExpOptions};
use crate::forecast::{mape, Forecaster, NativeArForecaster, SeasonalNaive};
use crate::opt::capacity::{optimize_capacity, synthetic_inputs};
use crate::trace::generator::{TraceConfig, TraceGenerator};

pub fn solver_table(opts: &ExpOptions) -> Result<()> {
    let cases = [(4usize, 3usize, 1usize), (8, 6, 2), (12, 10, 3), (20, 20, 5)];
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (l, r, g) in cases {
        let started = Instant::now();
        let mut solved = 0usize;
        for model in 0..l {
            let inp = synthetic_inputs(r, g, (model as u64) * 7919 + opts.seed);
            if optimize_capacity(&inp).is_some() {
                solved += 1;
            }
        }
        let secs = started.elapsed().as_secs_f64();
        rows.push(format!("{l},{r},{g},{solved},{secs:.4}"));
        let paper = match (l, r, g) {
            (4, 3, 1) => "1.41 s",
            (20, 20, 5) => "33 s",
            _ => "—",
        };
        table.push(vec![
            format!("l={l} r={r} g={g}"),
            solved.to_string(),
            format!("{secs:.3} s"),
            paper.to_string(),
        ]);
    }
    opts.csv("ilp_solver_runtime.csv", "models,regions,gpus,solved,seconds", &rows)?;
    print_table(
        "§5 — capacity ILP solve time (ours: exact B&B, per-model decomposition)",
        &["size", "solved", "time", "paper"],
        &table,
    );
    Ok(())
}


/// §6.3 support — "ARIMA is accurate enough to forecast the diurnal load":
/// rolling-origin next-hour MAPE of the seasonal-AR pipeline vs the
/// seasonal-naive baseline on the generator's IW traffic (with its Poisson
/// sampling noise).
pub fn forecast_accuracy(opts: &ExpOptions) -> Result<()> {
    let gen = TraceGenerator::new(TraceConfig {
        days: 9.0,
        scale: opts.scale,
        seed: opts.seed,
        bursts: true,
        ..Default::default()
    });
    // Build sampled 15-min input-TPS series per (model, region) from an
    // actual trace (so the forecaster sees arrival noise, not the rate fn).
    let buckets = (9.0 * 86_400.0 / 900.0) as usize;
    let keys: Vec<(ModelKind, Region)> = gen
        .cfg
        .models
        .iter()
        .flat_map(|&m| Region::ALL.into_iter().map(move |r| (m, r)))
        .collect();
    let mut series = vec![vec![0.0f64; buckets]; keys.len()];
    for req in gen.stream() {
        if req.tier == Tier::Niw {
            continue;
        }
        let idx = (req.arrival / 900.0) as usize;
        if idx < buckets {
            let k = keys.iter().position(|&(m, r)| m == req.model && r == req.origin).unwrap();
            series[k][idx] += req.input_tokens as f64 / 900.0;
        }
    }
    let mut ar = NativeArForecaster::new(96, 8, 4);
    let mut naive = SeasonalNaive::new(96, 4);
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (fc_name, fc) in [("seasonal-ar", &mut ar as &mut dyn Forecaster),
                          ("seasonal-naive", &mut naive as &mut dyn Forecaster)] {
        let mut errs = Vec::new();
        // Rolling origins over the last 2 days, every 6 hours.
        let mut origin = 7 * 96;
        while origin + 4 <= buckets {
            let hist: Vec<Vec<f64>> = series.iter().map(|s| s[..origin].to_vec()).collect();
            let preds = fc.forecast(&hist);
            for (k, p) in preds.iter().enumerate() {
                let actual = &series[k][origin..origin + 4];
                if actual.iter().sum::<f64>() > 1.0 {
                    errs.push(mape(p, actual));
                }
            }
            origin += 24;
        }
        let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        rows.push(format!("{fc_name},{mean:.4}"));
        table.push(vec![fc_name.to_string(), format!("{:.1}%", mean * 100.0)]);
    }
    opts.csv("forecast_accuracy.csv", "forecaster,mean_mape", &rows)?;
    print_table(
        "§6.3 — next-hour forecast MAPE on sampled IW traffic \
         (rolling origins; paper: ARIMA 'accurate enough' for diurnal load)",
        &["forecaster", "mean MAPE"],
        &table,
    );
    Ok(())
}
