//! §5 — ILP solver runtime table (paper: 1.41 s at l=4, r=3, g=1;
//! 33 s at l=20, r=20, g=5 with a commercial solver).
//!
//! Our formulation decouples per model, so an (l, r, g) problem is l
//! independent (r, g) ILPs — we report the summed wall time.  Three
//! solve modes per size:
//!
//! * **cold** — the bounded-variable B&B from an empty [`CapacitySolver`]
//!   (first epoch after a controller restart);
//! * **warm** — the next epoch: demand drifted 2%, re-solved through the
//!   same solver state (rhs swap + dual re-solve from the old basis);
//! * **old** — the pre-bounded dense tableau path
//!   ([`optimize_capacity_dense`]), kept as the equivalence oracle.
//!   Skipped at (20, 20, 10): its explicit bound rows make the tableau
//!   ~5× taller and it no longer finishes in experiment time there —
//!   which is the point of the rewrite.
//!
//! `SAGESERVE_EXP_QUICK=1` (the `make verify` smoke set) drops to the two
//! smallest sizes.

use anyhow::Result;
use std::time::Instant;

use crate::config::{ModelKind, Region, Tier};
use crate::experiments::{print_table, ExpOptions};
use crate::forecast::{mape, Forecaster, NativeArForecaster, SeasonalNaive};
use crate::opt::capacity::{
    optimize_capacity_dense, optimize_capacity_warm, perturb_inputs, synthetic_inputs,
    CapacitySolver,
};
use crate::trace::generator::{TraceConfig, TraceGenerator};

/// Same convention as `experiments::faults` / `SAGESERVE_BENCH_QUICK`.
fn quick_mode() -> bool {
    std::env::var("SAGESERVE_EXP_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Time the capacity ILP across problem sizes (Table: solver runtime)
/// and write `ilp_runtime.csv`.
pub fn solver_table(opts: &ExpOptions) -> Result<()> {
    let full: &[(usize, usize, usize)] =
        &[(4, 3, 1), (8, 6, 2), (12, 10, 3), (20, 20, 5), (20, 20, 10)];
    let cases: &[(usize, usize, usize)] = if quick_mode() { &full[..2] } else { full };
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for &(l, r, g) in cases {
        // Dense-oracle column: the old path's tableau is
        // (3rg + r + 1) × (2rg + slacks) — feasible through (20,20,5),
        // far too slow at (20,20,10).
        let dense_ok = r * g <= 100;

        // Cold pass: fresh state per model, keep the states and plans.
        let mut solvers: Vec<CapacitySolver> = (0..l).map(|_| CapacitySolver::new()).collect();
        let mut plans = Vec::with_capacity(l);
        let mut solved = 0usize;
        let (mut pivots_cold, mut nodes) = (0u64, 0usize);
        let started = Instant::now();
        for model in 0..l {
            let inp = synthetic_inputs(r, g, (model as u64) * 7919 + opts.seed);
            let plan = optimize_capacity_warm(&inp, &mut solvers[model]);
            if let Some(p) = &plan {
                solved += 1;
                pivots_cold += p.pivots;
                nodes += p.nodes;
            }
            plans.push((inp, plan));
        }
        let cold_s = started.elapsed().as_secs_f64();

        // Warm pass: drift demand 2% and re-solve through the same state
        // (the controller's epoch-over-epoch path).
        let mut pivots_warm = 0u64;
        let started = Instant::now();
        for model in 0..l {
            let (inp, plan) = &plans[model];
            if let Some(p) = plan {
                let next = perturb_inputs(inp, p, 0.02);
                if let Some(wp) = optimize_capacity_warm(&next, &mut solvers[model]) {
                    pivots_warm += wp.pivots;
                }
            }
        }
        let warm_s = started.elapsed().as_secs_f64();

        // Old dense path on the identical instances.
        let old_s = if dense_ok {
            let started = Instant::now();
            for (inp, _) in &plans {
                let _ = optimize_capacity_dense(inp);
            }
            started.elapsed().as_secs_f64()
        } else {
            f64::NAN
        };

        let speedup = if warm_s > 0.0 { cold_s / warm_s } else { f64::NAN };
        rows.push(format!(
            "{l},{r},{g},{solved},{cold_s:.4},{warm_s:.4},{},{speedup:.1},{pivots_cold},{pivots_warm},{nodes}",
            if dense_ok { format!("{old_s:.4}") } else { String::new() },
        ));
        let paper = match (l, r, g) {
            (4, 3, 1) => "1.41 s",
            (20, 20, 5) => "33 s",
            _ => "—",
        };
        table.push(vec![
            format!("l={l} r={r} g={g}"),
            solved.to_string(),
            format!("{cold_s:.3} s"),
            format!("{warm_s:.3} s ({speedup:.0}x)"),
            if dense_ok { format!("{old_s:.3} s") } else { "(skipped)".into() },
            paper.to_string(),
        ]);
    }
    if quick_mode() {
        println!("  (quick mode: {} of {} sizes)", cases.len(), full.len());
    }
    opts.csv(
        "ilp_solver_runtime.csv",
        "models,regions,gpus,solved,cold_s,warm_s,old_s,warm_speedup,pivots_cold,pivots_warm,nodes",
        &rows,
    )?;
    print_table(
        "§5 — capacity ILP solve time (ours: bounded-variable B&B, per-model decomposition)",
        &["size", "solved", "cold", "warm re-solve", "old dense", "paper"],
        &table,
    );
    Ok(())
}


/// §6.3 support — "ARIMA is accurate enough to forecast the diurnal load":
/// rolling-origin next-hour MAPE of the seasonal-AR pipeline vs the
/// seasonal-naive baseline on the generator's IW traffic (with its Poisson
/// sampling noise).
pub fn forecast_accuracy(opts: &ExpOptions) -> Result<()> {
    let gen = TraceGenerator::new(TraceConfig {
        days: 9.0,
        scale: opts.scale,
        seed: opts.seed,
        bursts: true,
        ..Default::default()
    });
    // Build sampled 15-min input-TPS series per (model, region) from an
    // actual trace (so the forecaster sees arrival noise, not the rate fn).
    let buckets = (9.0 * 86_400.0 / 900.0) as usize;
    let keys: Vec<(ModelKind, Region)> = gen
        .cfg
        .models
        .iter()
        .flat_map(|&m| Region::ALL.into_iter().map(move |r| (m, r)))
        .collect();
    let mut series = vec![vec![0.0f64; buckets]; keys.len()];
    for req in gen.stream() {
        if req.tier == Tier::Niw {
            continue;
        }
        let idx = (req.arrival / 900.0) as usize;
        if idx < buckets {
            let k = keys.iter().position(|&(m, r)| m == req.model && r == req.origin).unwrap();
            series[k][idx] += req.input_tokens as f64 / 900.0;
        }
    }
    let mut ar = NativeArForecaster::new(96, 8, 4);
    let mut naive = SeasonalNaive::new(96, 4);
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (fc_name, fc) in [("seasonal-ar", &mut ar as &mut dyn Forecaster),
                          ("seasonal-naive", &mut naive as &mut dyn Forecaster)] {
        let mut errs = Vec::new();
        // Rolling origins over the last 2 days, every 6 hours.
        let mut origin = 7 * 96;
        while origin + 4 <= buckets {
            let hist: Vec<Vec<f64>> = series.iter().map(|s| s[..origin].to_vec()).collect();
            let preds = fc.forecast(&hist);
            for (k, p) in preds.iter().enumerate() {
                let actual = &series[k][origin..origin + 4];
                if actual.iter().sum::<f64>() > 1.0 {
                    errs.push(mape(p, actual));
                }
            }
            origin += 24;
        }
        let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        rows.push(format!("{fc_name},{mean:.4}"));
        table.push(vec![fc_name.to_string(), format!("{:.1}%", mean * 100.0)]);
    }
    opts.csv("forecast_accuracy.csv", "forecaster,mean_mape", &rows)?;
    print_table(
        "§6.3 — next-hour forecast MAPE on sampled IW traffic \
         (rolling origins; paper: ARIMA 'accurate enough' for diurnal load)",
        &["forecaster", "mean MAPE"],
        &table,
    );
    Ok(())
}
