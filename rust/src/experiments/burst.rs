//! §7.2.7 / Fig 16a — burst management: 8× synthetic traffic spikes;
//! LT-UA's forecast-gap override vs LT-I / LT-U.  The three strategy
//! runs share one pre-materialized trace and execute concurrently
//! through the sweep runner.

use anyhow::Result;

use crate::config::{Epoch, ModelKind, Tier, HOUR};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// Run the burst-management ablation and write `fig16a_burst.csv`.
pub fn fig16a(opts: &ExpOptions) -> Result<()> {
    let strategies = [Strategy::LtI, Strategy::LtU, Strategy::LtUa];
    let cfgs: Vec<SimConfig> = strategies
        .iter()
        .map(|&strategy| SimConfig {
            trace: TraceConfig {
                epoch: Epoch::Jul2025,
                days: 1.0,
                scale: opts.scale,
                seed: opts.seed,
                start_weekday: 2,
                bursts: true,
                // The paper injects ~8x spikes; our bursts are 2–4x base,
                // so amplify ~2.7x to land in the 5–10x band — and stretch
                // them to 25–50 min so spikes overlap LT-UA's end-of-hour
                // correction window (§6.4).
                burst_amplitude: 2.7,
                burst_minutes: (25.0, 50.0),
                ..Default::default()
            },
            strategy,
            pjrt_forecaster: opts.pjrt,
            artifacts_dir: opts.artifacts_dir.clone(),
            ..Default::default()
        })
        .collect();
    println!("  running {} strategies under 8x bursts in parallel ...", cfgs.len());
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut table = Vec::new();
    for sim in &results {
        // Peak-window latency: worst 1-hour p95 TTFT across the day (IW),
        // binned in one pass over the outcomes.
        let end = sim.end_time;
        let mut worst_p95 = 0.0f64;
        for s in sim.metrics.interactive_latency_bins(ModelKind::Llama2_70B, HOUR, end) {
            if s.count > 20 {
                worst_p95 = worst_p95.max(s.ttft_p95);
            }
        }
        // Streaming tier summary — no outcome log to re-scan.
        let overall = sim.metrics.latency_by_tier(Tier::IwF);
        let util = sim.metrics.mean_util(ModelKind::Llama2_70B);
        let ih = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, end);
        rows.push(format!(
            "{},{worst_p95:.3},{:.3},{util:.4},{ih:.2}",
            sim.strategy.name(),
            overall.ttft_p95
        ));
        table.push(vec![
            sim.strategy.name().into(),
            format!("{worst_p95:.2}"),
            format!("{:.2}", overall.ttft_p95),
            format!("{util:.2}"),
            format!("{ih:.1}"),
        ]);
    }
    opts.csv(
        "fig16a_burst_response.csv",
        "strategy,worst_hour_p95_ttft,overall_iwf_p95_ttft,mean_util,inst_hours",
        &rows,
    )?;
    print_table(
        "Fig 16a — 8x burst response (paper: LT-UA recovers fastest; LT-I/LT-U \
         cap at the forecast ceiling)",
        &["strategy", "worst-hr p95 TTFT", "IW-F p95 TTFT", "mean util", "inst-h"],
        &table,
    );
    Ok(())
}
