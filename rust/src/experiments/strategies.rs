//! Strategy-comparison experiments: Fig 8 + Table 1 (Siloed vs Unified),
//! Figs 11–13 (Reactive vs LT-* vs Chiron), the Nov-2024 validation
//! (§7.2.7) and the hardware / tier-mix ablations (§7.2.8).
//!
//! Every strategy×scenario grid here runs through the parallel sweep
//! runner (`experiments::sweep`) — simulations are independent and
//! deterministic, so the wall-clock drops to the slowest single run while
//! the reported numbers stay identical to sequential execution.  The
//! runner also materializes each distinct trace exactly once and shares
//! the arrival buffer across the grid's strategies (generate once,
//! replay many — see `sweep::share_traces`).

use anyhow::Result;

use crate::config::{Epoch, FleetSpec, GpuKind, ModelKind, Region, Tier, HOUR};
use crate::experiments::sweep::{run_configs, RunResult};
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

fn base_cfg(opts: &ExpOptions, epoch: Epoch, days: f64, strategy: Strategy) -> SimConfig {
    // The Nov-2024 epoch carries 1/5 the Jul-2025 volume; compensate the
    // scale so Nov experiments exercise the same scaling dynamics (the
    // paper's Nov cluster was sized for its own load — all comparisons
    // are strategy-relative).
    let scale = match epoch {
        Epoch::Nov2024 => opts.scale * 5.0,
        Epoch::Jul2025 => opts.scale,
    };
    SimConfig {
        trace: TraceConfig {
            epoch,
            days,
            scale,
            seed: opts.seed,
            // Start on the peak weekday: Wednesday (0 = Monday).
            start_weekday: 2,
            ..Default::default()
        },
        strategy,
        pjrt_forecaster: opts.pjrt,
        artifacts_dir: opts.artifacts_dir.clone(),
        ..Default::default()
    }
}

/// Fig 8 + Table 1 — Siloed vs Unified-Reactive on the Nov-2024 West-US
/// Tuesday trace (4 models, 8×A100, 20 instances/model).
pub fn fig8_table1(opts: &ExpOptions) -> Result<()> {
    let strategies = [Strategy::Siloed, Strategy::Reactive];
    let cfgs: Vec<SimConfig> = strategies
        .iter()
        .map(|&strategy| {
            let mut cfg = base_cfg(opts, Epoch::Nov2024, 1.0, strategy);
            cfg.trace.start_weekday = 1; // Tuesday
            cfg.fleet = FleetSpec::homogeneous(GpuKind::A100x8);
            cfg
        })
        .collect();
    println!("  running {} strategies in parallel ...", cfgs.len());
    let results = run_configs(cfgs);

    // (a) instance counts over time (15-min samples) + instance-hours.
    let mut rows = Vec::new();
    let mut ih_table = Vec::new();
    for r in &results {
        let end = r.end_time;
        for &m in &r.models {
            let ledger = r
                .metrics
                .instances
                .iter()
                .filter(|((lm, lr), _)| *lm == m && *lr == Region::WestUs)
                .map(|(_, l)| l)
                .next();
            if let Some(l) = ledger {
                for (t, c) in l.sample(end, 900.0) {
                    rows.push(format!("{},{m},{:.2},{c}", r.strategy.name(), t / HOUR));
                }
            }
            let ih: f64 = r
                .metrics
                .instances
                .iter()
                .filter(|((lm, lr), _)| *lm == m && *lr == Region::WestUs)
                .map(|(_, l)| l.instance_hours(end))
                .sum();
            ih_table.push(vec![r.strategy.name().into(), m.to_string(), format!("{ih:.1}")]);
        }
    }
    opts.csv("fig8a_instance_counts_westus.csv", "strategy,model,hour,instances", &rows)?;
    print_table("Fig 8a — West-US instance-hours per model", &["strategy", "model", "inst-h"], &ih_table);

    let total_ih = |r: &RunResult| -> f64 {
        r.metrics
            .instances
            .iter()
            .filter(|((_, reg), _)| *reg == Region::WestUs)
            .map(|(_, l)| l.instance_hours(r.end_time))
            .sum()
    };
    let siloed_ih = total_ih(&results[0]);
    let unified_ih = total_ih(&results[1]);
    let spot_h: f64 = results[1].metrics.spot_hours(results[1].end_time);
    println!(
        "\n  West-US totals: Siloed {siloed_ih:.1} inst-h vs Unified {unified_ih:.1} inst-h \
         ({:.1}% fewer; paper: 34.5% fewer).  Unified donated {spot_h:.0} instance-hours to spot.",
        (1.0 - unified_ih / siloed_ih) * 100.0
    );

    // (b) memory utilization.
    let mut util_rows = Vec::new();
    for r in &results {
        for &m in &r.models {
            let u = r.metrics.mean_util(m);
            util_rows.push(format!("{},{m},{u:.4}", r.strategy.name()));
        }
    }
    opts.csv("fig8b_memory_util.csv", "strategy,model,mean_util", &util_rows)?;

    // Table 1 — p95 TTFT and E2E per model under both strategies.
    // Interactive traffic only: NIW is *designed* to defer (queue-manager
    // release / 24 h deadline), so its queueing time would swamp a joint
    // p95 without being an SLA signal.  One grouping pass per strategy
    // instead of a full outcome re-scan per table cell.
    let summaries: Vec<_> = results.iter().map(|r| r.metrics.interactive_latency_by_model()).collect();
    let mut table = Vec::new();
    let mut rows = Vec::new();
    for &m in &results[0].models {
        let mut line = vec![m.to_string()];
        for (r, by_model) in results.iter().zip(&summaries) {
            let s = by_model.get(&m).cloned().unwrap_or_default();
            line.push(format!("{:.1}", s.ttft_p95));
            line.push(format!("{:.1}", s.e2e_p95));
            rows.push(format!("{},{m},{:.3},{:.3}", r.strategy.name(), s.ttft_p95, s.e2e_p95));
        }
        table.push(line);
    }
    opts.csv("table1_latency_p95.csv", "strategy,model,ttft_p95,e2e_p95", &rows)?;
    print_table(
        "Table 1 — IW p95 latency (s): [siloed ttft, siloed e2e, unified ttft, unified e2e] \
         (paper: unified within 12% of siloed TTFT, E2E near-identical)",
        &["model", "sil ttft", "sil e2e", "uni ttft", "uni e2e"],
        &table,
    );
    Ok(())
}

/// The shared Fig 11/12/13 run: all five strategies on the Jul-2025 peak
/// day, 4 models, 3 regions — concurrently.
pub fn fig11_12_13(opts: &ExpOptions) -> Result<()> {
    let strategies = [Strategy::Reactive, Strategy::LtI, Strategy::LtU, Strategy::LtUa, Strategy::Chiron];
    let cfgs: Vec<SimConfig> =
        strategies.iter().map(|&s| base_cfg(opts, Epoch::Jul2025, 1.0, s)).collect();
    println!("  running {} strategies in parallel ...", cfgs.len());
    let sims = run_configs(cfgs);
    let focus = ModelKind::Llama2_70B;

    // ---- Fig 11: hourly instance counts + instance-hours (Llama-2) ----
    let mut rows = Vec::new();
    let mut table = Vec::new();
    let mut reactive_ih = 0.0;
    for sim in &sims {
        let end = sim.end_time;
        let name = sim.strategy.name();
        // Aggregated across regions, sampled hourly.
        let mut hourly = vec![0usize; (end / HOUR) as usize + 1];
        for ((m, _), ledger) in &sim.metrics.instances {
            if *m != focus {
                continue;
            }
            for (h, slot) in hourly.iter_mut().enumerate() {
                *slot += ledger.count_at(h as f64 * HOUR);
            }
        }
        for (h, c) in hourly.iter().enumerate() {
            rows.push(format!("{name},{h},{c}"));
        }
        let ih = sim.metrics.model_instance_hours(focus, end);
        if sim.strategy == Strategy::Reactive {
            reactive_ih = ih;
        }
        let savings = if sim.strategy == Strategy::Reactive || reactive_ih == 0.0 {
            "—".to_string()
        } else {
            format!("{:+.1}%", (ih / reactive_ih - 1.0) * 100.0)
        };
        table.push(vec![name.into(), format!("{ih:.2}"), savings]);
    }
    opts.csv("fig11_instance_hours_llama2.csv", "strategy,hour,instances", &rows)?;
    print_table(
        "Fig 11 — Llama-2 instance-hours, 3 regions, peak day \
         (paper: Reactive 362, LT-I 274 (-24%), LT-U 291 (-20%), LT-UA 277 (-23%), Chiron 1146)",
        &["strategy", "inst-hours", "vs reactive"],
        &table,
    );
    // Dollar extrapolation as in §7.2.1.
    if reactive_ih > 0.0 {
        let lt_ua_ih: f64 = sims
            .iter()
            .find(|s| s.strategy == Strategy::LtUa)
            .map(|s| s.metrics.model_instance_hours(focus, s.end_time))
            .unwrap_or(reactive_ih);
        let saved_per_day = (reactive_ih - lt_ua_ih).max(0.0);
        let dollars = saved_per_day * 98.32 * 3.0 * 4.0 * 7.0 / opts.scale.max(1e-9);
        println!(
            "  extrapolated full-scale savings ≈ ${:.2}M/week (paper: ≈$0.6M/week, $2.5M/month)",
            dollars / 1e6
        );
    }

    // ---- Fig 12: per-region instance-hours + memory utilization ----
    let mut rows = Vec::new();
    for sim in &sims {
        let end = sim.end_time;
        for region in Region::ALL {
            let ih: f64 = sim
                .metrics
                .instances
                .iter()
                .filter(|((m, r), _)| *m == focus && *r == region)
                .map(|(_, l)| l.instance_hours(end))
                .sum();
            rows.push(format!("{},{region},{ih:.2}", sim.strategy.name()));
        }
    }
    opts.csv("fig12a_per_region_instance_hours.csv", "strategy,region,inst_hours", &rows)?;
    let mut rows = Vec::new();
    for sim in &sims {
        rows.push(format!("{},{:.4}", sim.strategy.name(), sim.metrics.mean_util(focus)));
    }
    opts.csv("fig12b_memory_util.csv", "strategy,mean_util", &rows)?;

    // ---- Fig 13a: p75 latency; 13b: GPU-hours wasted on scaling ----
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for sim in &sims {
        let iw = sim
            .metrics
            .interactive_latency_by_model()
            .get(&focus)
            .cloned()
            .unwrap_or_default();
        rows.push(format!(
            "{},{:.3},{:.3}",
            sim.strategy.name(),
            iw.ttft_p75,
            iw.e2e_p75
        ));
        let waste = sim.metrics.scaling_waste.total_gpu_hours();
        let events = sim.metrics.scaling_waste.total_events();
        table.push(vec![
            sim.strategy.name().into(),
            format!("{:.2}", iw.ttft_p75),
            format!("{:.2}", iw.e2e_p75),
            format!("{waste:.2}"),
            events.to_string(),
        ]);
    }
    opts.csv("fig13a_latency_p75.csv", "strategy,ttft_p75,e2e_p75", &rows)?;
    let mut rows = Vec::new();
    for sim in &sims {
        for (cause, (n, secs)) in &sim.metrics.scaling_waste.by_cause {
            rows.push(format!("{},{cause},{n},{:.2}", sim.strategy.name(), secs / 3600.0));
        }
    }
    opts.csv("fig13b_scaling_waste.csv", "strategy,cause,events,gpu_hours", &rows)?;
    print_table(
        "Fig 13 — p75 latency (IW, Llama-2) and scaling waste \
         (paper: LT-* cut wasted GPU-hours ~70%)",
        &["strategy", "ttft p75 (s)", "e2e p75 (s)", "waste (GPU-h)", "scale events"],
        &table,
    );
    Ok(())
}

/// §7.2.7 — Nov-2024 peak-day validation (paper: 302 / 227 / 248 / 233
/// instance-hours for Reactive / LT-I / LT-U / LT-UA).
pub fn nov24_validation(opts: &ExpOptions) -> Result<()> {
    let strategies = [Strategy::Reactive, Strategy::LtI, Strategy::LtU, Strategy::LtUa];
    let cfgs: Vec<SimConfig> = strategies
        .iter()
        .map(|&s| {
            let mut cfg = base_cfg(opts, Epoch::Nov2024, 1.0, s);
            cfg.trace.start_weekday = 1;
            cfg
        })
        .collect();
    println!("  running {} strategies in parallel ...", cfgs.len());
    let results = run_configs(cfgs);
    let mut table = Vec::new();
    let mut rows = Vec::new();
    let mut reactive_ih = 0.0;
    for r in &results {
        let ih = r.metrics.model_instance_hours(ModelKind::Llama2_70B, r.end_time);
        if r.strategy == Strategy::Reactive {
            reactive_ih = ih;
        }
        let rel = if reactive_ih > 0.0 { format!("{:+.1}%", (ih / reactive_ih - 1.0) * 100.0) } else { "—".into() };
        rows.push(format!("{},{ih:.2}", r.strategy.name()));
        table.push(vec![r.strategy.name().into(), format!("{ih:.2}"), rel]);
    }
    opts.csv("nov24_instance_hours.csv", "strategy,inst_hours", &rows)?;
    print_table(
        "§7.2.7 — Nov-2024 Llama-2 instance-hours (paper: 302/227/248/233, ≈25% savings)",
        &["strategy", "inst-hours", "vs reactive"],
        &table,
    );
    Ok(())
}

/// §7.2.8 — ablations: A100 hardware; IW:NIW ratios 9:1 and 1:1.  All
/// eight (setting × strategy) runs execute concurrently.
pub fn ablations(opts: &ExpOptions) -> Result<()> {
    type Mutator = Box<dyn Fn(&mut SimConfig)>;
    let settings: Vec<(&str, Mutator)> = vec![
        ("h100-baseline", Box::new(|_: &mut SimConfig| {})),
        ("a100", Box::new(|cfg: &mut SimConfig| {
            cfg.fleet = FleetSpec::homogeneous(GpuKind::A100x8)
        })),
        ("iw-niw-9to1", Box::new(|cfg: &mut SimConfig| cfg.trace.iw_niw_ratio = Some(9.0))),
        ("iw-niw-1to1", Box::new(|cfg: &mut SimConfig| cfg.trace.iw_niw_ratio = Some(1.0))),
    ];
    let mut cfgs = Vec::new();
    for (_, mutate) in &settings {
        for s in [Strategy::Reactive, Strategy::LtUa] {
            let mut cfg = base_cfg(opts, Epoch::Jul2025, 1.0, s);
            mutate(&mut cfg);
            cfgs.push(cfg);
        }
    }
    println!("  running {} (setting × strategy) simulations in parallel ...", cfgs.len());
    let results = run_configs(cfgs);

    let mut table = Vec::new();
    let mut rows = Vec::new();
    for (pair, (label, _)) in results.chunks(2).zip(&settings) {
        let ihs: Vec<f64> = pair
            .iter()
            .map(|r| r.metrics.model_instance_hours(ModelKind::Llama2_70B, r.end_time))
            .collect();
        let saving = (1.0 - ihs[1] / ihs[0]) * 100.0;
        rows.push(format!("{label},{:.2},{:.2},{saving:.1}", ihs[0], ihs[1]));
        table.push(vec![
            label.to_string(),
            format!("{:.1}", ihs[0]),
            format!("{:.1}", ihs[1]),
            format!("{saving:.1}%"),
        ]);
    }
    opts.csv("ablations.csv", "setting,reactive_ih,ltua_ih,savings_pct", &rows)?;
    print_table(
        "§7.2.8 — ablations, LT-UA vs Reactive Llama-2 instance-hours \
         (paper: A100 -28.2%, 9:1 -26.3%, 1:1 -22%)",
        &["setting", "reactive", "lt-ua", "savings"],
        &table,
    );
    let _ = Tier::IwF; // silence unused import lint paths in some configs
    Ok(())
}
