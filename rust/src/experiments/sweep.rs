//! Parallel experiment sweep: run independent simulations on scoped
//! threads and collect results in input order.
//!
//! The simulator is deterministic and shares no state between runs (each
//! builds its own trace generator, cluster and forecaster from the
//! config), so a parallel sweep produces results *identical* to running
//! the same configs sequentially — asserted by
//! `tests/perf_invariants.rs`.  `Simulation` itself stays on the worker
//! thread (its boxed forecaster need not be `Send`); only the plain-data
//! [`RunResult`] crosses back.
//!
//! Set `SAGESERVE_SEQUENTIAL=1` to force sequential execution (profiling
//! a single run, or bisecting a suspected nondeterminism).

use std::thread;

use crate::config::ModelKind;
use crate::metrics::Metrics;
use crate::sim::engine::{run_simulation, SimConfig, Strategy};

/// Run `f` over `items`, one scoped thread per item, results in input
/// order.  A thread panic propagates to the caller.
pub fn sweep<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let sequential = items.len() <= 1
        || std::env::var("SAGESERVE_SEQUENTIAL").map_or(false, |v| !v.is_empty() && v != "0");
    if sequential {
        return items.into_iter().map(f).collect();
    }
    let f = &f;
    thread::scope(|s| {
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| s.spawn(move || f(item)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    })
}

/// Everything the experiment reports read from a finished simulation,
/// detached from the `Simulation` so it can cross threads.
pub struct RunResult {
    pub strategy: Strategy,
    pub end_time: f64,
    pub metrics: Metrics,
    pub models: Vec<ModelKind>,
}

/// Run a batch of simulation configs concurrently (strategy×scenario
/// grids of `fig8`/`fig11–13`/`ablations`/`week`).  Results are in config
/// order and identical to sequential execution.
pub fn run_configs(cfgs: Vec<SimConfig>) -> Vec<RunResult> {
    sweep(cfgs, |cfg| {
        let sim = run_simulation(cfg);
        let end_time = sim.end_time();
        RunResult {
            strategy: sim.cfg.strategy,
            end_time,
            models: sim.cfg.trace.models.clone(),
            metrics: sim.metrics,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_input_order() {
        let out = sweep((0..32).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..32).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sweep_handles_empty_and_single() {
        let empty: Vec<i32> = Vec::new();
        assert!(sweep(empty, |x: i32| x).is_empty());
        assert_eq!(sweep(vec![7], |x| x + 1), vec![8]);
    }
}
