//! Parallel experiment sweep: run independent simulations on a bounded
//! worker pool of scoped threads and collect results in input order.
//!
//! The simulator is deterministic and shares no mutable state between
//! runs (each builds its own cluster and forecaster from the config), so
//! a parallel sweep produces results *identical* to running the same
//! configs sequentially — asserted by `tests/perf_invariants.rs`.
//! `Simulation` itself stays on the worker thread (its boxed forecaster
//! need not be `Send`); only the plain-data [`RunResult`] crosses back.
//!
//! Two resource controls:
//! * the pool is capped at `available_parallelism` workers, so grids
//!   larger than the core count queue instead of oversubscribing;
//! * [`share_traces`] pre-materializes each *distinct* trace config
//!   once (chunk-parallel) and hands every strategy run the same
//!   `Arc<[Request]>` buffer — a grid of S strategies over one scenario
//!   generates its trace once, not S times.
//!
//! Set `SAGESERVE_SEQUENTIAL=1` to force sequential execution (profiling
//! a single run, or bisecting a suspected nondeterminism).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

use crate::config::ModelKind;
use crate::metrics::Metrics;
use crate::sim::engine::{run_simulation, SimConfig, Strategy};
use crate::trace::generator::{TraceConfig, TraceGenerator};
use crate::trace::types::Request;

/// Run `f` over `items` on a worker pool capped at
/// `available_parallelism`, results in input order.  A worker panic
/// propagates to the caller (scoped threads re-raise on join).
pub fn sweep<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let sequential = items.len() <= 1
        || std::env::var("SAGESERVE_SEQUENTIAL").map_or(false, |v| !v.is_empty() && v != "0");
    if sequential {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let workers = thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n);
    // Each slot is claimed exactly once via the atomic cursor; Mutexes
    // carry items out to workers and results back without blocking
    // (every lock is uncontended by construction).
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let (f, slots_ref, results_ref, cursor_ref) = (&f, &slots, &results, &cursor);
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots_ref[i].lock().unwrap().take().expect("slot claimed once");
                let r = f(item);
                *results_ref[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("sweep worker completed"))
        .collect()
}

/// Everything the experiment reports read from a finished simulation,
/// detached from the `Simulation` so it can cross threads.
pub struct RunResult {
    /// Strategy the run executed under.
    pub strategy: Strategy,
    /// Trace end time (seconds) — the ledger-integration cutoff.
    pub end_time: f64,
    /// Full streaming metrics accumulator of the finished run.
    pub metrics: Metrics,
    /// Models the run served (drives per-model report rows).
    pub models: Vec<ModelKind>,
}

/// Pre-materialize each distinct trace config once and share the arrival
/// buffer across every config that uses it (generate once, replay many).
/// Configs already carrying a replay CSV or a shared buffer are left
/// untouched.  Generation itself is chunk-parallel
/// (`TraceGenerator::materialize`), and the buffer is byte-identical to
/// the streaming path, so downstream metrics are unchanged.
pub fn share_traces(cfgs: &mut [SimConfig]) {
    let mut cache: Vec<(TraceConfig, Arc<[Request]>)> = Vec::new();
    for cfg in cfgs.iter_mut() {
        if cfg.replay_trace.is_some() || cfg.shared_trace.is_some() {
            continue;
        }
        let buf = match cache.iter().find(|(tc, _)| *tc == cfg.trace) {
            Some((_, buf)) => buf.clone(),
            None => {
                let buf = TraceGenerator::new(cfg.trace.clone()).materialize_shared();
                cache.push((cfg.trace.clone(), buf.clone()));
                buf
            }
        };
        cfg.shared_trace = Some(buf);
    }
}

/// Run a batch of simulation configs concurrently (strategy×scenario
/// grids of `fig8`/`fig11–13`/`fig16a`/`ablations`/`week`).  Each
/// distinct trace is generated exactly once and shared; results are in
/// config order and identical to sequential streaming execution.
pub fn run_configs(mut cfgs: Vec<SimConfig>) -> Vec<RunResult> {
    share_traces(&mut cfgs);
    sweep(cfgs, |cfg| {
        let sim = run_simulation(cfg);
        let end_time = sim.end_time();
        RunResult {
            strategy: sim.cfg.strategy,
            end_time,
            models: sim.cfg.trace.models.clone(),
            metrics: sim.metrics,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_input_order() {
        let out = sweep((0..32).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..32).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sweep_handles_empty_and_single() {
        let empty: Vec<i32> = Vec::new();
        assert!(sweep(empty, |x: i32| x).is_empty());
        assert_eq!(sweep(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn sweep_handles_more_items_than_cores() {
        // Grids larger than the worker pool must still complete in order.
        let items: Vec<u64> = (0..257).collect();
        let out = sweep(items.clone(), |x| x + 1);
        assert_eq!(out, items.iter().map(|x| x + 1).collect::<Vec<_>>());
    }

    #[test]
    fn share_traces_dedups_identical_configs() {
        use crate::sim::engine::quick_config;
        let mut cfgs = vec![
            quick_config(Strategy::Reactive, 0.02, 0.004),
            quick_config(Strategy::LtUa, 0.02, 0.004),
        ];
        share_traces(&mut cfgs);
        let a = cfgs[0].shared_trace.as_ref().expect("buffer set");
        let b = cfgs[1].shared_trace.as_ref().expect("buffer set");
        // Same TraceConfig ⇒ literally the same allocation.
        assert!(Arc::ptr_eq(a, b));
        assert!(!a.is_empty());
    }
}
