//! §7.2.7 / Fig 16b — week-long validation: p95 TTFT/E2E in 3-hour bins
//! across a full week (diurnal + weekday/weekend patterns).  The three
//! strategy runs (the longest simulations in the suite) execute
//! concurrently through the parallel sweep runner.

use anyhow::Result;

use crate::config::{Epoch, ModelKind, HOUR};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// Run the week-long strategy comparison and write `fig16b_week.csv`.
pub fn fig16b(opts: &ExpOptions) -> Result<()> {
    let strategies = [Strategy::Reactive, Strategy::LtU, Strategy::LtUa];
    let cfgs: Vec<SimConfig> = strategies
        .iter()
        .map(|&strategy| SimConfig {
            trace: TraceConfig {
                epoch: Epoch::Jul2025,
                days: 7.0,
                scale: opts.scale,
                seed: opts.seed,
                start_weekday: 0,
                ..Default::default()
            },
            strategy,
            pjrt_forecaster: opts.pjrt,
            artifacts_dir: opts.artifacts_dir.clone(),
            ..Default::default()
        })
        .collect();
    println!("  running {} week-long strategies in parallel ...", cfgs.len());
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut summary_table = Vec::new();
    for sim in &results {
        let end = sim.end_time;
        let bin = 3.0 * HOUR;
        let mut worst = (0.0f64, 0.0f64);
        // One pass over the outcomes for all 56 bins (the old per-bin
        // filter re-scanned the full week of outcomes per bin).
        let bins = sim.metrics.interactive_latency_bins(ModelKind::Llama2_70B, bin, end);
        for (i, s) in bins.iter().enumerate() {
            if s.count > 10 {
                rows.push(format!(
                    "{},{:.1},{:.3},{:.3}",
                    sim.strategy.name(),
                    i as f64 * bin / HOUR,
                    s.ttft_p95,
                    s.e2e_p95
                ));
                worst = (worst.0.max(s.ttft_p95), worst.1.max(s.e2e_p95));
            }
        }
        let overall = sim
            .metrics
            .interactive_latency_by_model()
            .get(&ModelKind::Llama2_70B)
            .cloned()
            .unwrap_or_default();
        let ih = sim.metrics.model_instance_hours(ModelKind::Llama2_70B, end);
        summary_table.push(vec![
            sim.strategy.name().into(),
            format!("{:.2}", overall.ttft_p95),
            format!("{:.2}", worst.0),
            format!("{:.2}", worst.1),
            format!("{ih:.1}"),
        ]);
    }
    opts.csv("fig16b_week_latency_3h.csv", "strategy,hour,p95_ttft,p95_e2e", &rows)?;
    print_table(
        "Fig 16b — week-long Llama-2 IW latency (paper: Reactive inferior; \
         LT-U ≈ LT-UA on weekdays, LT-UA better at weekend transitions)",
        &["strategy", "p95 TTFT", "worst-bin TTFT", "worst-bin E2E", "inst-h (week)"],
        &summary_table,
    );
    Ok(())
}
