//! Month-scale validation: a 30-day trace (10 M req/day at `--scale 1`)
//! through the epoch-sliced chunked engine — the run length ROADMAP
//! item 1 targets and the sequential sweep path cannot reach, because a
//! materialized 30-day buffer is ~300 M requests (≈14 GiB at 48 B
//! each).  The chunked executor generates day-sized chunks on worker
//! threads and hands simulator state across each boundary, so peak
//! memory stays O(chunk) no matter how long the trace runs.
//!
//! Not part of `exp all` (like `forecast-accuracy`): a full-scale month
//! is a deliberate, hours-long run — invoke it explicitly with
//! `sageserve exp month --scale F`.

use anyhow::Result;

use crate::config::{Epoch, ModelKind, DAY};
use crate::experiments::{print_table, ExpOptions};
use crate::sim::chunked::{run_simulation_chunked, ChunkedOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::{TraceConfig, TraceGenerator};

/// Run the 30-day chunked-engine validation (`exp month`).
pub fn month(opts: &ExpOptions) -> Result<()> {
    let cfg = SimConfig {
        trace: TraceConfig {
            epoch: Epoch::Jul2025,
            days: 30.0,
            scale: opts.scale,
            seed: opts.seed,
            start_weekday: 0,
            ..Default::default()
        },
        strategy: Strategy::LtUa,
        pjrt_forecaster: opts.pjrt,
        artifacts_dir: opts.artifacts_dir.clone(),
        ..Default::default()
    };
    let est = (TraceGenerator::new(cfg.trace.clone()).total_minutes() as f64 / 60.0 / 24.0)
        .round() as u64;
    println!(
        "  simulating {est} days at scale {} with {} through the chunked engine \
         (daily chunks, generation pipelined, peak memory O(chunk)) ...",
        opts.scale,
        cfg.strategy.name()
    );
    // 24 hourly epochs per chunk = one handoff per simulated day.
    let sim = run_simulation_chunked(cfg, &ChunkedOptions { chunk_epochs: 24, workers: 0 });
    let end = sim.end_time();

    // Daily p95 series: does LT-UA hold its latency floor across four
    // weekly cycles (weekday/weekend transitions ×4)?
    let bins = sim.metrics.interactive_latency_bins(ModelKind::Llama2_70B, DAY, end);
    let mut rows = Vec::new();
    for (day, s) in bins.iter().enumerate() {
        if s.count > 0 {
            rows.push(format!(
                "{day},{},{:.3},{:.3},{:.4}",
                s.count, s.ttft_p95, s.e2e_p95, s.sla_violation_rate
            ));
        }
    }
    opts.csv(
        "month_daily_latency.csv",
        "day,n,p95_ttft,p95_e2e,sla_violation",
        &rows,
    )?;

    let mut table = Vec::new();
    for &m in &sim.cfg.trace.models {
        let s = sim
            .metrics
            .interactive_latency_by_model()
            .get(&m)
            .cloned()
            .unwrap_or_default();
        table.push(vec![
            m.to_string(),
            format!("{}", s.count),
            format!("{:.2}", s.ttft_p95),
            format!("{:.2}", s.e2e_p95),
            format!("{:.1}", sim.metrics.model_instance_hours(m, end)),
        ]);
    }
    print_table(
        &format!(
            "Month-scale run — 30 days, LT-UA, chunked engine \
             ({} completed, {} dropped)",
            sim.metrics.completed, sim.metrics.dropped
        ),
        &["model", "IW n", "p95 TTFT", "p95 E2E", "inst-h"],
        &table,
    );
    Ok(())
}
