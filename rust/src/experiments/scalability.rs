//! §7.2.5 / Fig 14 — scalability test: add Llama-4-Scout (MoE) as a
//! fifth model and check SageServe's benefits persist.

use anyhow::Result;

use crate::config::{Epoch, ModelKind};
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{run_simulation, SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// Run the five-model scalability check and write `fig14_scalability.csv`.
pub fn fig14(opts: &ExpOptions) -> Result<()> {
    let mut table = Vec::new();
    let mut rows = Vec::new();
    for strategy in [Strategy::Reactive, Strategy::LtUa] {
        let cfg = SimConfig {
            trace: TraceConfig {
                epoch: Epoch::Jul2025,
                days: 1.0,
                scale: opts.scale,
                seed: opts.seed,
                start_weekday: 2,
                models: ModelKind::EVAL5.to_vec(),
                ..Default::default()
            },
            strategy,
            pjrt_forecaster: opts.pjrt,
            artifacts_dir: opts.artifacts_dir.clone(),
            ..Default::default()
        };
        println!("  running {} with 5 models ...", strategy.name());
        let sim = run_simulation(cfg);
        let end = sim.end_time();
        // IW only: NIW defers by design and would swamp the p95.  One
        // grouping pass instead of a full outcome re-scan per model.
        let by_model = sim.metrics.interactive_latency_by_model();
        for &m in &sim.cfg.trace.models {
            let lat = by_model.get(&m).cloned().unwrap_or_default();
            let ih = sim.metrics.model_instance_hours(m, end);
            let util = sim.metrics.mean_util(m);
            rows.push(format!(
                "{},{m},{:.3},{:.3},{ih:.2},{util:.4}",
                strategy.name(),
                lat.ttft_p95,
                lat.e2e_p95
            ));
            if strategy == Strategy::LtUa {
                table.push(vec![
                    m.to_string(),
                    format!("{:.2}", lat.ttft_p95),
                    format!("{:.2}", lat.e2e_p95),
                    format!("{ih:.1}"),
                    format!("{util:.2}"),
                ]);
            }
        }
    }
    opts.csv("fig14_five_models.csv", "strategy,model,ttft_p95,e2e_p95,inst_hours,mean_util", &rows)?;
    print_table(
        "Fig 14 — LT-UA with Llama-4-Scout added (paper: MoE keeps latency low, \
         fewer instance-hours than dense peers at similar size)",
        &["model", "ttft p95 (s)", "e2e p95 (s)", "inst-h", "mean util"],
        &table,
    );
    Ok(())
}
