//! Fig 9 — simulator fidelity: the analytic batch-time performance model
//! vs *real* PJRT execution of the AOT-compiled transformer.
//!
//! The paper validates SplitWise's interpolation model against real
//! hardware (R² 0.99 prefill / 0.83 decode, MAPE < 3%).  Our testbed is
//! the tinylm transformer on the CPU PJRT client.  A single fixed-shape
//! executable has constant cost, so `make artifacts` exports shape
//! variants: prefill cost varies with the prompt length S, decode cost
//! with the KV-buffer length M (the attention-context axis).  We measure
//! both sweeps, fit the same affine model class the simulator uses, and
//! report R² + MAPE.  Requires `make artifacts`.

use anyhow::{Context, Result};

use crate::experiments::{print_table, ExpOptions};
use crate::runtime::tinylm::TinyLm;
use crate::serve::linear_r2;

/// (prefill_len, max_len) pairs exported by aot.py, plus the base shape.
const VARIANTS: [(usize, usize); 3] = [(32, 64), (64, 128), (128, 256)];
const REPEATS: usize = 7;
const DECODE_STEPS: usize = 36;

/// Measure prefill/decode runtime fidelity against the linear cost
/// model and write the fig9 CSVs.
pub fn fig9(opts: &ExpOptions) -> Result<()> {
    let mut prefill_pts: Vec<(f64, f64)> = Vec::new(); // (S·B tokens, secs)
    let mut decode_pts: Vec<(f64, f64)> = Vec::new(); // (M, secs)

    for &(s, m) in &VARIANTS {
        let model = if (s, m) == (128, 256) {
            TinyLm::load(&opts.artifacts_dir)
        } else {
            TinyLm::load_variant(&opts.artifacts_dir, s, m)
        }
        .with_context(|| format!("fig9 needs AOT artifacts for s={s} m={m} — run `make artifacts`"))?;
        let b = model.cfg.batch;
        println!("  measuring variant S={s} M={m} ({REPEATS} prefills, {DECODE_STEPS} decode steps) ...");

        let tokens: Vec<i32> = (0..b * s).map(|i| (i % 251) as i32).collect();
        // Warm-up (compile/caches) then timed repeats.
        let mut pre = model.prefill(&tokens)?;
        for _ in 0..REPEATS {
            let t0 = std::time::Instant::now();
            pre = model.prefill(&tokens)?;
            prefill_pts.push(((b * s) as f64, t0.elapsed().as_secs_f64()));
        }

        let mut cur: Vec<i32> = vec![65; b];
        let mut pos: Vec<i32> = vec![s as i32; b];
        let mut cache = pre.cache;
        let mut raw = Vec::new();
        for step in 0..DECODE_STEPS {
            let t0 = std::time::Instant::now();
            let out = model.decode(&cur, &pos, &cache)?;
            let dt = t0.elapsed().as_secs_f64();
            if step > 2 {
                raw.push(dt); // skip cold steps
            }
            cache = out.cache;
            cur = model.argmax(&out.logits);
            for p in pos.iter_mut() {
                *p = (*p + 1).min(m as i32 - 1);
            }
        }
        // Median-of-5 grouping suppresses single-core scheduling noise.
        for group in raw.chunks(5) {
            let mut g = group.to_vec();
            g.sort_by(|a, b| a.partial_cmp(b).unwrap());
            decode_pts.push((m as f64, g[0])); // min: noise-robust timing estimator
        }
    }

    let r2_prefill = linear_r2(&prefill_pts).unwrap_or(f64::NAN);
    let r2_decode = linear_r2(&decode_pts).unwrap_or(f64::NAN);
    let mape = |pts: &[(f64, f64)]| -> f64 {
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        let icept = (sy - slope * sx) / n;
        pts.iter().map(|p| ((icept + slope * p.0) - p.1).abs() / p.1.max(1e-9)).sum::<f64>() / n
    };
    let mape_prefill = mape(&prefill_pts) * 100.0;
    let mape_decode = mape(&decode_pts) * 100.0;

    // Implied prompt TPS (slope⁻¹) — the Fig 9 annotation analogue.
    let n_p = prefill_pts.len() as f64;
    let sx: f64 = prefill_pts.iter().map(|p| p.0).sum();
    let sy: f64 = prefill_pts.iter().map(|p| p.1).sum();
    let sxx: f64 = prefill_pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = prefill_pts.iter().map(|p| p.0 * p.1).sum();
    let slope = (n_p * sxy - sx * sy) / (n_p * sxx - sx * sx);
    let prompt_tps = if slope > 0.0 { 1.0 / slope } else { f64::NAN };

    let mut rows: Vec<String> =
        prefill_pts.iter().map(|(x, y)| format!("prefill,{x},{y:.6}")).collect();
    rows.extend(decode_pts.iter().map(|(x, y)| format!("decode,{x},{y:.6}")));
    opts.csv("fig9_fidelity_samples.csv", "phase,size,seconds", &rows)?;

    print_table(
        "Fig 9 — perf-model fidelity on real PJRT execution \
         (paper: R² 0.99 prefill / 0.83 decode, MAPE < 3%)",
        &["phase", "axis", "samples", "R²", "affine MAPE"],
        &[
            vec![
                "prefill".into(),
                "prompt tokens".into(),
                prefill_pts.len().to_string(),
                format!("{r2_prefill:.3}"),
                format!("{mape_prefill:.1}%"),
            ],
            vec![
                "decode".into(),
                "KV length M".into(),
                decode_pts.len().to_string(),
                format!("{r2_decode:.3}"),
                format!("{mape_decode:.1}%"),
            ],
        ],
    );
    println!(
        "  implied prompt TPS of the real model: {prompt_tps:.0} tokens/s \
         (the paper reads 21,000 for Llama-2 on 8xH100 off the same fit)"
    );
    Ok(())
}
