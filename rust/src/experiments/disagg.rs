//! Disaggregated-serving ablation (`exp disagg`): unified vs
//! prefill/decode-disaggregated fleets on the week-long trace, at equal
//! SLA attainment.
//!
//! Each mode runs under Reactive and LT-UA on the *same* materialized
//! trace (generated once, shared across all four runs).  The
//! disaggregated fleets admit arrivals through the prefill-queue JSQ,
//! pay an explicit KV-cache migration per prefill→decode handoff, and
//! size the two pools with per-phase capacity solves (TTFT gates
//! prefill, ITL gates decode) under one shared GPU budget.
//!
//! Emits `disagg_ablation.csv` with per-(mode, strategy) net fleet
//! cost, TTFT/ITL attainment against the [`DisaggParams`] targets,
//! handoff counts and the KV-transfer overhead — both absolute
//! transfer-seconds and as a fraction of fleet GPU-time.
//!
//! Quick mode (`SAGESERVE_EXP_QUICK=1`, used by the `make verify`
//! smoke set as `smoke-disagg`) shrinks the trace to one day so the
//! whole ablation finishes in seconds.

use anyhow::Result;

use crate::config::{DisaggParams, Epoch};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// True when the smoke-mode env toggle is set (same convention as
/// `SAGESERVE_BENCH_QUICK`).
fn quick_mode() -> bool {
    std::env::var("SAGESERVE_EXP_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Run the unified-vs-disaggregated ablation and write
/// `disagg_ablation.csv`.
pub fn disagg(opts: &ExpOptions) -> Result<()> {
    let quick = quick_mode();
    let days = if quick { 1.0 } else { 7.0 };
    let scale = if quick { opts.scale.min(0.05) } else { opts.scale };
    let strategies = [Strategy::Reactive, Strategy::LtUa];
    let modes = [("unified", DisaggParams::default()), ("disagg", DisaggParams::enabled())];

    let mut labels = Vec::new();
    let mut cfgs = Vec::new();
    for (name, params) in &modes {
        for &strategy in &strategies {
            labels.push(*name);
            cfgs.push(SimConfig {
                trace: TraceConfig {
                    epoch: Epoch::Jul2025,
                    days,
                    scale,
                    seed: opts.seed,
                    start_weekday: 0,
                    ..Default::default()
                },
                strategy,
                disagg: params.clone(),
                pjrt_forecaster: opts.pjrt,
                artifacts_dir: opts.artifacts_dir.clone(),
                ..Default::default()
            });
        }
    }
    println!(
        "  running {} runs ({} modes × {} strategies, {days} day(s)) in parallel ...",
        cfgs.len(),
        modes.len(),
        strategies.len()
    );
    let results = run_configs(cfgs);

    // Both modes are read against the same SLO targets, so the
    // attainment columns are directly comparable.
    let targets = DisaggParams::default();
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (label, res) in labels.iter().zip(&results) {
        let m = &res.metrics;
        if *label == "unified" {
            assert_eq!(m.handoffs, 0, "unified runs must never hand off");
            assert_eq!(m.kv_transfer_secs, 0.0, "unified runs pay no KV transfer");
        } else {
            assert!(m.handoffs > 0, "disaggregated runs must hand off prefills");
            assert_eq!(
                m.handoffs,
                m.handoff_admissions + m.handoff_drops,
                "every handoff must be admitted or dropped — exactly once"
            );
        }
        let net_cost = m.net_fleet_cost(res.end_time);
        let ttft_att = m.ttft_attainment(targets.ttft_target);
        let itl_att = m.itl_attainment(targets.itl_target);
        let gpu_secs: f64 = m.gpu_hours_by_sku(res.end_time).values().sum::<f64>() * 3600.0;
        let kv_frac = if gpu_secs > 0.0 { m.kv_transfer_secs / gpu_secs } else { 0.0 };
        rows.push(format!(
            "{label},{},{},{},{net_cost:.2},{ttft_att:.4},{itl_att:.4},{:.3},{kv_frac:.6}",
            res.strategy.name(),
            m.completed,
            m.handoffs,
            m.kv_transfer_secs,
        ));
        table.push(vec![
            label.to_string(),
            res.strategy.name().into(),
            m.completed.to_string(),
            m.handoffs.to_string(),
            format!("${net_cost:.0}"),
            format!("{:.2}%", ttft_att * 100.0),
            format!("{:.2}%", itl_att * 100.0),
            format!("{:.1} s", m.kv_transfer_secs),
            format!("{:.4}%", kv_frac * 100.0),
        ]);
    }
    opts.csv(
        "disagg_ablation.csv",
        "config,strategy,completed,handoffs,net_cost_usd,ttft_attainment,\
         itl_attainment,kv_transfer_s,kv_overhead_frac",
        &rows,
    )?;
    print_table(
        "Disaggregation ablation — unified vs prefill/decode pools at equal \
         SLO targets (expect: comparable attainment; the disaggregated \
         fleet pays a small KV-transfer overhead and sizes each phase \
         against its own SLO)",
        &[
            "config",
            "strategy",
            "completed",
            "handoffs",
            "net cost",
            "TTFT att",
            "ITL att",
            "KV transfer",
            "KV overhead",
        ],
        &table,
    );
    Ok(())
}
