//! §7.2.6 / Fig 15 — multi-tier scheduling policies: FCFS / EDF / PF /
//! DPA, compared on IW-F vs IW-N Q3 TTFT and SLA violation rates.

use anyhow::Result;

use crate::config::{Epoch, Tier};
use crate::coordinator::scheduler::SchedPolicy;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{run_simulation, SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

fn sageserve_scaling_default() -> crate::config::ScalingParams {
    crate::config::ScalingParams::default()
}

/// Compare the four instance-level scheduling policies (§6.5) and
/// write `fig15_scheduling.csv`.
pub fn fig15(opts: &ExpOptions) -> Result<()> {
    let policies: [(&str, SchedPolicy); 4] = [
        ("fcfs", SchedPolicy::Fcfs),
        ("edf", SchedPolicy::Edf),
        ("pf", SchedPolicy::Pf),
        ("dpa", SchedPolicy::dpa_default()),
    ];
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (name, policy) in policies {
        let cfg = SimConfig {
            trace: TraceConfig {
                epoch: Epoch::Jul2025,
                days: 1.0,
                // Capacity is pinned below, so the diurnal peak pushes the
                // cluster into the moderate-overload regime where queues
                // form and the policy choice matters (the paper's default
                // setting shows ~45% IW-F violations).  Deliberately NOT a
                // collapse regime: the paper's Q3 TTFTs are seconds.
                scale: opts.scale,
                seed: opts.seed,
                start_weekday: 2,
                ..Default::default()
            },
            strategy: Strategy::LtUa,
            sched_policy: policy,
            // Pin the capacity (min = max = initial) so the scheduler —
            // not the autoscaler — is the bottleneck, as in the paper's
            // fixed "default setting".
            initial_instances: 6,
            scaling: {
                let mut p = sageserve_scaling_default();
                p.min_instances = 6;
                p.max_instances = 6;
                p
            },
            pjrt_forecaster: opts.pjrt,
            artifacts_dir: opts.artifacts_dir.clone(),
            ..Default::default()
        };
        println!("  running policy {name} ...");
        let sim = run_simulation(cfg);
        let mut line = vec![name.to_string()];
        for tier in [Tier::IwF, Tier::IwN] {
            // Q3 TTFT (p75) and violation rate straight off the
            // streaming tier summary — no per-tier outcome collection.
            let summary = sim.metrics.latency_by_tier(tier);
            let q3 = summary.ttft_p75;
            rows.push(format!(
                "{name},{tier},{q3:.3},{:.1}",
                summary.sla_violation_rate * 100.0
            ));
            line.push(format!("{q3:.2}"));
            line.push(format!("{:.1}%", summary.sla_violation_rate * 100.0));
        }
        table.push(line);
    }
    opts.csv("fig15_scheduling_policies.csv", "policy,tier,q3_ttft,sla_violation_pct", &rows)?;
    print_table(
        "Fig 15 — Q3 TTFT and SLA violations per policy \
         (paper: PF best for IW-F at IW-N's expense; EDF balances; DPA in between)",
        &["policy", "IW-F q3 (s)", "IW-F viol", "IW-N q3 (s)", "IW-N viol"],
        &table,
    );
    Ok(())
}
