//! The experiment harness: one entry per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the full index).
//!
//! Every experiment prints the paper-comparable rows to stdout and writes
//! CSV series into the output directory.  Absolute numbers differ from
//! the paper (synthetic traces, CPU-simulated cluster — DESIGN.md §1);
//! the *shape* — who wins, by roughly what factor — is the reproduction
//! target, and EXPERIMENTS.md records paper-vs-measured for each.

/// §7.2.7 / Fig 16a — burst management under synthetic traffic spikes.
pub mod burst;
/// §7.1 workload characterization figures (Figs 1–6, 10).
pub mod characterization;
/// Unified vs prefill/decode-disaggregated fleets at equal SLO targets.
pub mod disagg;
/// Fault-plane ablation: outages and spot shocks across strategies.
pub mod faults;
/// Control-plane guardrail ablation: forecast blackouts and telemetry
/// freezes across naive, guarded and reactive controllers.
pub mod guardrails;
/// Fig 9 — runtime fidelity of the linear prefill/decode cost model.
pub mod fidelity;
/// Heterogeneous-fleet sweep: mixed SKUs, SKU-aware vs blind routing.
pub mod hetero;
/// Capacity-ILP solver runtime table and forecast-accuracy check.
pub mod ilp_runtime;
/// 30-day chunked-engine run (dispatchable, not part of `exp all`).
pub mod month;
/// §7.2.5 / Fig 14 — five-model scalability check.
pub mod scalability;
/// §6.5 / Fig 15 — instance-level scheduling policy comparison.
pub mod scheduling;
/// Main strategy comparisons (Fig 8 / Table 1, Figs 11–13, ablations).
pub mod strategies;
/// Shared run infrastructure: trace sharing and the parallel sweep runner.
pub mod sweep;
/// §7.2.8 / Fig 16b — week-long strategy comparison.
pub mod week;

use anyhow::{Context, Result};
use std::io::Write;
use std::path::PathBuf;

/// Common experiment options (CLI-provided).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Directory the CSV outputs are written into.
    pub out_dir: PathBuf,
    /// Trace volume multiplier (1.0 = paper scale, ≈10 M req/day).
    pub scale: f64,
    /// Use the PJRT forecaster artifact instead of the native replica.
    pub pjrt: bool,
    /// Directory holding compiled runtime artifacts (PJRT executables).
    pub artifacts_dir: String,
    /// Trace-generator seed shared by every run of an experiment.
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            out_dir: PathBuf::from("results"),
            // Default keeps every experiment minutes-fast; pass --scale to
            // push toward the paper's full 10 M req/day.
            scale: 0.2,
            pjrt: false,
            artifacts_dir: "artifacts".into(),
            seed: 42,
        }
    }
}

impl ExpOptions {
    /// Write `name` under the out-dir with the given header and rows;
    /// returns the path written.
    pub fn csv(&self, name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("create {}", self.out_dir.display()))?;
        let path = self.out_dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{header}")?;
        for r in rows {
            writeln!(f, "{r}")?;
        }
        println!("  wrote {}", path.display());
        Ok(path)
    }
}

/// Known experiment ids, in run order for `exp all`.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig3", "fig4", "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
    "fig14", "fig15", "fig16a", "fig16b", "nov24", "ablations", "ilp", "hetero",
];

/// Dispatch one experiment id.
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    println!("━━━ experiment {id} ━━━");
    match id {
        "fig1" => characterization::fig1(opts),
        "fig3" => characterization::fig3(opts),
        "fig4" => characterization::fig4(opts),
        "fig5" => characterization::fig5(opts),
        "fig6" => characterization::fig6(opts),
        "fig8" => strategies::fig8_table1(opts),
        "fig9" => fidelity::fig9(opts),
        "fig10" => characterization::fig10(opts),
        "fig11" | "fig12" | "fig13" => strategies::fig11_12_13(opts),
        "fig14" => scalability::fig14(opts),
        "fig15" => scheduling::fig15(opts),
        "fig16a" => burst::fig16a(opts),
        "fig16b" => week::fig16b(opts),
        "nov24" => strategies::nov24_validation(opts),
        "ablations" => strategies::ablations(opts),
        "ilp" => ilp_runtime::solver_table(opts),
        "hetero" => hetero::hetero(opts),
        "forecast-accuracy" => ilp_runtime::forecast_accuracy(opts),
        // Dispatchable but not in `exp all` (hours-long at full scale):
        // the 30-day chunked-engine run, see experiments::month.
        "month" => month::month(opts),
        // The fault-plane ablation (robustness, not a paper figure):
        // region outage + spot shock × 3 strategies; `SAGESERVE_EXP_QUICK=1`
        // shrinks it to the `make verify` smoke run.
        "faults" => faults::faults(opts),
        // Unified vs prefill/decode-disaggregated fleets at equal SLO
        // targets; `SAGESERVE_EXP_QUICK=1` shrinks it to the `make
        // verify` smoke run (`smoke-disagg`).
        "disagg" => disagg::disagg(opts),
        // Control-plane guardrail ablation (robustness, not a paper
        // figure): forecast blackout + telemetry freeze × naive/guarded/
        // reactive controllers; `SAGESERVE_EXP_QUICK=1` shrinks it to
        // the `make verify` smoke run (`smoke-guardrails`).
        "guardrails" => guardrails::guardrails(opts),
        "all" => {
            // fig11/12/13 share one run; dedup here.
            let mut seen_strategies = false;
            for &e in ALL_EXPERIMENTS {
                if matches!(e, "fig11" | "fig12" | "fig13") {
                    if seen_strategies {
                        continue;
                    }
                    seen_strategies = true;
                }
                run(e, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}' (try: {:?})", ALL_EXPERIMENTS),
    }
}

/// Render a simple aligned table to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n{title}");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line: Vec<String> =
        header.iter().enumerate().map(|(i, h)| format!("{:<w$}", h, w = widths[i])).collect();
    println!("  {}", line.join("  "));
    println!("  {}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect();
        println!("  {}", line.join("  "));
    }
}

/// Where a path under the out-dir lives (for tests).
pub fn out_file(opts: &ExpOptions, name: &str) -> PathBuf {
    opts.out_dir.join(name)
}
