//! `exp hetero` — the heterogeneous-fleet sweep over §5's GPU axis `k`,
//! now at `k = 3`: H100-only vs A100-only vs MI300-only vs a 50/50
//! H100+A100 fleet vs the equal three-way fleet, all on the week-long
//! Jul-2025 trace under LT-UA, through the shared parallel sweep runner
//! (every run replays one pre-materialized trace).
//!
//! The capacity ILP prices SKUs by α_k and plans per-SKU throughput
//! θ_{i,k}; execution reclaims donated VMs most-valuable-spot-SKU-first,
//! provisions fresh VMs cheapest-first, and scales in
//! most-expensive-first, so a mixed fleet should converge to the
//! best-$-per-θ SKU and cost no more than the cheaper homogeneous fleet
//! at equal SLA attainment.
//!
//! The sweep doubles as the **routing ablation**: the three-way fleet
//! runs twice — SKU-blind vs SKU-aware routing on the *same* trace and
//! fleet — isolating what request-level SKU affinity adds on top of
//! pool-level per-SKU scaling.  Reported per row: per-SKU GPU-hours,
//! on-demand dollar cost, spot-market revenue, net cost, IW p95 TTFT
//! and SLA attainment (`hetero_fleet_cost.csv`).

use anyhow::Result;

use crate::config::{Epoch, FleetSpec, GpuKind};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// The fleets the sweep compares (also used by the integration tests).
pub fn fleet_specs() -> Vec<(&'static str, FleetSpec)> {
    vec![
        ("h100-only", FleetSpec::homogeneous(GpuKind::H100x8)),
        ("a100-only", FleetSpec::homogeneous(GpuKind::A100x8)),
        ("mi300-only", FleetSpec::homogeneous(GpuKind::Mi300x8)),
        (
            "mixed-50-50",
            FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]),
        ),
        ("mixed-3way", FleetSpec::mixed_3way()),
    ]
}

/// The sweep rows: every fleet under the default SKU-aware routing,
/// plus the three-way fleet again with routing forced SKU-blind — the
/// ablation pair shares fleet, trace and strategy, differing only in
/// `RoutingParams::sku_affinity`.
pub fn sweep_rows() -> Vec<(&'static str, &'static str, FleetSpec, bool)> {
    let mut rows: Vec<(&'static str, &'static str, FleetSpec, bool)> = fleet_specs()
        .into_iter()
        .map(|(label, fleet)| {
            let routing = if fleet.is_homogeneous() { "n/a" } else { "sku-aware" };
            (label, routing, fleet, true)
        })
        .collect();
    rows.push(("mixed-3way", "sku-blind", FleetSpec::mixed_3way(), false));
    rows
}

/// Run the heterogeneous-fleet sweep (homogeneous baselines vs mixed
/// fleets, SKU-aware vs SKU-blind routing) and write `hetero_fleet.csv`.
pub fn hetero(opts: &ExpOptions) -> Result<()> {
    let grid = sweep_rows();
    let cfgs: Vec<SimConfig> = grid
        .iter()
        .map(|(_, _, fleet, sku_aware)| {
            let mut cfg = SimConfig {
                trace: TraceConfig {
                    epoch: Epoch::Jul2025,
                    days: 7.0,
                    scale: opts.scale,
                    seed: opts.seed,
                    start_weekday: 0,
                    ..Default::default()
                },
                strategy: Strategy::LtUa,
                fleet: fleet.clone(),
                pjrt_forecaster: opts.pjrt,
                artifacts_dir: opts.artifacts_dir.clone(),
                ..Default::default()
            };
            cfg.routing.sku_affinity = *sku_aware;
            cfg
        })
        .collect();
    println!(
        "  running {} fleet/routing configurations over the week trace in parallel ...",
        cfgs.len()
    );
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut table = Vec::new();
    for ((label, routing, _, _), r) in grid.iter().zip(&results) {
        let end = r.end_time;
        let by_sku = r.metrics.gpu_hours_by_sku(end);
        let hours = |g: GpuKind| by_sku.get(&g).copied().unwrap_or(0.0);
        let (h100_h, a100_h, mi300_h) =
            (hours(GpuKind::H100x8), hours(GpuKind::A100x8), hours(GpuKind::Mi300x8));
        let cost = r.metrics.fleet_dollar_cost(end);
        let spot_rev = r.metrics.spot_revenue(end);
        let net = r.metrics.net_fleet_cost(end);
        // All-model interactive summary from the streaming cells.
        let iw = r.metrics.interactive_latency();
        let attain = (1.0 - iw.sla_violation_rate) * 100.0;
        rows.push(format!(
            "{label},{routing},{h100_h:.2},{a100_h:.2},{mi300_h:.2},{cost:.0},{spot_rev:.0},\
             {net:.0},{:.3},{attain:.2}",
            iw.ttft_p95
        ));
        table.push(vec![
            label.to_string(),
            routing.to_string(),
            format!("{h100_h:.0}"),
            format!("{a100_h:.0}"),
            format!("{mi300_h:.0}"),
            format!("${cost:.0}"),
            format!("${spot_rev:.0}"),
            format!("${net:.0}"),
            format!("{:.2}", iw.ttft_p95),
            format!("{attain:.2}%"),
        ]);
    }
    opts.csv(
        "hetero_fleet_cost.csv",
        "fleet,routing,h100_gpu_hours,a100_gpu_hours,mi300_gpu_hours,dollar_cost,\
         spot_revenue,net_cost,iw_ttft_p95,sla_attainment_pct",
        &rows,
    )?;
    print_table(
        "exp hetero — fleet cost/SLA trade-off + routing ablation, week trace, LT-UA \
         (expected: mixed fleets cost no more than the cheaper homogeneous fleet at equal \
         SLA; SKU-aware routing no worse on net cost than SKU-blind on the same 3-way fleet)",
        &[
            "fleet",
            "routing",
            "H100-h",
            "A100-h",
            "MI300-h",
            "cost",
            "spot rev",
            "net",
            "IW p95 TTFT (s)",
            "SLA attain",
        ],
        &table,
    );
    Ok(())
}
