//! `exp hetero` — the heterogeneous-fleet sweep over §5's GPU axis `k`:
//! H100-only vs A100-only vs a 50/50 mixed fleet on the week-long
//! Jul-2025 trace, all under LT-UA, through the shared parallel sweep
//! runner (the three runs replay one pre-materialized trace).
//!
//! The capacity ILP prices SKUs by α_k and plans per-SKU throughput
//! θ_{i,k}; execution is cheapest-SKU-first on scale-out and
//! most-expensive-first on scale-in, so a mixed fleet should converge to
//! the cheaper-per-throughput SKU and cost no more than the cheaper
//! homogeneous fleet at equal SLA attainment.  Reported per fleet:
//! per-SKU GPU-hours, total dollar cost, IW p95 TTFT and SLA attainment.

use anyhow::Result;

use crate::config::{Epoch, FleetSpec, GpuKind};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::metrics::LatencySummary;
use crate::sim::engine::{SimConfig, Strategy};
use crate::trace::generator::TraceConfig;

/// The fleets the sweep compares (also used by the integration tests).
pub fn fleet_specs() -> Vec<(&'static str, FleetSpec)> {
    vec![
        ("h100-only", FleetSpec::homogeneous(GpuKind::H100x8)),
        ("a100-only", FleetSpec::homogeneous(GpuKind::A100x8)),
        (
            "mixed-50-50",
            FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]),
        ),
    ]
}

pub fn hetero(opts: &ExpOptions) -> Result<()> {
    let fleets = fleet_specs();
    let cfgs: Vec<SimConfig> = fleets
        .iter()
        .map(|(_, fleet)| SimConfig {
            trace: TraceConfig {
                epoch: Epoch::Jul2025,
                days: 7.0,
                scale: opts.scale,
                seed: opts.seed,
                start_weekday: 0,
                ..Default::default()
            },
            strategy: Strategy::LtUa,
            fleet: fleet.clone(),
            pjrt_forecaster: opts.pjrt,
            artifacts_dir: opts.artifacts_dir.clone(),
            ..Default::default()
        })
        .collect();
    println!("  running {} fleet configurations over the week trace in parallel ...", cfgs.len());
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut table = Vec::new();
    for ((label, _), r) in fleets.iter().zip(&results) {
        let end = r.end_time;
        let by_sku = r.metrics.gpu_hours_by_sku(end);
        let h100_h = by_sku.get(&GpuKind::H100x8).copied().unwrap_or(0.0);
        let a100_h = by_sku.get(&GpuKind::A100x8).copied().unwrap_or(0.0);
        let cost = r.metrics.fleet_dollar_cost(end);
        let iw = LatencySummary::from_outcomes(
            r.metrics.outcomes.iter().filter(|o| o.tier.is_interactive()),
        );
        let attain = (1.0 - iw.sla_violation_rate) * 100.0;
        rows.push(format!(
            "{label},{h100_h:.2},{a100_h:.2},{cost:.0},{:.3},{attain:.2}",
            iw.ttft_p95
        ));
        table.push(vec![
            label.to_string(),
            format!("{h100_h:.0}"),
            format!("{a100_h:.0}"),
            format!("${cost:.0}"),
            format!("{:.2}", iw.ttft_p95),
            format!("{attain:.2}%"),
        ]);
    }
    opts.csv(
        "hetero_fleet_cost.csv",
        "fleet,h100_gpu_hours,a100_gpu_hours,dollar_cost,iw_ttft_p95,sla_attainment_pct",
        &rows,
    )?;
    print_table(
        "exp hetero — fleet cost/SLA trade-off, week trace, LT-UA \
         (expected: mixed costs no more than the cheaper homogeneous fleet at equal SLA)",
        &["fleet", "H100-h", "A100-h", "cost", "IW p95 TTFT (s)", "SLA attain"],
        &table,
    );
    Ok(())
}
