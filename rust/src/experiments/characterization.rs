//! Characterization experiments: Fig 1 (motivation), Figs 3–6 (workload
//! study), Fig 10 (token CDFs).

use anyhow::Result;

use crate::config::{Epoch, ModelKind, Region, Tier, DAY, HOUR, MINUTE};
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{run_simulation, SimConfig, Strategy};
use crate::trace::generator::{TraceConfig, TraceGenerator};
use crate::trace::stats::WorkloadStats;

/// Fig 1 — ideal vs reactive VM scaling on a TPS ramp.
///
/// Replays the paper's illustration: an instance serves 4000 TPS; the
/// reactive policy decides from current TPS and pays a 5-minute
/// provisioning delay (under-allocation); a conservative 3500-TPS sizing
/// over-allocates on transient upticks.  The ideal policy is prescient.
pub fn fig1(opts: &ExpOptions) -> Result<()> {
    let cap = 4000.0;
    let cap_conservative = 3500.0;
    let provision_delay = 5; // minutes
    // The paper's traffic shape: rise, plateau, small bump, stabilize.
    let tps_at = |m: i64| -> f64 {
        match m {
            ..=9 => 3200.0,
            10..=19 => 3600.0 + 200.0 * ((m - 10) as f64),
            20..=24 => 6800.0,
            25..=29 => 7400.0,
            _ => 7000.0,
        }
    };
    let horizon = 60i64;
    let mut rows = Vec::new();
    let mut table = Vec::new();
    let mut reactive_pending: Vec<(i64, i64)> = Vec::new(); // (ready_at, delta)
    let mut reactive_count = 1i64;
    let mut reactive_cons_count = 1i64;
    let mut sla_viol_minutes = 0i64;
    let mut over_alloc_minutes = 0i64;
    for m in 0..horizon {
        let tps = tps_at(m);
        let ideal = (tps / cap).ceil() as i64;
        // Reactive with true capacity: scale when overloaded, 5-min delay.
        for &(ready, d) in &reactive_pending {
            if ready == m {
                reactive_count += d;
            }
        }
        reactive_pending.retain(|&(ready, _)| ready > m);
        let needed = (tps / cap).ceil() as i64;
        let in_flight: i64 = reactive_pending.iter().map(|&(_, d)| d).sum();
        if needed > reactive_count + in_flight {
            reactive_pending.push((m + provision_delay, needed - reactive_count - in_flight));
        }
        if (reactive_count as f64) * cap < tps {
            sla_viol_minutes += 1;
        }
        // Conservative capacity: reacts to every bump, over-allocates.
        let needed_cons = (tps / cap_conservative).ceil() as i64;
        if needed_cons > reactive_cons_count {
            reactive_cons_count = needed_cons; // scale up (sticky)
        }
        if reactive_cons_count > ideal {
            over_alloc_minutes += 1;
        }
        rows.push(format!(
            "{m},{tps:.0},{ideal},{reactive_count},{reactive_cons_count}"
        ));
        if m % 10 == 0 {
            table.push(vec![
                m.to_string(),
                format!("{tps:.0}"),
                ideal.to_string(),
                reactive_count.to_string(),
                reactive_cons_count.to_string(),
            ]);
        }
    }
    opts.csv("fig1_scaling_illustration.csv", "minute,tps,ideal,reactive,reactive_conservative", &rows)?;
    print_table(
        "Fig 1 — ideal vs reactive instance counts (every 10 min)",
        &["min", "TPS", "ideal", "reactive", "conservative"],
        &table,
    );
    println!(
        "  under-allocation: {sla_viol_minutes} min of SLA violation; \
         over-allocation: {over_alloc_minutes} min above ideal"
    );
    Ok(())
}

fn epoch_cfg(opts: &ExpOptions, epoch: Epoch, days: f64) -> TraceConfig {
    TraceConfig {
        epoch,
        days,
        scale: opts.scale,
        seed: opts.seed,
        bursts: true,
        ..Default::default()
    }
}

/// Fig 3 — cumulative RPS / TPS per tier for both epochs (15-min buckets,
/// 1 week) plus the 1-hour 1-minute zoom (Fig 3b/3d analogue).
pub fn fig3(opts: &ExpOptions) -> Result<()> {
    for (epoch, tag) in [(Epoch::Jul2025, "jul2025"), (Epoch::Nov2024, "nov2024")] {
        let gen = TraceGenerator::new(epoch_cfg(opts, epoch, 7.0));
        let mut rows = Vec::new();
        let buckets = (7.0 * DAY / 900.0) as usize;
        for b in 0..buckets {
            let t = (b as f64 + 0.5) * 900.0;
            let mut line = format!("{:.2}", t / HOUR);
            for tier in Tier::ALL {
                let mut rps = 0.0;
                let mut tps = 0.0;
                for region in Region::ALL {
                    for &m in &gen.cfg.models {
                        let r = gen.rate(m, region, tier, t);
                        rps += r;
                        tps += r * TraceGenerator::mean_tokens_exact(m, tier);
                    }
                }
                line.push_str(&format!(",{rps:.3},{tps:.1}"));
            }
            rows.push(line);
        }
        opts.csv(
            &format!("fig3_cumulative_{tag}.csv"),
            "hour,iwf_rps,iwf_tps,iwn_rps,iwn_tps,niw_rps,niw_tps",
            &rows,
        )?;
    }
    // Peak-hour zoom at 1-minute resolution (sampled, so arrival noise is
    // visible as in the paper's Fig 3b/d).
    let gen = TraceGenerator::new(epoch_cfg(opts, Epoch::Jul2025, 1.0));
    let mut minute_counts = vec![[0u64; 3]; 60];
    let (lo, hi) = (13.0 * HOUR, 14.0 * HOUR);
    for r in gen.stream() {
        if r.arrival >= lo && r.arrival < hi {
            minute_counts[((r.arrival - lo) / MINUTE) as usize][r.tier.index()] += 1;
        }
    }
    let rows: Vec<String> = minute_counts
        .iter()
        .enumerate()
        .map(|(m, c)| format!("{m},{},{},{}", c[0], c[1], c[2]))
        .collect();
    opts.csv("fig3_peakhour_zoom.csv", "minute,iwf_req,iwn_req,niw_req", &rows)?;
    println!("  (diurnal periodicity + weekend quiesce in the CSVs; zoom shows 1-min noise)");
    Ok(())
}

/// Fig 4 — per-model per-region RPS/TPS for the Jul-2025 week.
pub fn fig4(opts: &ExpOptions) -> Result<()> {
    let gen = TraceGenerator::new(epoch_cfg(opts, Epoch::Jul2025, 7.0));
    let mut rows = Vec::new();
    let buckets = (7.0 * DAY / 900.0) as usize;
    for tier in Tier::ALL {
        for region in Region::ALL {
            for &m in &gen.cfg.models {
                for b in (0..buckets).step_by(4) {
                    let t = (b as f64 + 0.5) * 900.0;
                    let r = gen.rate(m, region, tier, t);
                    let tps = r * TraceGenerator::mean_tokens_exact(m, tier);
                    rows.push(format!("{tier},{region},{m},{:.2},{r:.4},{tps:.1}", t / HOUR));
                }
            }
        }
    }
    opts.csv("fig4_per_model_region_jul2025.csv", "tier,region,model,hour,rps,tps", &rows)?;

    // Paper call-outs as a quick table: Model A East vs West (IW-F).
    let t_peak = 13.5 * HOUR;
    let east = gen.rate(ModelKind::Bloom176B, Region::EastUs, Tier::IwF, t_peak);
    let west = gen.rate(ModelKind::Bloom176B, Region::WestUs, Tier::IwF, t_peak);
    let b_central = gen.rate(ModelKind::Llama2_70B, Region::CentralUs, Tier::IwF, t_peak);
    let b_east = gen.rate(ModelKind::Llama2_70B, Region::EastUs, Tier::IwF, t_peak);
    print_table(
        "Fig 4 call-outs (peak-hour RPS)",
        &["claim", "value"],
        &[
            vec!["Model A East / West (paper ≈4x)".into(), format!("{:.1}x", east / west)],
            vec![
                "Model B Central > East (IW-F)".into(),
                format!("{} ({:.2} vs {:.2})", b_central > b_east, b_central, b_east),
            ],
        ],
    );
    Ok(())
}

/// Fig 5 — Nov-2024 per-region week (no IW-F tier) + 1-hour zoom.
pub fn fig5(opts: &ExpOptions) -> Result<()> {
    let gen = TraceGenerator::new(epoch_cfg(opts, Epoch::Nov2024, 7.0));
    let mut rows = Vec::new();
    let buckets = (7.0 * DAY / 900.0) as usize;
    for region in Region::ALL {
        for b in 0..buckets {
            let t = (b as f64 + 0.5) * 900.0;
            let mut iw_rps = 0.0;
            let mut iw_tps = 0.0;
            let mut niw_rps = 0.0;
            let mut niw_tps = 0.0;
            for &m in &gen.cfg.models {
                let r = gen.rate(m, region, Tier::IwN, t);
                iw_rps += r;
                iw_tps += r * TraceGenerator::mean_tokens_exact(m, Tier::IwN);
                let rn = gen.rate(m, region, Tier::Niw, t);
                niw_rps += rn;
                niw_tps += rn * TraceGenerator::mean_tokens_exact(m, Tier::Niw);
            }
            rows.push(format!(
                "{region},{:.2},{iw_rps:.4},{iw_tps:.1},{niw_rps:.4},{niw_tps:.1}",
                t / HOUR
            ));
        }
    }
    opts.csv("fig5_nov2024_regions.csv", "region,hour,iw_rps,iw_tps,niw_rps,niw_tps", &rows)?;
    println!("  Nov-2024 volume ≈ 1/5 of Jul-2025 (5x growth across epochs)");
    Ok(())
}

/// Fig 6 — top applications, per-app load, and E2E latency distributions
/// (the latency panels come from a 1-day simulation of the current
/// Reactive deployment).
pub fn fig6(opts: &ExpOptions) -> Result<()> {
    // (a)+(b): app mix from the sampled stream.
    let gen = TraceGenerator::new(epoch_cfg(opts, Epoch::Jul2025, 1.0));
    let mut stats = WorkloadStats::new(DAY, 900.0);
    for r in gen.stream() {
        stats.observe(&r);
    }
    let top = stats.top_apps();
    let total = stats.total_requests as f64;
    let rows: Vec<String> = top
        .iter()
        .map(|(app, req, tok)| format!("{},{req},{tok},{:.1}", app.name(), *req as f64 / total * 100.0))
        .collect();
    opts.csv("fig6a_top_apps.csv", "app,requests,tokens,share_pct", &rows)?;
    let table: Vec<Vec<String>> = top
        .iter()
        .take(5)
        .map(|(app, req, _)| {
            vec![app.name().to_string(), format!("{:.1}%", *req as f64 / total * 100.0)]
        })
        .collect();
    print_table("Fig 6a — top applications (paper: RAG 41.2%)", &["app", "share"], &table);

    // (c)+(d): E2E latency by tier and region from a simulated day.
    let cfg = SimConfig {
        trace: epoch_cfg(opts, Epoch::Jul2025, 1.0),
        strategy: Strategy::Reactive,
        pjrt_forecaster: false,
        artifacts_dir: opts.artifacts_dir.clone(),
        ..Default::default()
    };
    let sim = run_simulation(cfg);
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for region in Region::ALL {
        for tier in Tier::ALL {
            // Streaming (tier, region) cell fold — no outcome log.
            let summary = sim.metrics.latency_by_tier_region(tier, region);
            if summary.count == 0 {
                continue;
            }
            rows.push(format!(
                "{region},{tier},{},{:.3},{:.3},{:.3},{:.3}",
                summary.count, summary.mean_e2e, summary.e2e_p50, summary.e2e_p95, summary.ttft_p95
            ));
            if tier == Tier::IwF {
                table.push(vec![
                    region.to_string(),
                    format!("{:.2}s", summary.mean_e2e),
                    format!("{:.2}s", summary.e2e_p50),
                    format!("{:.2}s", summary.e2e_p95),
                ]);
            }
        }
    }
    opts.csv("fig6c_latency_by_region.csv", "region,tier,count,mean_e2e,p50_e2e,p95_e2e,p95_ttft", &rows)?;
    print_table(
        "Fig 6c — IW-F E2E latency by region (paper: mean 3.3–4.5 s, p95 11–15 s)",
        &["region", "mean", "median", "p95"],
        &table,
    );

    // (e): per-instance load spread within each region for Model A —
    // percentiles over the streaming per-bin utilization means.  At the
    // default 15-minute metrics bin each bin holds exactly one sample
    // (UTIL_SAMPLE_EVERY × SCALE_TICK == bin_width), so this matches
    // the old raw-sample percentiles; if the bin is ever widened the
    // spread would silently flatten toward the mean — assert the
    // coupling so it fails loudly instead.
    debug_assert!(
        (sim.metrics.bin_width() - 900.0).abs() < 1e-9,
        "fig6e expects one util sample per metrics bin (900 s); \
         re-derive the spread if MetricsConfig::bin changes"
    );
    let mut rows = Vec::new();
    for region in Region::ALL {
        let mut utils: Vec<f64> = sim
            .metrics
            .util_series(ModelKind::Bloom176B, region)
            .iter()
            .filter(|b| b.count > 0)
            .inspect(|b| debug_assert!(b.count == 1, "util bin aggregates {} samples", b.count))
            .map(|b| b.sum / b.count as f64)
            .collect();
        if utils.is_empty() {
            continue;
        }
        let p50 = crate::metrics::percentile(&mut utils, 50.0);
        let p95 = crate::metrics::percentile(&mut utils, 95.0);
        let p99 = crate::metrics::percentile(&mut utils, 99.0);
        rows.push(format!("{region},{p50:.4},{p95:.4},{p99:.4}"));
    }
    opts.csv("fig6e_load_percentiles_modelA.csv", "region,p50,p95,p99", &rows)?;
    Ok(())
}

/// Fig 10 — CDFs of prompt/output/total token counts per model.
pub fn fig10(opts: &ExpOptions) -> Result<()> {
    let gen = TraceGenerator::new(epoch_cfg(opts, Epoch::Jul2025, 1.0));
    let mut stats = WorkloadStats::new(DAY, 900.0);
    for r in gen.stream() {
        stats.observe(&r);
    }
    let mut rows = Vec::new();
    let mut table = Vec::new();
    for &m in &gen.cfg.models {
        for (output, tag) in [(false, "input"), (true, "output")] {
            let (vals, frac) = stats.token_cdf(m, output);
            if vals.is_empty() {
                continue;
            }
            for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
                let idx = ((frac.len() - 1) as f64 * q) as usize;
                rows.push(format!("{m},{tag},{q},{}", vals[idx]));
            }
            let median = vals[vals.len() / 2];
            if tag == "input" {
                table.push(vec![m.to_string(), format!("{median}"), String::new()]);
            } else if let Some(last) = table.last_mut() {
                last[2] = format!("{median}");
            }
        }
    }
    opts.csv("fig10_token_cdf.csv", "model,direction,quantile,tokens", &rows)?;
    print_table(
        "Fig 10 — median token counts (paper: inputs mostly >1k, outputs <1k)",
        &["model", "median input", "median output"],
        &table,
    );
    Ok(())
}
