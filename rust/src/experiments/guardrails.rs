//! Guardrail ablation (`exp guardrails`): what a control-plane fault
//! costs each controller flavor, and what the guardrail cascade buys
//! back.
//!
//! Three scenarios — no fault, a forecast blackout, and a telemetry
//! freeze (both spanning days 2–4 of the week, long enough to exhaust
//! the held-plan budget and force the cascade onto its reactive rung) —
//! each run under three controllers:
//!
//! * **naive** — LT-UA with the guardrails off: faulted inputs are
//!   consumed as truth.  A blackout reads as "demand is zero", so the
//!   ILP scales the fleet into the floor and the LT-UA gap check
//!   (gated on a positive forecast) never fires.
//! * **guarded** — LT-UA behind the watchdog + residual tracker +
//!   fallback cascade of [`crate::coordinator::controller::guardrail_epoch`].
//! * **reactive** — the purely reactive strategy: no forecast, no
//!   solver, nothing for the control-plane fault to poison — the
//!   paper's "slow but immune" baseline.
//!
//! Emits `guardrail_ablation.csv` with per-(scenario, controller) SLA
//! attainment, GPU-hours/cost, cascade rung counts, degraded time and
//! the safety-margin capacity ledger.  The run asserts the structural
//! invariant: degraded time accrues on the guarded controller exactly
//! when a fault scenario is active, and never elsewhere.
//!
//! Quick mode (`SAGESERVE_EXP_QUICK=1`, the `make verify` smoke set)
//! shrinks the trace to one day with the fault window at the same trace
//! fractions.

use anyhow::Result;

use crate::config::{Epoch, GuardrailParams, Tier, HOUR};
use crate::experiments::sweep::run_configs;
use crate::experiments::{print_table, ExpOptions};
use crate::sim::engine::{SimConfig, Strategy};
use crate::sim::faults::ControlFaultPlan;
use crate::trace::generator::TraceConfig;

/// True when the smoke-mode env toggle is set (same convention as
/// `SAGESERVE_BENCH_QUICK`).
fn quick_mode() -> bool {
    std::env::var("SAGESERVE_EXP_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

/// The scenarios, with fault windows at fixed trace fractions (days 2–4
/// of a week) so quick mode exercises the identical phases.
fn scenarios(days: f64) -> Vec<(&'static str, ControlFaultPlan)> {
    let span = days * 24.0 * HOUR;
    let (start, end) = (span * 2.0 / 7.0, span * 4.0 / 7.0);
    vec![
        ("none", ControlFaultPlan::default()),
        ("forecast-blackout", ControlFaultPlan::forecast_blackout(start, end)),
        ("stale-telemetry", ControlFaultPlan::stale_telemetry(start, end)),
    ]
}

/// The controller flavors: (label, strategy, guardrails on?).
const CONTROLLERS: [(&str, Strategy, bool); 3] = [
    ("naive", Strategy::LtUa, false),
    ("guarded", Strategy::LtUa, true),
    ("reactive", Strategy::Reactive, false),
];

/// Interactive SLA attainment across both IW tiers (count-weighted).
fn iw_sla_attainment(metrics: &crate::metrics::Metrics) -> f64 {
    let (mut violations, mut count) = (0.0, 0.0);
    for tier in Tier::ALL {
        if !tier.is_interactive() {
            continue;
        }
        let s = metrics.latency_by_tier(tier);
        violations += s.sla_violation_rate * s.count as f64;
        count += s.count as f64;
    }
    if count > 0.0 {
        1.0 - violations / count
    } else {
        1.0
    }
}

/// Run the guardrail ablation and write `guardrail_ablation.csv`.
pub fn guardrails(opts: &ExpOptions) -> Result<()> {
    let quick = quick_mode();
    let days = if quick { 1.0 } else { 7.0 };
    let scale = if quick { opts.scale.min(0.05) } else { opts.scale };

    let scens = scenarios(days);
    let mut labels = Vec::new();
    let mut cfgs = Vec::new();
    for (scen, plan) in &scens {
        for &(ctrl, strategy, guarded) in &CONTROLLERS {
            labels.push((*scen, ctrl));
            cfgs.push(SimConfig {
                trace: TraceConfig {
                    epoch: Epoch::Jul2025,
                    days,
                    scale,
                    seed: opts.seed,
                    start_weekday: 0,
                    ..Default::default()
                },
                strategy,
                control_faults: plan.clone(),
                guardrails: if guarded {
                    GuardrailParams::enabled()
                } else {
                    GuardrailParams::default()
                },
                pjrt_forecaster: opts.pjrt,
                artifacts_dir: opts.artifacts_dir.clone(),
                ..Default::default()
            });
        }
    }
    println!(
        "  running {} guardrail runs ({} scenarios × {} controllers, {days} day(s)) in parallel ...",
        cfgs.len(),
        scens.len(),
        CONTROLLERS.len()
    );
    let results = run_configs(cfgs);

    let mut rows = Vec::new();
    let mut table = Vec::new();
    for (&(scen, ctrl), res) in labels.iter().zip(&results) {
        let g = &res.metrics.guardrails;
        let fault_active = scen != "none";
        if ctrl == "guarded" {
            // The acceptance invariant: degraded time > 0 exactly when
            // control faults are active.
            assert_eq!(
                g.degraded_secs > 0.0,
                fault_active,
                "guarded {scen}: degraded_secs {} vs fault_active {fault_active}",
                g.degraded_secs
            );
        } else {
            assert_eq!(
                g.degraded_secs, 0.0,
                "{ctrl} {scen}: only the guarded controller walks the cascade"
            );
        }
        let attainment = iw_sla_attainment(&res.metrics);
        let gpu_hours: f64 =
            res.models.iter().map(|&m| res.metrics.model_instance_hours(m, res.end_time)).sum();
        let cost = res.metrics.fleet_dollar_cost(res.end_time);
        rows.push(format!(
            "{scen},{ctrl},{},{attainment:.4},{gpu_hours:.1},{cost:.0},{},{},{},{:.0},{},{},{},{:.1}",
            res.metrics.completed,
            g.epochs_fresh,
            g.epochs_held,
            g.epochs_reactive,
            g.degraded_secs,
            g.transition_count(),
            g.actuations_dropped,
            g.actuations_delayed,
            g.margin_instance_hours,
        ));
        table.push(vec![
            scen.to_string(),
            ctrl.to_string(),
            format!("{:.2}%", attainment * 100.0),
            format!("{gpu_hours:.0}"),
            format!("${cost:.0}"),
            format!("{}/{}/{}", g.epochs_fresh, g.epochs_held, g.epochs_reactive),
            format!("{:.1} h", g.degraded_secs / HOUR),
            g.transition_count().to_string(),
            format!("{:.1}", g.margin_instance_hours),
        ]);
    }
    opts.csv(
        "guardrail_ablation.csv",
        "scenario,controller,completed,iw_sla_attainment,gpu_hours,cost_usd,\
         epochs_fresh,epochs_held,epochs_reactive,degraded_secs,transitions,\
         actuations_dropped,actuations_delayed,margin_instance_hours",
        &rows,
    )?;
    print_table(
        "Guardrail ablation — control-plane faults per controller \
         (expect: the naive controller burns SLA or GPU-hours inside the \
          fault window; the guarded cascade holds attainment near the \
          no-fault row at a modest capacity-margin premium; the reactive \
          baseline is immune but scales late everywhere)",
        &[
            "scenario",
            "controller",
            "IW SLA",
            "gpu-h",
            "cost",
            "fresh/held/react",
            "degraded",
            "transitions",
            "margin-ih",
        ],
        &table,
    );
    Ok(())
}
