//! # SageServe — forecast-aware auto-scaling for LLM serving (reproduction)
//!
//! A three-layer reproduction of *SageServe: Optimizing LLM Serving on Cloud
//! Data Centers with Forecast Aware Auto-Scaling* (ACM 2025):
//!
//! * **Layer 3 (this crate)** — the coordination contribution: global/regional
//!   request routing, the NIW queue manager, instance-level schedulers
//!   (FCFS/EDF/PF/DPA), the forecast+ILP predictive autoscaler with its LT-I /
//!   LT-U / LT-UA deferral strategies, the Siloed / Reactive / Chiron
//!   baselines, and the SplitWise-style cloud-scale discrete-event simulator
//!   everything is evaluated on.
//! * **Layer 2 (python/compile, build-time only)** — the JAX graphs: a real
//!   byte-level transformer LM (prefill + decode with KV caches) and the
//!   seasonal-AR load-forecast pipeline, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels: tiled
//!   online-softmax attention and the batched AR forecast recursion.
//!
//! Python never runs on the request path: [`runtime`] loads the AOT
//! artifacts through PJRT and [`serve`] drives real batched inference from
//! Rust.  See `ARCHITECTURE.md` for the layer map with `file:symbol`
//! pointers, `DESIGN.md` for the systems inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

// Rustdoc is part of the verify gate (`make docs` runs `cargo doc
// --no-deps` with `-D warnings`).  The lint is crate-wide; modules whose
// public surface has not been audited yet carry a file-level
// `#![allow(missing_docs)]` with a debt note — drop those as they are
// documented.  config, perf, opt (bounded, ilp, simplex, capacity),
// coordinator::router, coordinator::queue_manager,
// coordinator::autoscaler, coordinator::controller,
// coordinator::scheduler, sim::cluster, sim::engine, sim::chunked,
// sim::event, sim::instance, sim::faults, forecast, trace, metrics and
// experiments are fully documented; the remaining debt is serve,
// runtime and util.
#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod forecast;
pub mod metrics;
pub mod opt;
pub mod perf;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

pub use config::{FleetSpec, GpuKind, ModelKind, Region, Tier};
