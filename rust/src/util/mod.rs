//! In-tree replacements for the usual crate ecosystem (this build
//! environment is fully offline — see Cargo.toml):
//!
//! * [`rng`] — xoshiro256++ PRNG plus normal / log-normal / Poisson
//!   samplers (replaces `rand`/`rand_distr`).
//! * [`json`] — a small recursive-descent JSON parser and writer
//!   (replaces `serde_json`; used for the AOT manifest, the golden
//!   self-test fixtures, and results output).
//! * [`bench`] — a minimal timing harness for `cargo bench` binaries
//!   (replaces `criterion`).
//! * [`proptest`] — seeded random-input sweep helper for property-style
//!   tests.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
