//! In-tree replacements for the usual crate ecosystem (this build
//! environment is fully offline — see Cargo.toml):
//!
//! * [`rng`] — xoshiro256++ PRNG plus normal / log-normal / Poisson
//!   samplers (replaces `rand`/`rand_distr`).
//! * [`json`] — a small recursive-descent JSON parser and writer
//!   (replaces `serde_json`; used for the AOT manifest, the golden
//!   self-test fixtures, and results output).
//! * [`bench`] — a minimal timing harness for `cargo bench` binaries
//!   (replaces `criterion`).
//! * [`proptest`] — seeded random-input sweep helper for property-style
//!   tests.

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
