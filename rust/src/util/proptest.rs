//! Property-test helper (offline replacement for `proptest`): run a
//! property over many seeded random cases and report the first failing
//! seed so failures are reproducible.

use crate::util::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` deterministic cases.  Panics
/// with the failing case's seed on the first property violation (the
/// property itself should panic/assert on failure).
pub fn run_cases(base_seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng, usize)) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        run_cases(1, 50, |rng, _| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        run_cases(2, 50, |rng, _| {
            assert!(rng.f64() < 0.9, "drew a large value");
        });
    }
}
