//! Deterministic PRNG + distribution samplers (offline replacement for
//! `rand` / `rand_distr`).
//!
//! Core generator: xoshiro256++ seeded via SplitMix64 — fast, high
//! quality, and stable across platforms so simulations are reproducible
//! byte-for-byte from a seed.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion (the reference seeding procedure).
        let mut sm = seed;
        let mut next_sm = move || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal with ln-space parameters (mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson sample.  Knuth's product method for small λ; for large λ
    /// the normal approximation with continuity correction (the error is
    /// far below the workload-model noise floor for λ > 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numeric guard
                }
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt());
            x.round().max(0.0) as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        let mean = m1 / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_mean_matches_formula() {
        let (mu, sigma) = (7.0f64, 0.8f64);
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += r.lognormal(mu, sigma);
        }
        let expect = (mu + sigma * sigma / 2.0).exp();
        let got = acc / n as f64;
        assert!((got / expect - 1.0).abs() < 0.03, "got {got} expect {expect}");
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut r = Rng::seed_from_u64(6);
        let lambda = 4.2;
        let n = 100_000;
        let mut acc = 0u64;
        for _ in 0..n {
            acc += r.poisson(lambda);
        }
        let mean = acc as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_large_lambda_moments() {
        let mut r = Rng::seed_from_u64(7);
        let lambda = 250.0;
        let n = 50_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.poisson(lambda) as f64;
            m1 += x;
            m2 += x * x;
        }
        let mean = m1 / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!((mean / lambda - 1.0).abs() < 0.01, "mean {mean}");
        assert!((var / lambda - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut r = Rng::seed_from_u64(8);
        assert_eq!(r.poisson(0.0), 0);
    }
}
