//! Deterministic PRNG + distribution samplers (offline replacement for
//! `rand` / `rand_distr`).
//!
//! Core generator: xoshiro256++ seeded via SplitMix64 — fast, high
//! quality, and stable across platforms so simulations are reproducible
//! byte-for-byte from a seed.
//!
//! Sampler notes (the trace pipeline's hot path — see PERF.md):
//! * `normal` is a *paired* Box–Muller: both the cosine and sine halves
//!   of each transform are consumed, halving the ln/sqrt/trig cost per
//!   normal draw.
//! * `poisson` uses Knuth's product method only below λ = 10; above
//!   that it switches to Hörmann's PTRS transformed rejection — O(1)
//!   expected draws for any λ, and exact (no normal approximation).
//! * [`AliasTable`] gives O(1) discrete sampling for fixed weight
//!   tables (Vose construction).
//! * [`Rng::seed_from_parts`] derives statistically independent
//!   counter-based streams from `(seed, chunk, stream)` — the basis of
//!   the chunk-parallel trace generator, where every minute bucket of
//!   every arrival stream gets its own RNG so generation order (and
//!   thread count) cannot affect the output.

/// SplitMix64 finalizer: a full-avalanche 64-bit mix.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second half of the last Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion (the reference seeding procedure).
        let mut sm = seed;
        let mut next_sm = move || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s, spare_normal: None }
    }

    /// Counter-based stream derivation: an independent generator for
    /// every `(seed, chunk, stream)` triple.  Each coordinate passes
    /// through a full-avalanche mix before combining, so neighbouring
    /// chunks/streams land in unrelated regions of the seed space.
    pub fn seed_from_parts(seed: u64, chunk: u64, stream: u64) -> Self {
        let mut h = seed;
        h = mix64(h ^ mix64(chunk.wrapping_add(0xd1b54a32d192ed03)));
        h = mix64(h ^ mix64(stream.wrapping_add(0x2545f4914f6cdd1d)));
        Rng::seed_from_u64(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Standard normal via paired Box–Muller: each transform yields two
    /// independent normals; the sine half is cached and returned by the
    /// next call instead of being discarded.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid ln(0)
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal with ln-space parameters (mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson sample.  Knuth's product method for small λ; Hörmann's
    /// PTRS transformed rejection (exact, O(1) expected iterations) for
    /// λ ≥ 10.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 10.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numeric guard
                }
            }
        } else {
            self.poisson_ptrs(lambda)
        }
    }

    /// PTRS: W. Hörmann, "The transformed rejection method for
    /// generating Poisson random variables" (1993).  Valid for λ ≥ 10.
    fn poisson_ptrs(&mut self, lambda: f64) -> u64 {
        let slam = lambda.sqrt();
        let loglam = lambda.ln();
        let b = 0.931 + 2.53 * slam;
        let a = -0.059 + 0.024_83 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.f64() - 0.5;
            let v = self.f64();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            if v.ln() + inv_alpha.ln() - (a / (us * us) + b).ln()
                <= k * loglam - lambda - ln_factorial(k as u64)
            {
                return k as u64;
            }
        }
    }
}

/// ln(k!) — exact product for small k, Stirling series (error < 1e-10
/// for k ≥ 16) above.
pub fn ln_factorial(k: u64) -> f64 {
    if k < 16 {
        let mut acc = 0.0;
        for i in 2..=k {
            acc += (i as f64).ln();
        }
        return acc;
    }
    let x = k as f64;
    const HALF_LN_2PI: f64 = 0.918_938_533_204_672_8; // ln(2π)/2
    (x + 0.5) * x.ln() - x + HALF_LN_2PI + 1.0 / (12.0 * x) - 1.0 / (360.0 * x * x * x)
}

/// O(1) discrete sampling over a fixed weight table (Vose's alias
/// method).  Build once, sample with a single uniform draw — replaces
/// per-call linear scans (which also re-summed the weights) on the
/// trace generator's per-request app-mix path.
#[derive(Debug, Clone)]
pub struct AliasTable {
    /// Acceptance threshold per column, pre-scaled to [0, 1].
    prob: Vec<f64>,
    /// Overflow target per column.
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from (unnormalized) non-negative weights.  Panics on an
    /// empty table or a non-positive total.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut prob = vec![1.0f64; n];
        let mut alias: Vec<u32> = (0..n as u32).collect();
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l as u32;
            scaled[l] -= 1.0 - scaled[s];
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers (numeric drift) keep prob = 1.0: always accepted.
        Self { prob, alias }
    }

    /// Number of columns (= number of weights).
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw an index with probability proportional to its weight.
    /// One uniform: the integer part picks the column, the fractional
    /// part decides accept-vs-alias.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64() * self.prob.len() as f64;
        let i = (x as usize).min(self.prob.len() - 1);
        if x - i as f64 <= self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn counter_streams_deterministic_and_distinct() {
        let mut a = Rng::seed_from_parts(42, 3, 7);
        let mut b = Rng::seed_from_parts(42, 3, 7);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Neighbouring chunks/streams must diverge immediately.
        let base = Rng::seed_from_parts(42, 3, 7).next_u64();
        assert_ne!(base, Rng::seed_from_parts(42, 4, 7).next_u64());
        assert_ne!(base, Rng::seed_from_parts(42, 3, 8).next_u64());
        assert_ne!(base, Rng::seed_from_parts(43, 3, 7).next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        let mean = m1 / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn paired_normals_are_uncorrelated() {
        // The cached sine half must be independent of the cosine half it
        // was generated with: near-zero correlation across pairs.
        let mut r = Rng::seed_from_u64(9);
        let n = 100_000;
        let (mut sx, mut sy, mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal(); // cosine half
            let y = r.normal(); // paired sine half
            sx += x;
            sy += y;
            sxy += x * y;
            sxx += x * x;
            syy += y * y;
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        let vx = sxx / nf - (sx / nf) * (sx / nf);
        let vy = syy / nf - (sy / nf) * (sy / nf);
        let corr = cov / (vx * vy).sqrt();
        assert!(corr.abs() < 0.01, "pair correlation {corr}");
        assert!((vx - 1.0).abs() < 0.02 && (vy - 1.0).abs() < 0.02);
    }

    #[test]
    fn lognormal_mean_matches_formula() {
        let (mu, sigma) = (7.0f64, 0.8f64);
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += r.lognormal(mu, sigma);
        }
        let expect = (mu + sigma * sigma / 2.0).exp();
        let got = acc / n as f64;
        assert!((got / expect - 1.0).abs() < 0.03, "got {got} expect {expect}");
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut r = Rng::seed_from_u64(6);
        let lambda = 4.2;
        let n = 100_000;
        let mut acc = 0u64;
        for _ in 0..n {
            acc += r.poisson(lambda);
        }
        let mean = acc as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_midrange_ptrs_moments() {
        // λ in the PTRS band (10 ≤ λ): mean and variance must both track
        // λ — the old normal-approximation band started at 30, so 12.5
        // and 35 exercise the new sampler on both sides of that line.
        for &lambda in &[12.5f64, 35.0] {
            let mut r = Rng::seed_from_u64(1234);
            let n = 200_000;
            let (mut m1, mut m2) = (0.0f64, 0.0f64);
            for _ in 0..n {
                let x = r.poisson(lambda) as f64;
                m1 += x;
                m2 += x * x;
            }
            let mean = m1 / n as f64;
            let var = m2 / n as f64 - mean * mean;
            assert!((mean / lambda - 1.0).abs() < 0.01, "λ={lambda} mean {mean}");
            assert!((var / lambda - 1.0).abs() < 0.03, "λ={lambda} var {var}");
        }
    }

    #[test]
    fn poisson_large_lambda_moments() {
        let mut r = Rng::seed_from_u64(7);
        let lambda = 250.0;
        let n = 50_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.poisson(lambda) as f64;
            m1 += x;
            m2 += x * x;
        }
        let mean = m1 / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!((mean / lambda - 1.0).abs() < 0.01, "mean {mean}");
        assert!((var / lambda - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut r = Rng::seed_from_u64(8);
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn ln_factorial_matches_product() {
        // Cross-check the Stirling branch against the exact product at
        // the switchover and beyond.
        for k in [0u64, 1, 5, 15, 16, 17, 40, 100] {
            let exact: f64 = (2..=k).map(|i| (i as f64).ln()).sum();
            assert!(
                (ln_factorial(k) - exact).abs() < 1e-8,
                "k={k}: {} vs {exact}",
                ln_factorial(k)
            );
        }
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [0.55, 0.15, 0.10, 0.07, 0.05, 0.05, 0.03];
        let table = AliasTable::new(&weights);
        assert_eq!(table.len(), weights.len());
        let mut rng = Rng::seed_from_u64(11);
        let n = 400_000;
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().sum();
        for (i, &w) in weights.iter().enumerate() {
            let got = counts[i] as f64 / n as f64;
            let want = w / total;
            assert!((got - want).abs() < 0.005, "idx {i}: got {got} want {want}");
        }
    }

    #[test]
    fn alias_table_degenerate_single_weight() {
        let table = AliasTable::new(&[3.0]);
        let mut rng = Rng::seed_from_u64(12);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
    }
}
