//! Minimal bench harness for `cargo bench` binaries (offline replacement
//! for `criterion`): warmup, timed iterations, mean / p50 / p95 report.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

/// True when `SAGESERVE_BENCH_QUICK` is set (CI smoke mode: cap
/// iterations so the whole bench suite finishes in seconds while still
/// emitting machine-readable numbers).
pub fn quick_mode() -> bool {
    std::env::var("SAGESERVE_BENCH_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Pick the iteration budget: `full` normally, `quick` under
/// `SAGESERVE_BENCH_QUICK=1`.
pub fn quick_iters(full: usize, quick: usize) -> usize {
    if quick_mode() {
        quick
    } else {
        full
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a warmup pass, then timed passes until either
/// `max_iters` or ~2 s of measurement, whichever first.  The closure's
/// return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, max_iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    for _ in 0..2.min(max_iters) {
        std::hint::black_box(f());
    }
    let budget_ns = 2e9;
    let mut samples = Vec::new();
    let started = Instant::now();
    for _ in 0..max_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if started.elapsed().as_nanos() as f64 > budget_ns && samples.len() >= 5 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples[n / 2],
        p95_ns: samples[(n as f64 * 0.95) as usize % n.max(1)],
    };
    result.report();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 50, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
