//! Minimal JSON: a recursive-descent parser and a writer (offline
//! replacement for `serde_json`).  Handles the full JSON grammar minus
//! exotic escapes (\u surrogate pairs map to the replacement character).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers → `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Required-field accessors with decent errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — wörld""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — wörld"));
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn writer_roundtrip_complex() {
        let src = r#"{"nested":{"arr":[1,2.5,"x",false,null]},"z":-7}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn f64_vec_helper() {
        let j = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert!(Json::parse(r#"[1, "x"]"#).unwrap().as_f64_vec().is_none());
    }
}
