//! The hourly load-forecast executable (Layer 2 graph + Layer 1 kernel).
//!
//! `forecast.hlo.txt` maps a `[S, T]` trailing TPS history to a `[S, H]`
//! forecast, where S = n_models × n_regions series at 15-minute resolution.
//! The Autoscaler calls this once per control epoch — never per request.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

use super::engine::{literal_f32, Engine};
use crate::util::json::Json;

/// Shape constants mirrored from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ForecastShape {
    pub n_series: usize,
    pub history: usize,
    pub season: usize,
    pub order: usize,
    pub horizon: usize,
}

impl ForecastShape {
    pub fn from_json(j: &Json) -> Result<ForecastShape> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow!("field '{k}' not a number"))
        };
        Ok(ForecastShape {
            n_series: u("n_series")?,
            history: u("history")?,
            season: u("season")?,
            order: u("order")?,
            horizon: u("horizon")?,
        })
    }
}

/// The compiled forecast graph.
pub struct ForecastExecutable {
    pub shape: ForecastShape,
    engine: Engine,
    path: PathBuf,
}

impl ForecastExecutable {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("open manifest.json (run `make artifacts`)")?;
        let manifest = Json::parse(&text).context("parse manifest.json")?;
        let shape = ForecastShape::from_json(manifest.req("forecast")?)?;
        let mut engine = Engine::cpu()?;
        let path = dir.join("forecast.hlo.txt");
        engine.load_hlo_text(&path)?;
        Ok(ForecastExecutable { shape, engine, path })
    }

    /// Forecast the next `horizon` steps for all series.
    ///
    /// `history` is row-major `[n_series, history]`, time ascending (newest
    /// last).  Returns row-major `[n_series, horizon]`, clamped at >= 0 by
    /// the graph.
    pub fn forecast(&self, history: &[f32]) -> Result<Vec<f32>> {
        let (s, t) = (self.shape.n_series, self.shape.history);
        anyhow::ensure!(history.len() == s * t, "history must be [{s}, {t}]");
        let lit = literal_f32(history, &[s, t])?;
        let out = self.engine.execute(&self.path, &[lit])?;
        anyhow::ensure!(out.len() == 1, "forecast returned {} outputs", out.len());
        let vals = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(vals.len() == s * self.shape.horizon, "bad forecast size");
        Ok(vals)
    }
}
