//! Generic PJRT engine: one CPU client + a cache of compiled executables.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT client plus compiled-executable cache, keyed by artifact path.
///
/// Compilation happens once per artifact (at load, not on the hot path);
/// `execute` is the only per-request call.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, executables: HashMap::new() })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (cached by path).
    pub fn load_hlo_text(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref().to_path_buf();
        if self.executables.contains_key(&path) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))
            .context("HLO text artifacts are produced by `make artifacts`")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        self.executables.insert(path, exe);
        Ok(())
    }

    /// Execute a loaded artifact.  jax lowers with `return_tuple=True`, so
    /// the single output is a tuple literal; this unpacks it into its
    /// elements.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        path: impl AsRef<Path>,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(path.as_ref())
            .ok_or_else(|| anyhow!("artifact not loaded: {}", path.as_ref().display()))?;
        let result = exe
            .execute::<L>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", path.as_ref().display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("decompose tuple: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} != data len {}", dims, data.len());
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} != data len {}", dims, data.len());
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e:?}"))
}
