//! Golden-output verification for the PJRT round trip.
//!
//! `python/compile/aot.py` runs the jitted jax graphs on fixed inputs and
//! records samples in `artifacts/selftest.json`; this module executes the
//! HLO artifacts on the same inputs through the Rust runtime and asserts
//! the numbers match — proving the AOT bridge (HLO text, weight blob,
//! argument ordering) is lossless end-to-end.

use anyhow::{Context, Result};

use crate::runtime::forecast_exec::ForecastExecutable;
use crate::runtime::tinylm::TinyLm;
use crate::util::json::Json;

/// Maximum |a-b| tolerated between jax and PJRT-on-rust (both f32).
const ATOL: f32 = 2e-3;

pub fn run(artifacts_dir: &str) -> Result<()> {
    let dir = std::path::Path::new(artifacts_dir);
    let text = std::fs::read_to_string(dir.join("selftest.json"))
        .context("open selftest.json (run `make artifacts`)")?;
    let golden = Json::parse(&text)?;

    // ---- tinylm prefill + greedy decode step ----
    let model = TinyLm::load(dir)?;
    let (b, s, vocab) = (model.cfg.batch, model.cfg.prefill_len, model.cfg.vocab);
    let tokens: Vec<i32> = golden
        .req("prefill_tokens")?
        .as_f64_vec()
        .context("prefill_tokens")?
        .into_iter()
        .map(|v| v as i32)
        .collect();
    anyhow::ensure!(tokens.len() == b * s, "token fixture shape");
    let pre = model.prefill(&tokens)?;

    let expect_head: Vec<f32> = golden
        .req("prefill_last_logits_head")?
        .as_f64_vec()
        .context("logits head")?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let mut max_err = 0.0f32;
    for lane in 0..b {
        for k in 0..8 {
            let got = pre.logits[(lane * s + (s - 1)) * vocab + k];
            let want = expect_head[lane * 8 + k];
            max_err = max_err.max((got - want).abs());
        }
    }
    anyhow::ensure!(max_err < ATOL, "prefill logits diverge: max err {max_err}");
    println!("prefill OK (max logit err {max_err:.2e})");

    let greedy: Vec<i32> = golden
        .req("greedy_next")?
        .as_f64_vec()
        .context("greedy_next")?
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let mut got_greedy = Vec::with_capacity(b);
    for lane in 0..b {
        let row = &pre.logits[(lane * s + (s - 1)) * vocab..(lane * s + s) * vocab];
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i as i32;
            }
        }
        got_greedy.push(best);
    }
    anyhow::ensure!(got_greedy == greedy, "greedy tokens diverge: {got_greedy:?} vs {greedy:?}");
    println!("greedy continuation OK ({greedy:?})");

    let pos = vec![s as i32; b];
    let dec = model.decode(&greedy, &pos, &pre.cache)?;
    let expect_dec: Vec<f32> = golden
        .req("decode_logits_head")?
        .as_f64_vec()
        .context("decode head")?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let mut max_err = 0.0f32;
    for lane in 0..b {
        for k in 0..8 {
            let got = dec.logits[lane * vocab + k];
            let want = expect_dec[lane * 8 + k];
            max_err = max_err.max((got - want).abs());
        }
    }
    anyhow::ensure!(max_err < ATOL, "decode logits diverge: max err {max_err}");
    println!("decode step OK (max logit err {max_err:.2e})");

    // ---- forecast graph ----
    let exe = ForecastExecutable::load(dir)?;
    let hist: Vec<f32> = golden
        .req("forecast_history")?
        .as_f64_vec()
        .context("forecast history")?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let expect: Vec<f32> = golden
        .req("forecast_out")?
        .as_f64_vec()
        .context("forecast out")?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let got = exe.forecast(&hist)?;
    anyhow::ensure!(got.len() == expect.len(), "forecast shape");
    let mut max_rel = 0.0f32;
    for (g, w) in got.iter().zip(&expect) {
        max_rel = max_rel.max((g - w).abs() / w.abs().max(1.0));
    }
    anyhow::ensure!(max_rel < 1e-3, "forecast diverges: max rel err {max_rel}");
    println!("forecast OK (max rel err {max_rel:.2e})");
    println!("selftest PASSED — jax and rust-PJRT agree on all artifacts");
    Ok(())
}
