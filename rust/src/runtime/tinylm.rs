//! The served model: load the tinylm manifest/weights and run
//! prefill/decode through PJRT.
//!
//! The Layer-2 graph takes its parameters as runtime inputs (not HLO
//! constants) so the HLO text stays small; jax flattens the params dict in
//! sorted-key order, which the manifest records as `hlo_param_order`.  This
//! loader replays exactly that ordering.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use super::engine::{literal_f32, literal_i32, Engine};
use crate::util::json::Json;

/// Architecture/shape constants mirrored from `manifest.json` (fixed at
/// AOT time by `python/compile/aot.py`).
#[derive(Debug, Clone)]
pub struct TinyLmConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub batch: usize,
    pub prefill_len: usize,
    pub head_dim: usize,
    pub seed: u64,
    pub params: Vec<ParamEntry>,
    pub hlo_param_order: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TinyLmConfig {
    /// Parse the `tinylm` section of `manifest.json`.
    pub fn from_json(j: &Json) -> Result<TinyLmConfig> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow!("field '{k}' not a number"))
        };
        let params = j
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not an array"))?
            .iter()
            .map(|e| {
                Ok(ParamEntry {
                    name: e.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: e
                        .req("shape")?
                        .as_f64_vec()
                        .ok_or_else(|| anyhow!("bad shape"))?
                        .into_iter()
                        .map(|v| v as usize)
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let hlo_param_order = j
            .req("hlo_param_order")?
            .as_arr()
            .ok_or_else(|| anyhow!("hlo_param_order not an array"))?
            .iter()
            .map(|e| e.as_str().unwrap_or_default().to_string())
            .collect();
        Ok(TinyLmConfig {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            max_len: u("max_len")?,
            batch: u("batch")?,
            prefill_len: u("prefill_len")?,
            head_dim: u("head_dim")?,
            seed: u("seed")? as u64,
            params,
            hlo_param_order,
        })
    }
}

/// KV cache state for one serving batch: `[L, B*H, M, dh]` buffers.
pub struct KvCache {
    pub k: xla::Literal,
    pub v: xla::Literal,
}

/// Prefill output: per-position logits plus the populated cache.
pub struct PrefillOut {
    /// `[B, S, vocab]` logits, flattened row-major.
    pub logits: Vec<f32>,
    pub cache: KvCache,
}

/// Decode output: next-token logits plus the updated cache.
pub struct DecodeOut {
    /// `[B, vocab]` logits, flattened row-major.
    pub logits: Vec<f32>,
    pub cache: KvCache,
}

/// The AOT-compiled transformer: weights pinned as literals, prefill and
/// decode executables compiled once.
pub struct TinyLm {
    pub cfg: TinyLmConfig,
    engine: Engine,
    prefill_path: PathBuf,
    decode_path: PathBuf,
    /// Parameter literals in HLO argument order (sorted by name).
    weights: Vec<xla::Literal>,
}

impl TinyLm {
    /// Load manifest, weights blob and both HLO artifacts from
    /// `artifacts/`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Self::load_inner(artifacts_dir.as_ref(), None)
    }

    /// Load a (prefill_len, max_len) shape variant exported for the Fig 9
    /// fidelity study.  Shares the base weights; only the HLO differs.
    pub fn load_variant(
        artifacts_dir: impl AsRef<Path>,
        prefill_len: usize,
        max_len: usize,
    ) -> Result<Self> {
        Self::load_inner(artifacts_dir.as_ref(), Some((prefill_len, max_len)))
    }

    fn load_inner(dir: &Path, variant: Option<(usize, usize)>) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("open {} (run `make artifacts`)", manifest_path.display()))?;
        let manifest = Json::parse(&text).context("parse manifest.json")?;
        let mut cfg = TinyLmConfig::from_json(manifest.req("tinylm")?)?;
        if let Some((s, m)) = variant {
            cfg.prefill_len = s;
            cfg.max_len = m;
        }

        // Weights blob: flat little-endian f32 in *manifest* order; the HLO
        // executable wants them in *sorted-name* order.
        let blob_path = dir.join("tinylm_params.bin");
        let mut raw = Vec::new();
        std::fs::File::open(&blob_path)
            .with_context(|| format!("open {}", blob_path.display()))?
            .read_to_end(&mut raw)?;
        let mut by_name: HashMap<&str, xla::Literal> = HashMap::new();
        let mut offset = 0usize;
        for entry in &cfg.params {
            let n: usize = entry.shape.iter().product();
            let bytes = n * 4;
            anyhow::ensure!(offset + bytes <= raw.len(), "weights blob truncated at {}", entry.name);
            let mut vals = vec![0f32; n];
            for (i, chunk) in raw[offset..offset + bytes].chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            by_name.insert(entry.name.as_str(), literal_f32(&vals, &entry.shape)?);
            offset += bytes;
        }
        anyhow::ensure!(offset == raw.len(), "weights blob has {} trailing bytes", raw.len() - offset);

        let mut weights = Vec::with_capacity(cfg.hlo_param_order.len());
        for name in &cfg.hlo_param_order {
            let lit = by_name
                .remove(name.as_str())
                .ok_or_else(|| anyhow!("manifest missing param {name}"))?;
            weights.push(lit);
        }

        let mut engine = Engine::cpu()?;
        let (prefill_path, decode_path) = match variant {
            None => (dir.join("tinylm_prefill.hlo.txt"), dir.join("tinylm_decode.hlo.txt")),
            Some((s, m)) => (
                dir.join(format!("tinylm_prefill_s{s}_m{m}.hlo.txt")),
                dir.join(format!("tinylm_decode_s{s}_m{m}.hlo.txt")),
            ),
        };
        engine.load_hlo_text(&prefill_path)?;
        engine.load_hlo_text(&decode_path)?;
        Ok(TinyLm { cfg, engine, prefill_path, decode_path, weights })
    }

    fn cache_dims(&self) -> [usize; 4] {
        [
            self.cfg.n_layers,
            self.cfg.batch * self.cfg.n_heads,
            self.cfg.max_len,
            self.cfg.head_dim,
        ]
    }

    /// Run prefill over a `[B, S]` token batch (right-padded with zeros).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let (b, s) = (self.cfg.batch, self.cfg.prefill_len);
        anyhow::ensure!(tokens.len() == b * s, "tokens must be [{b}, {s}]");
        let tok_lit = literal_i32(tokens, &[b, s])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.weights.len() + 1);
        args.extend(self.weights.iter());
        args.push(&tok_lit);
        let mut out = self.engine.execute(&self.prefill_path, &args)?;
        anyhow::ensure!(out.len() == 3, "prefill returned {} outputs", out.len());
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(logits.len() == b * s * self.cfg.vocab, "bad logits size");
        Ok(PrefillOut { logits, cache: KvCache { k, v } })
    }

    /// Run one decode step: token `token[i]` is written at `pos[i]` and the
    /// model predicts position `pos[i] + 1` for every lane.
    pub fn decode(&self, token: &[i32], pos: &[i32], cache: &KvCache) -> Result<DecodeOut> {
        let b = self.cfg.batch;
        anyhow::ensure!(token.len() == b && pos.len() == b, "token/pos must be [{b}]");
        let dims = self.cache_dims();
        for p in pos {
            anyhow::ensure!((*p as usize) < dims[2], "pos {p} out of cache range");
        }
        let tok_lit = literal_i32(token, &[b])?;
        let pos_lit = literal_i32(pos, &[b])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.weights.len() + 4);
        args.extend(self.weights.iter());
        args.push(&tok_lit);
        args.push(&pos_lit);
        args.push(&cache.k);
        args.push(&cache.v);
        let mut out = self.engine.execute(&self.decode_path, &args)?;
        anyhow::ensure!(out.len() == 3, "decode returned {} outputs", out.len());
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(logits.len() == b * self.cfg.vocab, "bad logits size");
        Ok(DecodeOut { logits, cache: KvCache { k, v } })
    }

    /// Greedy next token per lane from `[B, vocab]` logits.
    pub fn argmax(&self, logits: &[f32]) -> Vec<i32> {
        logits
            .chunks_exact(self.cfg.vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}
