//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.  HLO
//! *text* is the interchange format (jax ≥ 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects in proto form; the text parser reassigns
//! ids).  See `/opt/xla-example/README.md`.
//!
//! Submodules:
//! * [`engine`] — generic executable cache around one PJRT client.
//! * [`tinylm`] — the served transformer: weights blob + manifest loading,
//!   prefill/decode execution.
//! * [`forecast_exec`] — the hourly load-forecast executable.

// Rustdoc debt: public surface not yet audited for `missing_docs`
// (PR 4 audited config, perf, coordinator::router and sim::cluster);
// drop this allow once every pub item here is documented.
#![allow(missing_docs)]

pub mod engine;
pub mod forecast_exec;
pub mod selftest;
pub mod tinylm;

pub use engine::Engine;
pub use forecast_exec::ForecastExecutable;
pub use tinylm::{TinyLm, TinyLmConfig};
