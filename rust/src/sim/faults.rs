//! Deterministic fault plane: declarative, counter-seeded fault
//! schedules the engine injects alongside arrivals (ROADMAP item 3's
//! region-dark and spot-shock scenarios).
//!
//! A [`FaultPlan`] is a *pure description* — region outage windows,
//! a per-instance VM-crash hazard, spot-market preemption shocks that
//! reclaim donated capacity, and cross-region latency degradation
//! windows — compiled by [`FaultPlan::compile`] into fault
//! [`Event`](crate::sim::event::Event) variants at simulation start.
//! The engine processes them like any other event: an outage kills the
//! region's instances and re-enters their in-flight requests through
//! the retry path ([`RetryPolicy`]); a crash tick draws victims from a
//! counter-seeded RNG; a spot shock removes donated VMs from the
//! market pool.
//!
//! ## Determinism contract
//!
//! * **Empty plan ⇒ zero cost.** [`FaultPlan::compile`] pushes *no*
//!   events for an empty plan, so the event heap's sequence counter —
//!   and therefore every pop order, RNG draw and metric — is untouched:
//!   runs without faults are bit-identical to a build without the fault
//!   plane at all.
//! * **Counter-seeded hazard.** Crash draws use
//!   [`Rng::seed_from_parts`]`(seed, tick, FAULT_STREAM)` — a fresh
//!   stream per crash tick, exactly like the trace generator's
//!   per-minute streams — so no RNG *state* exists to carry across
//!   chunk boundaries and chunked execution stays bit-identical to
//!   sequential with faults active (`tests/chunked_equivalence.rs`).
//! * **Handoff.** The mutable fault-plane runtime state (availability
//!   mask, pending retries, recovery watches) lives in
//!   [`Cluster`](crate::sim::cluster::Cluster) and
//!   [`SimHandoff`](crate::sim::engine::SimHandoff); the plan itself is
//!   immutable config.
//!
//! ## Control-plane faults
//!
//! [`ControlFaultPlan`] is the *control-plane* sibling: instead of
//! killing VMs it rots the controller's inputs — forecast blackout and
//! corruption windows, telemetry freezes, forced capacity-solver
//! failures, and actuation faults (scale-outs silently dropped or
//! landing late).  Control faults are pure window predicates over `now`
//! (no events, no RNG), so an empty plan touches neither the event heap
//! nor any engine state: the bit-identity and chunked-equals-sequential
//! contracts above carry over for free (`tests/guardrail_equivalence.rs`).
//! The guardrail layer that keeps serving safe under these faults lives
//! in [`coordinator::controller`](crate::coordinator::controller).

use crate::config::{Region, Time, DAY, HOUR, MINUTE};
use crate::sim::event::{Event, EventQueue};
use crate::util::rng::Rng;

/// Stream constant for the fault plane's counter-seeded RNG (disjoint
/// from every trace-generator stream, which are small indices).
pub const FAULT_STREAM: u64 = 0xFA17_0175;

/// One region-outage window: at `start` every VM in `region` is lost
/// (in-flight work killed into the retry path, the donated spot pool
/// reclaimed, the region masked out of routing); at `end` the mask
/// lifts and the engine re-seeds the region's endpoints with
/// minimum-floor replacement VMs at realistic provisioning lead time.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionOutage {
    /// The region that goes dark.
    pub region: Region,
    /// Outage start (simulated seconds).
    pub start: Time,
    /// Outage end — when routing may use the region again.
    pub end: Time,
}

/// One spot-market preemption shock: at `at`, the external market
/// reclaims `frac` of every region's donated spot pool (the VMs are
/// gone — they do not return when the shock passes).
#[derive(Debug, Clone, PartialEq)]
pub struct SpotShock {
    /// Shock instant (simulated seconds).
    pub at: Time,
    /// Fraction of each region's donated pool reclaimed, in [0, 1].
    pub frac: f64,
}

/// One cross-region latency degradation window: requests served in
/// `region` pay `extra` seconds on top of normal routing latency, and
/// the *retry* path avoids the region while the window is open (normal
/// traffic still uses it — degraded beats dead).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyDegradation {
    /// The degraded region.
    pub region: Region,
    /// Window start (simulated seconds).
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// Extra latency charged per request served in the region (secs).
    pub extra: Time,
}

/// Capped-exponential-backoff retry policy for requests killed by
/// instance loss.  Attempt `n` (1-based) waits
/// `min(base_backoff · 2^(n−1), max_backoff)` before re-routing; after
/// `max_attempts` failures the request is permanently lost.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// First-attempt backoff (secs).
    pub base_backoff: Time,
    /// Backoff ceiling (secs) — the "capped" in capped exponential.
    pub max_backoff: Time,
    /// Kill count after which a request is declared lost.
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { base_backoff: 1.0, max_backoff: MINUTE, max_attempts: 5 }
    }
}

impl RetryPolicy {
    /// Backoff delay before retry attempt `attempt` (1-based), capped
    /// at [`RetryPolicy::max_backoff`].
    pub fn backoff(&self, attempt: u32) -> Time {
        let exp = attempt.saturating_sub(1).min(52);
        (self.base_backoff * (1u64 << exp) as f64).min(self.max_backoff)
    }
}

/// A declarative fault schedule.  `FaultPlan::default()` is empty —
/// the zero-cost no-fault configuration every existing experiment runs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Region outage windows.
    pub outages: Vec<RegionOutage>,
    /// Latency degradation windows.
    pub degradations: Vec<LatencyDegradation>,
    /// Spot-market preemption shocks.
    pub spot_shocks: Vec<SpotShock>,
    /// Expected VM crashes per instance-day (0 = no crash hazard).
    /// Sampled per live instance on a counter-seeded tick cadence.
    pub crash_rate_per_day: f64,
    /// Crash-hazard sampling interval (secs).
    pub crash_check_secs: Time,
    /// Retry policy applied to every killed request.
    pub retry: RetryPolicy,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            outages: Vec::new(),
            degradations: Vec::new(),
            spot_shocks: Vec::new(),
            crash_rate_per_day: 0.0,
            crash_check_secs: MINUTE,
            retry: RetryPolicy::default(),
        }
    }
}

impl FaultPlan {
    /// True when the plan injects nothing — the default, and the gate
    /// for every fault-plane code path in the engine.
    pub fn is_empty(&self) -> bool {
        self.outages.is_empty()
            && self.degradations.is_empty()
            && self.spot_shocks.is_empty()
            && self.crash_rate_per_day <= 0.0
    }

    /// Compile the plan into events.  Pushes **nothing** for an empty
    /// plan, so the event heap's sequence counter is untouched and
    /// no-fault runs stay bit-identical to a fault-plane-free build.
    /// Windows starting at or past `horizon` (trace end) are skipped;
    /// an end event is always paired with its start.
    pub fn compile(&self, events: &mut EventQueue, horizon: Time) {
        for (idx, o) in self.outages.iter().enumerate() {
            debug_assert!(o.end > o.start, "outage window must be positive");
            if o.start < horizon {
                events.push(o.start, Event::FaultOutageStart { idx });
                events.push(o.end, Event::FaultOutageEnd { idx });
            }
        }
        for (idx, d) in self.degradations.iter().enumerate() {
            debug_assert!(d.end > d.start, "degradation window must be positive");
            if d.start < horizon {
                events.push(d.start, Event::FaultDegradeStart { idx });
                events.push(d.end, Event::FaultDegradeEnd { idx });
            }
        }
        for (idx, s) in self.spot_shocks.iter().enumerate() {
            if s.at < horizon {
                events.push(s.at, Event::FaultSpotShock { idx });
            }
        }
        if self.crash_rate_per_day > 0.0 {
            debug_assert!(self.crash_check_secs > 0.0);
            events.push(self.crash_check_secs, Event::FaultCrashTick { k: 1 });
        }
    }

    /// The counter-seeded RNG for crash tick `k`: a pure function of
    /// `(seed, k)`, so chunked and sequential execution draw identical
    /// hazards with no RNG state in the handoff.
    pub fn crash_rng(seed: u64, k: u64) -> Rng {
        Rng::seed_from_parts(seed, k, FAULT_STREAM)
    }

    /// Per-instance crash probability per [`FaultPlan::crash_check_secs`] tick.
    pub fn crash_prob_per_tick(&self) -> f64 {
        (self.crash_rate_per_day * self.crash_check_secs / DAY).clamp(0.0, 1.0)
    }

    /// Preset: one region dark over `[start, end)`.
    pub fn region_dark(region: Region, start: Time, end: Time) -> FaultPlan {
        FaultPlan {
            outages: vec![RegionOutage { region, start, end }],
            ..FaultPlan::default()
        }
    }

    /// Preset: one market-wide spot preemption shock.
    pub fn spot_shock(at: Time, frac: f64) -> FaultPlan {
        FaultPlan { spot_shocks: vec![SpotShock { at, frac }], ..FaultPlan::default() }
    }

    /// Parse a CLI fault spec: `;`-separated clauses of
    ///
    /// * `region-dark=<region>@<start>-<end>` — outage window;
    /// * `degrade=<region>@<start>-<end>:<extra>` — latency window;
    /// * `spot-shock=<frac>@<t>` — market preemption shock;
    /// * `crash=<rate-per-instance-day>` — crash hazard;
    /// * `retry=<base>/<max>/<attempts>` — retry policy override.
    ///
    /// Times accept `s`/`m`/`h`/`d` suffixes (`48h`, `2d`, `90m`,
    /// `30s`, bare seconds).  Example:
    /// `region-dark=centralus@48h-60h;crash=0.05`.
    ///
    /// Errors name the offending clause, so `simulate --faults` misuse
    /// fails loudly instead of silently running faultless.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in s.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let bad = |what: &str| format!("bad fault clause '{clause}': {what}");
            let (key, val) =
                clause.split_once('=').ok_or_else(|| bad("expected <key>=<value>"))?;
            match key.trim() {
                "region-dark" | "outage" => {
                    let (region, rest) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected <region>@<start>-<end>"))?;
                    let (start, end) = parse_window(rest).ok_or_else(|| bad(BAD_WINDOW))?;
                    plan.outages.push(RegionOutage {
                        region: parse_region(region.trim()).ok_or_else(|| bad(BAD_REGION))?,
                        start,
                        end,
                    });
                }
                "degrade" => {
                    let (region, rest) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected <region>@<start>-<end>:<extra>"))?;
                    let (window, extra) = rest
                        .rsplit_once(':')
                        .ok_or_else(|| bad("expected an ':<extra>' latency suffix"))?;
                    let (start, end) = parse_window(window).ok_or_else(|| bad(BAD_WINDOW))?;
                    plan.degradations.push(LatencyDegradation {
                        region: parse_region(region.trim()).ok_or_else(|| bad(BAD_REGION))?,
                        start,
                        end,
                        extra: parse_time(extra.trim()).ok_or_else(|| bad(BAD_TIME))?,
                    });
                }
                "spot-shock" => {
                    let (frac, at) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected <frac>@<t>"))?;
                    let frac: f64 = frac
                        .trim()
                        .parse()
                        .map_err(|_| bad("fraction is not a number"))?;
                    if !(0.0..=1.0).contains(&frac) {
                        return Err(bad("fraction must be in [0, 1]"));
                    }
                    let at = parse_time(at.trim()).ok_or_else(|| bad(BAD_TIME))?;
                    plan.spot_shocks.push(SpotShock { at, frac });
                }
                "crash" => {
                    let rate: f64 =
                        val.trim().parse().map_err(|_| bad("rate is not a number"))?;
                    if !rate.is_finite() || rate < 0.0 {
                        return Err(bad("rate must be finite and >= 0"));
                    }
                    plan.crash_rate_per_day = rate;
                }
                "retry" => {
                    let mut parts = val.split('/');
                    let mut next =
                        || parts.next().ok_or_else(|| bad("expected <base>/<max>/<attempts>"));
                    let base = parse_time(next()?.trim()).ok_or_else(|| bad(BAD_TIME))?;
                    let max = parse_time(next()?.trim()).ok_or_else(|| bad(BAD_TIME))?;
                    let attempts: u32 = next()?
                        .trim()
                        .parse()
                        .map_err(|_| bad("attempt count is not an integer"))?;
                    if parts.next().is_some() {
                        return Err(bad("expected exactly <base>/<max>/<attempts>"));
                    }
                    plan.retry = RetryPolicy {
                        base_backoff: base,
                        max_backoff: max,
                        max_attempts: attempts,
                    };
                }
                other => {
                    return Err(bad(&format!(
                        "unknown key '{other}' \
                         (region-dark|outage|degrade|spot-shock|crash|retry)"
                    )))
                }
            }
        }
        Ok(plan)
    }
}

/// One forecast-corruption window: while it is open, every forecast
/// value the controller consumes is distorted to
/// `max(0, value * scale + bias)` before it reaches the capacity ILP.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastCorruption {
    /// Window start (simulated seconds).
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// Multiplicative distortion applied to every forecast value.
    pub scale: f64,
    /// Additive bias (input TPS) applied after scaling.
    pub bias: f64,
}

/// One actuation-delay window: every scale-out committed while it is
/// open lands `extra` seconds later than the provisioning model says
/// (the cloud control plane acknowledged the request but executed it
/// late).
#[derive(Debug, Clone, PartialEq)]
pub struct ActuationDelay {
    /// Window start (simulated seconds).
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// Extra provisioning lead time (secs) added to each scale-out.
    pub extra: Time,
}

/// A declarative *control-plane* fault schedule — the sibling of
/// [`FaultPlan`] that rots the controller's inputs and outputs instead
/// of the data plane's VMs.
///
/// Every fault is a half-open `[start, end)` window queried as a pure
/// function of `now`: nothing is compiled into events and no RNG is
/// drawn, so `ControlFaultPlan::default()` (empty) leaves the engine
/// bit-identical to a build without control faults at all, and chunked
/// execution stays bit-identical to sequential with faults active
/// (the window predicates are stateless; the guardrail state they
/// provoke rides [`SimHandoff`](crate::sim::engine::SimHandoff)).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ControlFaultPlan {
    /// Forecast blackout windows: the forecaster returns nothing, which
    /// a naive controller consumes as zero predicted demand.
    pub forecast_blackouts: Vec<(Time, Time)>,
    /// Forecast corruption windows (scaled/biased forecaster output).
    pub forecast_corruptions: Vec<ForecastCorruption>,
    /// Telemetry freeze windows: the controller sees utilization and
    /// queue-depth signals frozen at the last pre-freeze sample.
    pub telemetry_freezes: Vec<(Time, Time)>,
    /// Solver failure windows: every capacity solve reports the
    /// infeasible/iteration-cap outcome (`None`).
    pub solver_failures: Vec<(Time, Time)>,
    /// Actuation drop windows: scale-outs are silently swallowed — the
    /// controller believes they succeeded.
    pub actuation_drops: Vec<(Time, Time)>,
    /// Actuation delay windows: scale-outs land with extra lead time.
    pub actuation_delays: Vec<ActuationDelay>,
}

/// Is `now` inside any half-open `[start, end)` window?
fn in_window(windows: &[(Time, Time)], now: Time) -> bool {
    windows.iter().any(|&(s, e)| now >= s && now < e)
}

impl ControlFaultPlan {
    /// True when the plan injects nothing — the default, and the gate
    /// for every control-fault code path in the engine and controller.
    pub fn is_empty(&self) -> bool {
        self.forecast_blackouts.is_empty()
            && self.forecast_corruptions.is_empty()
            && self.telemetry_freezes.is_empty()
            && self.solver_failures.is_empty()
            && self.actuation_drops.is_empty()
            && self.actuation_delays.is_empty()
    }

    /// Is a forecast blackout open at `now`?
    pub fn forecast_blackout_at(&self, now: Time) -> bool {
        in_window(&self.forecast_blackouts, now)
    }

    /// The `(scale, bias)` of the first forecast-corruption window open
    /// at `now`, if any.
    pub fn forecast_corruption_at(&self, now: Time) -> Option<(f64, f64)> {
        self.forecast_corruptions
            .iter()
            .find(|c| now >= c.start && now < c.end)
            .map(|c| (c.scale, c.bias))
    }

    /// Is the telemetry feed frozen at `now`?
    pub fn telemetry_frozen_at(&self, now: Time) -> bool {
        in_window(&self.telemetry_freezes, now)
    }

    /// The last good telemetry instant while frozen: the earliest start
    /// among freeze windows containing `now`, or `None` when live.
    pub fn telemetry_frozen_since(&self, now: Time) -> Option<Time> {
        self.telemetry_freezes
            .iter()
            .filter(|&&(s, e)| now >= s && now < e)
            .map(|&(s, _)| s)
            .fold(None, |acc: Option<Time>, s| Some(acc.map_or(s, |a| a.min(s))))
    }

    /// Is the capacity solver forced to fail at `now`?
    pub fn solver_fault_at(&self, now: Time) -> bool {
        in_window(&self.solver_failures, now)
    }

    /// Are scale-out actuations silently dropped at `now`?
    pub fn actuation_drop_at(&self, now: Time) -> bool {
        in_window(&self.actuation_drops, now)
    }

    /// Extra provisioning lead time for a scale-out committed at `now`
    /// (the worst open delay window; 0 when none is open).
    pub fn actuation_extra_lead_at(&self, now: Time) -> Time {
        self.actuation_delays
            .iter()
            .filter(|d| now >= d.start && now < d.end)
            .map(|d| d.extra)
            .fold(0.0, f64::max)
    }

    /// Is *any* control fault open at `now`?  (Degraded-mode accounting.)
    pub fn any_fault_at(&self, now: Time) -> bool {
        self.forecast_blackout_at(now)
            || self.forecast_corruption_at(now).is_some()
            || self.telemetry_frozen_at(now)
            || self.solver_fault_at(now)
            || self.actuation_drop_at(now)
            || self.actuation_extra_lead_at(now) > 0.0
    }

    /// Preset: one forecast blackout over `[start, end)` — the
    /// `exp guardrails` headline scenario.
    pub fn forecast_blackout(start: Time, end: Time) -> ControlFaultPlan {
        ControlFaultPlan {
            forecast_blackouts: vec![(start, end)],
            ..ControlFaultPlan::default()
        }
    }

    /// Preset: one telemetry freeze over `[start, end)`.
    pub fn stale_telemetry(start: Time, end: Time) -> ControlFaultPlan {
        ControlFaultPlan {
            telemetry_freezes: vec![(start, end)],
            ..ControlFaultPlan::default()
        }
    }

    /// Parse a CLI control-fault spec: `;`-separated clauses of
    ///
    /// * `forecast-blackout=<start>-<end>` — forecaster returns nothing;
    /// * `forecast-corrupt=<scale>@<start>-<end>[:<bias>]` — scaled
    ///   (and optionally biased, in input TPS) forecaster output;
    /// * `telemetry-freeze=<start>-<end>` — stale telemetry window;
    /// * `solver-fail=<start>-<end>` — forced capacity-solve failures;
    /// * `act-drop=<start>-<end>` — scale-outs silently dropped;
    /// * `act-delay=<extra>@<start>-<end>` — scale-outs land late.
    ///
    /// Times accept the same `s`/`m`/`h`/`d` suffixes as
    /// [`FaultPlan::parse`]; errors name the offending clause.  Example:
    /// `forecast-blackout=36h-60h;act-delay=20m@36h-60h`.
    pub fn parse(s: &str) -> Result<ControlFaultPlan, String> {
        let mut plan = ControlFaultPlan::default();
        for clause in s.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let bad = |what: &str| format!("bad control-fault clause '{clause}': {what}");
            let (key, val) =
                clause.split_once('=').ok_or_else(|| bad("expected <key>=<value>"))?;
            match key.trim() {
                "forecast-blackout" => {
                    plan.forecast_blackouts
                        .push(parse_window(val).ok_or_else(|| bad(BAD_WINDOW))?);
                }
                "forecast-corrupt" => {
                    let (scale, rest) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected <scale>@<start>-<end>[:<bias>]"))?;
                    let scale: f64 =
                        scale.trim().parse().map_err(|_| bad("scale is not a number"))?;
                    if !scale.is_finite() || scale < 0.0 {
                        return Err(bad("scale must be finite and >= 0"));
                    }
                    let (window, bias) = match rest.rsplit_once(':') {
                        Some((w, b)) => {
                            let bias: f64 =
                                b.trim().parse().map_err(|_| bad("bias is not a number"))?;
                            if !bias.is_finite() {
                                return Err(bad("bias must be finite"));
                            }
                            (w, bias)
                        }
                        None => (rest, 0.0),
                    };
                    let (start, end) = parse_window(window).ok_or_else(|| bad(BAD_WINDOW))?;
                    plan.forecast_corruptions.push(ForecastCorruption {
                        start,
                        end,
                        scale,
                        bias,
                    });
                }
                "telemetry-freeze" => {
                    plan.telemetry_freezes
                        .push(parse_window(val).ok_or_else(|| bad(BAD_WINDOW))?);
                }
                "solver-fail" => {
                    plan.solver_failures
                        .push(parse_window(val).ok_or_else(|| bad(BAD_WINDOW))?);
                }
                "act-drop" => {
                    plan.actuation_drops
                        .push(parse_window(val).ok_or_else(|| bad(BAD_WINDOW))?);
                }
                "act-delay" => {
                    let (extra, window) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected <extra>@<start>-<end>"))?;
                    let extra = parse_time(extra.trim()).ok_or_else(|| bad(BAD_TIME))?;
                    let (start, end) = parse_window(window).ok_or_else(|| bad(BAD_WINDOW))?;
                    plan.actuation_delays.push(ActuationDelay { start, end, extra });
                }
                other => {
                    return Err(bad(&format!(
                        "unknown key '{other}' (forecast-blackout|forecast-corrupt|\
                         telemetry-freeze|solver-fail|act-drop|act-delay)"
                    )))
                }
            }
        }
        Ok(plan)
    }
}

/// Shared parse-error fragments (clause context is prepended by the
/// caller).
const BAD_WINDOW: &str =
    "expected a <start>-<end> window with end > start (s/m/h/d suffixes)";
/// See [`BAD_WINDOW`].
const BAD_TIME: &str = "expected a duration (s/m/h/d suffixes, >= 0)";
/// See [`BAD_WINDOW`].
const BAD_REGION: &str = "unknown region (eastus|centralus|westus)";

/// Parse `<start>-<end>` with time-suffix bounds.
fn parse_window(s: &str) -> Option<(Time, Time)> {
    let (a, b) = s.split_once('-')?;
    let (start, end) = (parse_time(a.trim())?, parse_time(b.trim())?);
    if end > start {
        Some((start, end))
    } else {
        None
    }
}

/// Parse a duration with an optional `s`/`m`/`h`/`d` suffix.
fn parse_time(s: &str) -> Option<Time> {
    let (num, mult) = match s.as_bytes().last()? {
        b'd' => (&s[..s.len() - 1], DAY),
        b'h' => (&s[..s.len() - 1], HOUR),
        b'm' => (&s[..s.len() - 1], MINUTE),
        b's' => (&s[..s.len() - 1], 1.0),
        _ => (s, 1.0),
    };
    let v: f64 = num.trim().parse().ok()?;
    if v.is_finite() && v >= 0.0 {
        Some(v * mult)
    } else {
        None
    }
}

fn parse_region(s: &str) -> Option<Region> {
    match s.to_ascii_lowercase().as_str() {
        "eastus" | "east" => Some(Region::EastUs),
        "centralus" | "central" => Some(Region::CentralUs),
        "westus" | "west" => Some(Region::WestUs),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty_and_compiles_to_nothing() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        let mut q = EventQueue::new();
        plan.compile(&mut q, 7.0 * DAY);
        assert!(q.is_empty(), "empty plan must push zero events");
    }

    #[test]
    fn compile_pairs_window_events_and_skips_past_horizon() {
        let mut plan = FaultPlan::region_dark(Region::CentralUs, 2.0 * DAY, 2.5 * DAY);
        plan.spot_shocks.push(SpotShock { at: 3.0 * DAY, frac: 0.5 });
        plan.spot_shocks.push(SpotShock { at: 30.0 * DAY, frac: 0.5 }); // past horizon
        plan.crash_rate_per_day = 0.1;
        assert!(!plan.is_empty());
        let mut q = EventQueue::new();
        plan.compile(&mut q, 7.0 * DAY);
        // outage start + end, one in-horizon shock, first crash tick.
        assert_eq!(q.len(), 4);
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, MINUTE);
        assert_eq!(e, Event::FaultCrashTick { k: 1 });
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let pol = RetryPolicy::default();
        assert_eq!(pol.backoff(1), 1.0);
        assert_eq!(pol.backoff(2), 2.0);
        assert_eq!(pol.backoff(3), 4.0);
        assert_eq!(pol.backoff(7), 60.0, "must cap at max_backoff");
        assert_eq!(pol.backoff(60), 60.0, "huge attempt counts must not overflow");
        let tight = RetryPolicy { base_backoff: 0.5, max_backoff: 3.0, max_attempts: 9 };
        assert_eq!(tight.backoff(1), 0.5);
        assert_eq!(tight.backoff(4), 3.0);
    }

    #[test]
    fn crash_rng_is_a_pure_function_of_seed_and_tick() {
        let a = FaultPlan::crash_rng(42, 7).next_u64();
        let b = FaultPlan::crash_rng(42, 7).next_u64();
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::crash_rng(42, 8).next_u64());
        assert_ne!(a, FaultPlan::crash_rng(43, 7).next_u64());
    }

    #[test]
    fn parse_roundtrips_the_clause_grammar() {
        let plan = FaultPlan::parse(
            "region-dark=centralus@48h-60h; spot-shock=0.5@72h; crash=0.25; \
             degrade=westus@1d-2d:0.2s; retry=2s/30s/4",
        )
        .expect("valid spec");
        assert_eq!(plan.outages.len(), 1);
        assert_eq!(plan.outages[0].region, Region::CentralUs);
        assert_eq!(plan.outages[0].start, 48.0 * HOUR);
        assert_eq!(plan.outages[0].end, 60.0 * HOUR);
        assert_eq!(plan.spot_shocks, vec![SpotShock { at: 72.0 * HOUR, frac: 0.5 }]);
        assert_eq!(plan.crash_rate_per_day, 0.25);
        assert_eq!(plan.degradations[0].region, Region::WestUs);
        assert_eq!(plan.degradations[0].extra, 0.2);
        assert_eq!(
            plan.retry,
            RetryPolicy { base_backoff: 2.0, max_backoff: 30.0, max_attempts: 4 }
        );

        let err = FaultPlan::parse("region-dark=nowhere@1h-2h").unwrap_err();
        assert!(err.contains("region-dark=nowhere@1h-2h"), "error names the clause: {err}");
        assert!(FaultPlan::parse("spot-shock=1.5@1h").is_err(), "frac > 1 rejected");
        assert!(FaultPlan::parse("region-dark=eastus@2h-1h").is_err(), "inverted window");
        let err = FaultPlan::parse("bogus=1").unwrap_err();
        assert!(err.contains("unknown key 'bogus'"), "unknown keys are named: {err}");
        assert!(FaultPlan::parse("crash").is_err(), "missing '=' rejected");
    }

    #[test]
    fn control_plan_default_is_empty_and_queries_false() {
        let plan = ControlFaultPlan::default();
        assert!(plan.is_empty());
        assert!(!plan.any_fault_at(0.0));
        assert!(!plan.forecast_blackout_at(HOUR));
        assert!(plan.forecast_corruption_at(HOUR).is_none());
        assert_eq!(plan.actuation_extra_lead_at(HOUR), 0.0);
    }

    #[test]
    fn control_windows_are_half_open() {
        let plan = ControlFaultPlan::forecast_blackout(HOUR, 2.0 * HOUR);
        assert!(!plan.is_empty());
        assert!(!plan.forecast_blackout_at(HOUR - 1.0));
        assert!(plan.forecast_blackout_at(HOUR));
        assert!(plan.forecast_blackout_at(2.0 * HOUR - 1.0));
        assert!(!plan.forecast_blackout_at(2.0 * HOUR), "end is exclusive");
        assert!(plan.any_fault_at(HOUR));

        let stale = ControlFaultPlan::stale_telemetry(0.0, HOUR);
        assert!(stale.telemetry_frozen_at(0.0));
        assert!(!stale.telemetry_frozen_at(HOUR));
        assert!(!stale.forecast_blackout_at(0.5 * HOUR), "presets are independent");
    }

    #[test]
    fn control_parse_roundtrips_the_clause_grammar() {
        let plan = ControlFaultPlan::parse(
            "forecast-blackout=36h-60h; forecast-corrupt=0.5@1d-2d:-100; \
             telemetry-freeze=12h-18h; solver-fail=2d-3d; act-drop=1h-2h; \
             act-delay=20m@36h-60h",
        )
        .expect("valid spec");
        assert_eq!(plan.forecast_blackouts, vec![(36.0 * HOUR, 60.0 * HOUR)]);
        assert_eq!(
            plan.forecast_corruptions,
            vec![ForecastCorruption { start: DAY, end: 2.0 * DAY, scale: 0.5, bias: -100.0 }]
        );
        assert_eq!(plan.telemetry_freezes, vec![(12.0 * HOUR, 18.0 * HOUR)]);
        assert_eq!(plan.solver_failures, vec![(2.0 * DAY, 3.0 * DAY)]);
        assert_eq!(plan.actuation_drops, vec![(HOUR, 2.0 * HOUR)]);
        assert_eq!(
            plan.actuation_delays,
            vec![ActuationDelay { start: 36.0 * HOUR, end: 60.0 * HOUR, extra: 20.0 * MINUTE }]
        );
        assert_eq!(plan.forecast_corruption_at(1.5 * DAY), Some((0.5, -100.0)));
        assert_eq!(plan.actuation_extra_lead_at(40.0 * HOUR), 20.0 * MINUTE);

        // Bias defaults to zero when the `:<bias>` suffix is omitted.
        let noscale = ControlFaultPlan::parse("forecast-corrupt=2@1h-2h").expect("valid");
        assert_eq!(noscale.forecast_corruptions[0].bias, 0.0);
        assert_eq!(noscale.forecast_corruptions[0].scale, 2.0);

        let err = ControlFaultPlan::parse("forecast-blackout=2h-1h").unwrap_err();
        assert!(err.contains("forecast-blackout=2h-1h"), "error names the clause: {err}");
        assert!(ControlFaultPlan::parse("forecast-corrupt=-1@1h-2h").is_err());
        assert!(ControlFaultPlan::parse("act-delay=1h-2h").is_err(), "missing '@'");
        let err = ControlFaultPlan::parse("bogus=1").unwrap_err();
        assert!(err.contains("unknown key 'bogus'"), "unknown keys are named: {err}");
        assert!(ControlFaultPlan::parse("").expect("empty spec ok").is_empty());
    }

    #[test]
    fn overlapping_delay_windows_take_the_worst_extra_lead() {
        let plan = ControlFaultPlan {
            actuation_delays: vec![
                ActuationDelay { start: 0.0, end: 2.0 * HOUR, extra: 60.0 },
                ActuationDelay { start: HOUR, end: 3.0 * HOUR, extra: 300.0 },
            ],
            ..ControlFaultPlan::default()
        };
        assert_eq!(plan.actuation_extra_lead_at(0.5 * HOUR), 60.0);
        assert_eq!(plan.actuation_extra_lead_at(1.5 * HOUR), 300.0);
        assert_eq!(plan.actuation_extra_lead_at(2.5 * HOUR), 300.0);
        assert_eq!(plan.actuation_extra_lead_at(3.5 * HOUR), 0.0);
    }
}
