//! Deterministic fault plane: declarative, counter-seeded fault
//! schedules the engine injects alongside arrivals (ROADMAP item 3's
//! region-dark and spot-shock scenarios).
//!
//! A [`FaultPlan`] is a *pure description* — region outage windows,
//! a per-instance VM-crash hazard, spot-market preemption shocks that
//! reclaim donated capacity, and cross-region latency degradation
//! windows — compiled by [`FaultPlan::compile`] into fault
//! [`Event`](crate::sim::event::Event) variants at simulation start.
//! The engine processes them like any other event: an outage kills the
//! region's instances and re-enters their in-flight requests through
//! the retry path ([`RetryPolicy`]); a crash tick draws victims from a
//! counter-seeded RNG; a spot shock removes donated VMs from the
//! market pool.
//!
//! ## Determinism contract
//!
//! * **Empty plan ⇒ zero cost.** [`FaultPlan::compile`] pushes *no*
//!   events for an empty plan, so the event heap's sequence counter —
//!   and therefore every pop order, RNG draw and metric — is untouched:
//!   runs without faults are bit-identical to a build without the fault
//!   plane at all.
//! * **Counter-seeded hazard.** Crash draws use
//!   [`Rng::seed_from_parts`]`(seed, tick, FAULT_STREAM)` — a fresh
//!   stream per crash tick, exactly like the trace generator's
//!   per-minute streams — so no RNG *state* exists to carry across
//!   chunk boundaries and chunked execution stays bit-identical to
//!   sequential with faults active (`tests/chunked_equivalence.rs`).
//! * **Handoff.** The mutable fault-plane runtime state (availability
//!   mask, pending retries, recovery watches) lives in
//!   [`Cluster`](crate::sim::cluster::Cluster) and
//!   [`SimHandoff`](crate::sim::engine::SimHandoff); the plan itself is
//!   immutable config.

use crate::config::{Region, Time, DAY, HOUR, MINUTE};
use crate::sim::event::{Event, EventQueue};
use crate::util::rng::Rng;

/// Stream constant for the fault plane's counter-seeded RNG (disjoint
/// from every trace-generator stream, which are small indices).
pub const FAULT_STREAM: u64 = 0xFA17_0175;

/// One region-outage window: at `start` every VM in `region` is lost
/// (in-flight work killed into the retry path, the donated spot pool
/// reclaimed, the region masked out of routing); at `end` the mask
/// lifts and the engine re-seeds the region's endpoints with
/// minimum-floor replacement VMs at realistic provisioning lead time.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionOutage {
    /// The region that goes dark.
    pub region: Region,
    /// Outage start (simulated seconds).
    pub start: Time,
    /// Outage end — when routing may use the region again.
    pub end: Time,
}

/// One spot-market preemption shock: at `at`, the external market
/// reclaims `frac` of every region's donated spot pool (the VMs are
/// gone — they do not return when the shock passes).
#[derive(Debug, Clone, PartialEq)]
pub struct SpotShock {
    /// Shock instant (simulated seconds).
    pub at: Time,
    /// Fraction of each region's donated pool reclaimed, in [0, 1].
    pub frac: f64,
}

/// One cross-region latency degradation window: requests served in
/// `region` pay `extra` seconds on top of normal routing latency, and
/// the *retry* path avoids the region while the window is open (normal
/// traffic still uses it — degraded beats dead).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyDegradation {
    /// The degraded region.
    pub region: Region,
    /// Window start (simulated seconds).
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// Extra latency charged per request served in the region (secs).
    pub extra: Time,
}

/// Capped-exponential-backoff retry policy for requests killed by
/// instance loss.  Attempt `n` (1-based) waits
/// `min(base_backoff · 2^(n−1), max_backoff)` before re-routing; after
/// `max_attempts` failures the request is permanently lost.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// First-attempt backoff (secs).
    pub base_backoff: Time,
    /// Backoff ceiling (secs) — the "capped" in capped exponential.
    pub max_backoff: Time,
    /// Kill count after which a request is declared lost.
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { base_backoff: 1.0, max_backoff: MINUTE, max_attempts: 5 }
    }
}

impl RetryPolicy {
    /// Backoff delay before retry attempt `attempt` (1-based), capped
    /// at [`RetryPolicy::max_backoff`].
    pub fn backoff(&self, attempt: u32) -> Time {
        let exp = attempt.saturating_sub(1).min(52);
        (self.base_backoff * (1u64 << exp) as f64).min(self.max_backoff)
    }
}

/// A declarative fault schedule.  `FaultPlan::default()` is empty —
/// the zero-cost no-fault configuration every existing experiment runs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Region outage windows.
    pub outages: Vec<RegionOutage>,
    /// Latency degradation windows.
    pub degradations: Vec<LatencyDegradation>,
    /// Spot-market preemption shocks.
    pub spot_shocks: Vec<SpotShock>,
    /// Expected VM crashes per instance-day (0 = no crash hazard).
    /// Sampled per live instance on a counter-seeded tick cadence.
    pub crash_rate_per_day: f64,
    /// Crash-hazard sampling interval (secs).
    pub crash_check_secs: Time,
    /// Retry policy applied to every killed request.
    pub retry: RetryPolicy,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            outages: Vec::new(),
            degradations: Vec::new(),
            spot_shocks: Vec::new(),
            crash_rate_per_day: 0.0,
            crash_check_secs: MINUTE,
            retry: RetryPolicy::default(),
        }
    }
}

impl FaultPlan {
    /// True when the plan injects nothing — the default, and the gate
    /// for every fault-plane code path in the engine.
    pub fn is_empty(&self) -> bool {
        self.outages.is_empty()
            && self.degradations.is_empty()
            && self.spot_shocks.is_empty()
            && self.crash_rate_per_day <= 0.0
    }

    /// Compile the plan into events.  Pushes **nothing** for an empty
    /// plan, so the event heap's sequence counter is untouched and
    /// no-fault runs stay bit-identical to a fault-plane-free build.
    /// Windows starting at or past `horizon` (trace end) are skipped;
    /// an end event is always paired with its start.
    pub fn compile(&self, events: &mut EventQueue, horizon: Time) {
        for (idx, o) in self.outages.iter().enumerate() {
            debug_assert!(o.end > o.start, "outage window must be positive");
            if o.start < horizon {
                events.push(o.start, Event::FaultOutageStart { idx });
                events.push(o.end, Event::FaultOutageEnd { idx });
            }
        }
        for (idx, d) in self.degradations.iter().enumerate() {
            debug_assert!(d.end > d.start, "degradation window must be positive");
            if d.start < horizon {
                events.push(d.start, Event::FaultDegradeStart { idx });
                events.push(d.end, Event::FaultDegradeEnd { idx });
            }
        }
        for (idx, s) in self.spot_shocks.iter().enumerate() {
            if s.at < horizon {
                events.push(s.at, Event::FaultSpotShock { idx });
            }
        }
        if self.crash_rate_per_day > 0.0 {
            debug_assert!(self.crash_check_secs > 0.0);
            events.push(self.crash_check_secs, Event::FaultCrashTick { k: 1 });
        }
    }

    /// The counter-seeded RNG for crash tick `k`: a pure function of
    /// `(seed, k)`, so chunked and sequential execution draw identical
    /// hazards with no RNG state in the handoff.
    pub fn crash_rng(seed: u64, k: u64) -> Rng {
        Rng::seed_from_parts(seed, k, FAULT_STREAM)
    }

    /// Per-instance crash probability per [`FaultPlan::crash_check_secs`] tick.
    pub fn crash_prob_per_tick(&self) -> f64 {
        (self.crash_rate_per_day * self.crash_check_secs / DAY).clamp(0.0, 1.0)
    }

    /// Preset: one region dark over `[start, end)`.
    pub fn region_dark(region: Region, start: Time, end: Time) -> FaultPlan {
        FaultPlan {
            outages: vec![RegionOutage { region, start, end }],
            ..FaultPlan::default()
        }
    }

    /// Preset: one market-wide spot preemption shock.
    pub fn spot_shock(at: Time, frac: f64) -> FaultPlan {
        FaultPlan { spot_shocks: vec![SpotShock { at, frac }], ..FaultPlan::default() }
    }

    /// Parse a CLI fault spec: `;`-separated clauses of
    ///
    /// * `region-dark=<region>@<start>-<end>` — outage window;
    /// * `degrade=<region>@<start>-<end>:<extra>` — latency window;
    /// * `spot-shock=<frac>@<t>` — market preemption shock;
    /// * `crash=<rate-per-instance-day>` — crash hazard;
    /// * `retry=<base>/<max>/<attempts>` — retry policy override.
    ///
    /// Times accept `s`/`m`/`h`/`d` suffixes (`48h`, `2d`, `90m`,
    /// `30s`, bare seconds).  Example:
    /// `region-dark=centralus@48h-60h;crash=0.05`.
    pub fn parse(s: &str) -> Option<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in s.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause.split_once('=')?;
            match key.trim() {
                "region-dark" | "outage" => {
                    let (region, rest) = val.split_once('@')?;
                    let (start, end) = parse_window(rest)?;
                    plan.outages.push(RegionOutage {
                        region: parse_region(region.trim())?,
                        start,
                        end,
                    });
                }
                "degrade" => {
                    let (region, rest) = val.split_once('@')?;
                    let (window, extra) = rest.rsplit_once(':')?;
                    let (start, end) = parse_window(window)?;
                    plan.degradations.push(LatencyDegradation {
                        region: parse_region(region.trim())?,
                        start,
                        end,
                        extra: parse_time(extra.trim())?,
                    });
                }
                "spot-shock" => {
                    let (frac, at) = val.split_once('@')?;
                    let frac: f64 = frac.trim().parse().ok()?;
                    if !(0.0..=1.0).contains(&frac) {
                        return None;
                    }
                    plan.spot_shocks.push(SpotShock { at: parse_time(at.trim())?, frac });
                }
                "crash" => {
                    let rate: f64 = val.trim().parse().ok()?;
                    if !rate.is_finite() || rate < 0.0 {
                        return None;
                    }
                    plan.crash_rate_per_day = rate;
                }
                "retry" => {
                    let mut parts = val.split('/');
                    let base = parse_time(parts.next()?.trim())?;
                    let max = parse_time(parts.next()?.trim())?;
                    let attempts: u32 = parts.next()?.trim().parse().ok()?;
                    if parts.next().is_some() {
                        return None;
                    }
                    plan.retry = RetryPolicy {
                        base_backoff: base,
                        max_backoff: max,
                        max_attempts: attempts,
                    };
                }
                _ => return None,
            }
        }
        Some(plan)
    }
}

/// Parse `<start>-<end>` with time-suffix bounds.
fn parse_window(s: &str) -> Option<(Time, Time)> {
    let (a, b) = s.split_once('-')?;
    let (start, end) = (parse_time(a.trim())?, parse_time(b.trim())?);
    if end > start {
        Some((start, end))
    } else {
        None
    }
}

/// Parse a duration with an optional `s`/`m`/`h`/`d` suffix.
fn parse_time(s: &str) -> Option<Time> {
    let (num, mult) = match s.as_bytes().last()? {
        b'd' => (&s[..s.len() - 1], DAY),
        b'h' => (&s[..s.len() - 1], HOUR),
        b'm' => (&s[..s.len() - 1], MINUTE),
        b's' => (&s[..s.len() - 1], 1.0),
        _ => (s, 1.0),
    };
    let v: f64 = num.trim().parse().ok()?;
    if v.is_finite() && v >= 0.0 {
        Some(v * mult)
    } else {
        None
    }
}

fn parse_region(s: &str) -> Option<Region> {
    match s.to_ascii_lowercase().as_str() {
        "eastus" | "east" => Some(Region::EastUs),
        "centralus" | "central" => Some(Region::CentralUs),
        "westus" | "west" => Some(Region::WestUs),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty_and_compiles_to_nothing() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        let mut q = EventQueue::new();
        plan.compile(&mut q, 7.0 * DAY);
        assert!(q.is_empty(), "empty plan must push zero events");
    }

    #[test]
    fn compile_pairs_window_events_and_skips_past_horizon() {
        let mut plan = FaultPlan::region_dark(Region::CentralUs, 2.0 * DAY, 2.5 * DAY);
        plan.spot_shocks.push(SpotShock { at: 3.0 * DAY, frac: 0.5 });
        plan.spot_shocks.push(SpotShock { at: 30.0 * DAY, frac: 0.5 }); // past horizon
        plan.crash_rate_per_day = 0.1;
        assert!(!plan.is_empty());
        let mut q = EventQueue::new();
        plan.compile(&mut q, 7.0 * DAY);
        // outage start + end, one in-horizon shock, first crash tick.
        assert_eq!(q.len(), 4);
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, MINUTE);
        assert_eq!(e, Event::FaultCrashTick { k: 1 });
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let pol = RetryPolicy::default();
        assert_eq!(pol.backoff(1), 1.0);
        assert_eq!(pol.backoff(2), 2.0);
        assert_eq!(pol.backoff(3), 4.0);
        assert_eq!(pol.backoff(7), 60.0, "must cap at max_backoff");
        assert_eq!(pol.backoff(60), 60.0, "huge attempt counts must not overflow");
        let tight = RetryPolicy { base_backoff: 0.5, max_backoff: 3.0, max_attempts: 9 };
        assert_eq!(tight.backoff(1), 0.5);
        assert_eq!(tight.backoff(4), 3.0);
    }

    #[test]
    fn crash_rng_is_a_pure_function_of_seed_and_tick() {
        let a = FaultPlan::crash_rng(42, 7).next_u64();
        let b = FaultPlan::crash_rng(42, 7).next_u64();
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::crash_rng(42, 8).next_u64());
        assert_ne!(a, FaultPlan::crash_rng(43, 7).next_u64());
    }

    #[test]
    fn parse_roundtrips_the_clause_grammar() {
        let plan = FaultPlan::parse(
            "region-dark=centralus@48h-60h; spot-shock=0.5@72h; crash=0.25; \
             degrade=westus@1d-2d:0.2s; retry=2s/30s/4",
        )
        .expect("valid spec");
        assert_eq!(plan.outages.len(), 1);
        assert_eq!(plan.outages[0].region, Region::CentralUs);
        assert_eq!(plan.outages[0].start, 48.0 * HOUR);
        assert_eq!(plan.outages[0].end, 60.0 * HOUR);
        assert_eq!(plan.spot_shocks, vec![SpotShock { at: 72.0 * HOUR, frac: 0.5 }]);
        assert_eq!(plan.crash_rate_per_day, 0.25);
        assert_eq!(plan.degradations[0].region, Region::WestUs);
        assert_eq!(plan.degradations[0].extra, 0.2);
        assert_eq!(
            plan.retry,
            RetryPolicy { base_backoff: 2.0, max_backoff: 30.0, max_attempts: 4 }
        );

        assert!(FaultPlan::parse("region-dark=nowhere@1h-2h").is_none());
        assert!(FaultPlan::parse("spot-shock=1.5@1h").is_none(), "frac > 1 rejected");
        assert!(FaultPlan::parse("region-dark=eastus@2h-1h").is_none(), "inverted window");
        assert!(FaultPlan::parse("bogus=1").is_none());
    }
}
